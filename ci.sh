#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, formatting, lints,
# and a `plan` subcommand smoke run (cold compute+persist, then a cache
# hit) against a synthetic bucket-only manifest.
#
#   ./ci.sh          # build + test + fmt + clippy + plan smoke
#   ./ci.sh bench    # additionally run the serve bench (emits BENCH_serve.json)
#
# The serve bench and the PJRT integration tests skip themselves when
# artifacts/ has not been built, so this script is runnable on a bare
# checkout.
set -euo pipefail
cd "$(dirname "$0")"
# The crate manifest may live at the repo root or under rust/ depending on
# how the build environment lays the workspace out; run cargo where it is.
if [[ ! -f Cargo.toml && -f rust/Cargo.toml ]]; then
    cd rust
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy -- -D warnings

# --- `adaptgear plan` smoke: needs only a manifest (buckets), no HLO.
# First invocation computes + persists the plan; the second must be served
# from the on-disk store with zero monitor iterations.
plan_smoke() {
    local bin=""
    local candidate
    for candidate in target/release/adaptgear ../target/release/adaptgear; do
        if [[ -x "$candidate" ]]; then
            bin="$candidate"
            break
        fi
    done
    if [[ -z "$bin" ]]; then
        echo "plan smoke: adaptgear binary not found, skipping"
        return 0
    fi
    local tmp
    tmp="$(mktemp -d)"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b1024": {"vertices": 1024, "edges": 4096, "features": 32,
               "hidden": 32, "classes": 8, "blocks": 64}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset cora --artifacts "$tmp" --explain
    echo "==> $bin plan (second run must hit the plan cache)"
    "$bin" plan --dataset cora --artifacts "$tmp" | tee "$tmp/second.txt"
    grep -q "cache hit" "$tmp/second.txt"
    rm -rf "$tmp"
}
plan_smoke

if [[ "${1:-}" == "bench" ]]; then
    run cargo bench --bench serve
fi

echo "ci.sh: all checks passed"
