#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, formatting, lints,
# a `plan` subcommand smoke run (cold compute+persist, then a cache
# hit), and a hybrid-split smoke on a mixed-density planted graph —
# all against synthetic bucket-only manifests.
#
#   ./ci.sh          # build + test + fmt + clippy + rustdoc (warnings
#                    # denied) + plan/hybrid/sampled/topk/trace/stream/
#                    # check/help smokes
#   ./ci.sh bench    # additionally run the quick bench suite: emit the
#                    # seven BENCH_*.json reports, schema-validate them,
#                    # self-check the comparator, and gate against
#                    # committed baselines/ when present
#
# The PJRT-backed bench tiers and the integration tests skip themselves
# when artifacts/ has not been built, so this script is runnable on a
# bare checkout.
set -euo pipefail
cd "$(dirname "$0")"
ROOT="$(pwd)"

# Every mktemp -d in this script is registered here and removed by ONE
# EXIT trap, so a failure inside any smoke function cannot leak tempdirs
# (the old per-function `rm -rf` never ran when a step failed mid-way).
CI_TMPDIRS=()
cleanup_tmpdirs() {
    if [[ ${#CI_TMPDIRS[@]} -gt 0 ]]; then
        rm -rf "${CI_TMPDIRS[@]}"
    fi
}
trap cleanup_tmpdirs EXIT
# Sets NEW_TMPDIR (no command substitution: `$(new_tmpdir)` would run in
# a subshell and the registration would never reach the parent's array).
new_tmpdir() {
    NEW_TMPDIR="$(mktemp -d)"
    CI_TMPDIRS+=("$NEW_TMPDIR")
}

# Fail fast with a clear message when the toolchain is missing — every
# check below needs it, and a bare "command not found" mid-run is easy
# to misread as a test failure.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: error: cargo not found on PATH." >&2
    echo "ci.sh: tier-1 verification is 'cargo build --release && cargo test -q';" >&2
    echo "ci.sh: install the Rust toolchain (e.g. rustup) and re-run." >&2
    exit 1
fi

# The crate manifest may live at the repo root or under rust/ depending on
# how the build environment lays the workspace out; run cargo where it is.
if [[ ! -f Cargo.toml && -f rust/Cargo.toml ]]; then
    cd rust
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
# Rustdoc gate: module docs and intra-doc links must stay warning-free
# (README.md and DESIGN.md point into these docs).
run env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

find_bin() {
    local candidate
    for candidate in target/release/adaptgear ../target/release/adaptgear; do
        if [[ -x "$candidate" ]]; then
            echo "$candidate"
            return 0
        fi
    done
    return 1
}

# Assert a grep pattern holds, printing the whole file on failure so the
# CI log shows what the command actually said instead of a bare exit 1.
expect_grep() {
    local pattern="$1" file="$2" what="$3"
    # -e so patterns that start with a dash (e.g. "--sampled") work
    if ! grep -q -e "$pattern" "$file"; then
        echo "FAILED: $what (pattern '$pattern' not found). Output was:" >&2
        cat "$file" >&2
        exit 1
    fi
}

# --- `adaptgear plan` smoke: needs only a manifest (buckets), no HLO.
# First invocation computes + persists the plan; the second must be served
# from the on-disk store with zero monitor iterations.
plan_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "plan smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b1024": {"vertices": 1024, "edges": 4096, "features": 32,
               "hidden": 32, "classes": 8, "blocks": 64}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset cora --artifacts "$tmp" --explain
    echo "==> $bin plan (second run must hit the plan cache)"
    "$bin" plan --dataset cora --artifacts "$tmp" | tee "$tmp/second.txt"
    expect_grep "cache hit" "$tmp/second.txt" \
        "plan smoke: second run did not hit the plan cache"
}
plan_smoke

# --- hybrid smoke: on the mixed-density planted graph the planner must
# split the intra diagonal into >= 2 density classes with distinct
# kernels, price the split below both uniform plans, and cache-hit on
# the second invocation.
hybrid_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "hybrid smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b512k": {"vertices": 524288, "edges": 8388608, "features": 32,
               "hidden": 32, "classes": 4, "blocks": 32768}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset planted-mixed --artifacts "$tmp" --explain \
        | tee "$tmp/explain.txt"
    echo "==> hybrid smoke: the plan must carry two intra classes"
    expect_grep "intra classes: 2" "$tmp/explain.txt" \
        "hybrid smoke: plan did not split into two intra classes"
    expect_grep "dense_intra" "$tmp/explain.txt" "hybrid smoke: no dense_intra class"
    expect_grep "sparse_intra" "$tmp/explain.txt" "hybrid smoke: no sparse_intra class"
    expect_grep "tile_sparse" "$tmp/explain.txt" \
        "hybrid smoke: explain does not list the tile_sparse kernel"
    expect_grep "feature density" "$tmp/explain.txt" \
        "hybrid smoke: explain does not print the feature-density term"
    echo "==> $bin plan (hybrid replan must hit the plan cache)"
    "$bin" plan --dataset planted-mixed --artifacts "$tmp" | tee "$tmp/second.txt"
    expect_grep "cache hit" "$tmp/second.txt" \
        "hybrid smoke: second run did not hit the plan cache"
}
hybrid_smoke

# --- sampled-training smoke: `train --sampled` must complete an epoch on
# a bare checkout (native CPU backend) and report an amortized plan-cache
# hit rate; the >50% bar itself is enforced by the bench suite's unit
# test, so the smoke only asserts the loop ran end to end.
sampled_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "sampled smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    echo "==> $bin train --sampled (native backend, one epoch)"
    "$bin" train --dataset planted-mixed --sampled --fanout 10,10 \
        --batch-size 128 --scale 0.004 --artifacts "$tmp/none" \
        | tee "$tmp/sampled.txt"
    expect_grep "sampled training \[native\]" "$tmp/sampled.txt" \
        "sampled smoke: the sampled loop did not complete"
    expect_grep "plan cache: " "$tmp/sampled.txt" \
        "sampled smoke: no amortized plan-cache report"
    expect_grep "epoch   0" "$tmp/sampled.txt" \
        "sampled smoke: no epoch loss line"
}
sampled_smoke

# --- top-k smoke: the fused feature-sparsity mode must complete a
# native epoch and report the k it trained with; the dense-equivalence
# and gradient contracts are pinned by tests/feat_prop.rs, so the smoke
# only asserts the flag drives the loop end to end.
topk_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "topk smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    echo "==> $bin train --sampled --topk 16 (native backend, one epoch)"
    "$bin" train --dataset planted-mixed --sampled --fanout 10,10 \
        --batch-size 128 --scale 0.004 --topk 16 --artifacts "$tmp/none" \
        | tee "$tmp/topk.txt"
    expect_grep "sampled training \[native\]" "$tmp/topk.txt" \
        "topk smoke: the sampled loop did not complete"
    expect_grep "topk 16" "$tmp/topk.txt" \
        "topk smoke: the report does not record the top-k width"
    expect_grep "epoch   0" "$tmp/topk.txt" \
        "topk smoke: no epoch loss line"
}
topk_smoke

# --- trace smoke: `train --sampled --trace-out` must emit a parseable
# Chrome trace (Perfetto-loadable) carrying the sampled-loop span
# taxonomy and a non-zero epoch-2 plan-cache-hit counter. The trace is
# written to the repo root so CI uploads it alongside BENCH_*.json.
trace_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "trace smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    local trace="$ROOT/TRACE_sampled.json"
    echo "==> $bin train --sampled --trace-out (two epochs, native backend)"
    "$bin" train --dataset planted-mixed --sampled --fanout 10,10 \
        --batch-size 128 --scale 0.004 --epochs 2 \
        --artifacts "$tmp/none" --trace-out "$trace" \
        | tee "$tmp/traced.txt"
    expect_grep "trace: " "$tmp/traced.txt" \
        "trace smoke: the run did not report writing a trace"
    expect_grep '"traceEvents"' "$trace" \
        "trace smoke: not a Chrome trace-event file"
    expect_grep '"name":"train.sample"' "$trace" \
        "trace smoke: no train.sample span"
    expect_grep '"name":"train.plan"' "$trace" \
        "trace smoke: no train.plan span"
    expect_grep '"name":"train.step"' "$trace" \
        "trace smoke: no train.step span"
    # epoch 2 must be served from the per-batch plan cache
    expect_grep '"plan.cache.hit":[1-9]' "$trace" \
        "trace smoke: epoch 2 recorded zero plan-cache hits"
    # the embedded metrics snapshot must survive a real JSON parser
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$trace" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    t = json.load(f)
assert isinstance(t["traceEvents"], list) and t["traceEvents"], "empty traceEvents"
assert {e["ph"] for e in t["traceEvents"]} <= {"B", "E"}, "unexpected phase"
EOF
    fi
}
trace_smoke

# --- stream smoke: the deterministic mutation workload must drift one
# side of the plan (not all classes), re-plan it online, swap the live
# plan, and stay numerically faithful — asserted via the "plan swapped"
# line, a non-zero plan.replan.class counter, and the forward check.
stream_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "stream smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    echo "==> $bin stream (deterministic mutation workload, native backend)"
    "$bin" stream --dataset planted-mixed --reweights 200 \
        | tee "$tmp/stream.txt"
    expect_grep "plan swapped" "$tmp/stream.txt" \
        "stream smoke: no plan swap line"
    expect_grep "plan.replan.class=[1-9]" "$tmp/stream.txt" \
        "stream smoke: plan.replan.class counter did not move"
    expect_grep "forward max err" "$tmp/stream.txt" \
        "stream smoke: no forward equivalence check"
}
stream_smoke

# --- check smoke: the static invariant audit end to end. A freshly
# planned store must audit clean (exit 0); corrupting one invariant in
# one plan file must flip the exit code and name the documented lint
# code (AG022). A second clean run writes CHECK_report.json at the repo
# root so CI uploads it alongside BENCH_*.json and TRACE_*.json.
check_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "check smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b512k": {"vertices": 524288, "edges": 8388608, "features": 32,
               "hidden": 32, "classes": 4, "blocks": 32768}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset planted-mixed --artifacts "$tmp"
    run "$bin" check --artifacts "$tmp" --out "$tmp/CHECK_clean.json"
    expect_grep '"errors":0' "$tmp/CHECK_clean.json" \
        "check smoke: fresh plan store did not audit clean"
    # The repo-root report CI uploads; fold in the trace-smoke artifact
    # so the obs analyzer audits a real exported trace when one exists.
    if [[ -f "$ROOT/TRACE_sampled.json" ]]; then
        run "$bin" check --artifacts "$tmp" --trace "$ROOT/TRACE_sampled.json" \
            --out "$ROOT/CHECK_report.json"
    else
        run "$bin" check --artifacts "$tmp" --out "$ROOT/CHECK_report.json"
    fi

    echo "==> check smoke: a corrupted plan must exit non-zero with AG022"
    local plan_file
    plan_file="$(ls "$tmp"/plans/plan_*.json | head -n1)"
    sed -E -i 's/"threshold":[-+0-9.eE]+/"threshold":-1/g' "$plan_file"
    if "$bin" check --artifacts "$tmp" --out "$tmp/CHECK_broken.json" \
        > "$tmp/broken.txt" 2>&1; then
        echo "FAILED: check smoke: corrupted plan store exited zero" >&2
        cat "$tmp/broken.txt" >&2
        exit 1
    fi
    expect_grep "AG022" "$tmp/broken.txt" \
        "check smoke: corrupted threshold did not surface AG022"
}
check_smoke

# --- help smoke: every subcommand documents itself with an example the
# README can point at (`adaptgear <cmd> --help`).
help_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "help smoke: adaptgear binary not found, skipping"
        return 0
    fi
    new_tmpdir
    local tmp="$NEW_TMPDIR"
    echo "==> help smoke: per-subcommand examples"
    for cmd in datasets decompose plan train serve stream bench check selftest; do
        "$bin" "$cmd" --help > "$tmp/help_$cmd.txt"
        expect_grep "EXAMPLE" "$tmp/help_$cmd.txt" \
            "help smoke: $cmd --help has no EXAMPLE section"
        expect_grep "adaptgear $cmd" "$tmp/help_$cmd.txt" \
            "help smoke: $cmd --help example does not invoke the command"
    done
    "$bin" --help > "$tmp/help_top.txt"
    expect_grep "\-\-sampled" "$tmp/help_top.txt" \
        "help smoke: top-level help does not mention --sampled"
    expect_grep "sample" "$tmp/help_top.txt" \
        "help smoke: top-level help does not mention the sample suite"
    expect_grep "feat" "$tmp/help_top.txt" \
        "help smoke: top-level help does not mention the feat suite"
    expect_grep "feat" "$tmp/help_bench.txt" \
        "help smoke: bench --help does not list the feat suite"
}
help_smoke

# --- `./ci.sh bench`: the quick benchmark suite end to end.
# Emits BENCH_{kernels,plan,train,serve,sample,stream,feat}.json at the
# repo root, schema-validates all seven, proves the comparator on a
# known-identical baseline (must pass), and gates against committed
# baselines/ when they exist.
bench_mode() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "bench: adaptgear binary not found, skipping"
        return 0
    fi
    run "$bin" bench --quick --out "$ROOT" --artifacts artifacts
    run "$bin" bench --validate --out "$ROOT"

    echo "==> bench: comparator self-check (a run vs itself must pass)"
    new_tmpdir
    local self="$NEW_TMPDIR"
    cp "$ROOT"/BENCH_*.json "$self"/
    run "$bin" bench --check --baseline "$self" --out "$ROOT"

    if [[ -d "$ROOT/baselines" ]]; then
        run "$bin" bench --check --baseline "$ROOT/baselines" --out "$ROOT"
    else
        echo "bench: no baselines/ directory — skipping the regression gate"
        echo "bench: (to enable it: copy the emitted BENCH_*.json into baselines/ and commit)"
    fi
}
if [[ "${1:-}" == "bench" ]]; then
    bench_mode
fi

echo "ci.sh: all checks passed"
