#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, formatting, lints.
#
#   ./ci.sh          # build + test + fmt + clippy
#   ./ci.sh bench    # additionally run the serve bench (emits BENCH_serve.json)
#
# The serve bench and the PJRT integration tests skip themselves when
# artifacts/ has not been built, so this script is runnable on a bare
# checkout.
set -euo pipefail
cd "$(dirname "$0")"
# The crate manifest may live at the repo root or under rust/ depending on
# how the build environment lays the workspace out; run cargo where it is.
if [[ ! -f Cargo.toml && -f rust/Cargo.toml ]]; then
    cd rust
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy -- -D warnings

if [[ "${1:-}" == "bench" ]]; then
    run cargo bench --bench serve
fi

echo "ci.sh: all checks passed"
