#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, formatting, lints,
# a `plan` subcommand smoke run (cold compute+persist, then a cache
# hit), and a hybrid-split smoke on a mixed-density planted graph —
# all against synthetic bucket-only manifests.
#
#   ./ci.sh          # build + test + fmt + clippy + plan/hybrid smokes
#   ./ci.sh bench    # additionally run the serve bench (emits BENCH_serve.json)
#
# The serve bench and the PJRT integration tests skip themselves when
# artifacts/ has not been built, so this script is runnable on a bare
# checkout.
set -euo pipefail
cd "$(dirname "$0")"

# Fail fast with a clear message when the toolchain is missing — every
# check below needs it, and a bare "command not found" mid-run is easy
# to misread as a test failure.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: error: cargo not found on PATH." >&2
    echo "ci.sh: tier-1 verification is 'cargo build --release && cargo test -q';" >&2
    echo "ci.sh: install the Rust toolchain (e.g. rustup) and re-run." >&2
    exit 1
fi

# The crate manifest may live at the repo root or under rust/ depending on
# how the build environment lays the workspace out; run cargo where it is.
if [[ ! -f Cargo.toml && -f rust/Cargo.toml ]]; then
    cd rust
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy -- -D warnings

find_bin() {
    local candidate
    for candidate in target/release/adaptgear ../target/release/adaptgear; do
        if [[ -x "$candidate" ]]; then
            echo "$candidate"
            return 0
        fi
    done
    return 1
}

# --- `adaptgear plan` smoke: needs only a manifest (buckets), no HLO.
# First invocation computes + persists the plan; the second must be served
# from the on-disk store with zero monitor iterations.
plan_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "plan smoke: adaptgear binary not found, skipping"
        return 0
    fi
    local tmp
    tmp="$(mktemp -d)"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b1024": {"vertices": 1024, "edges": 4096, "features": 32,
               "hidden": 32, "classes": 8, "blocks": 64}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset cora --artifacts "$tmp" --explain
    echo "==> $bin plan (second run must hit the plan cache)"
    "$bin" plan --dataset cora --artifacts "$tmp" | tee "$tmp/second.txt"
    grep -q "cache hit" "$tmp/second.txt"
    rm -rf "$tmp"
}
plan_smoke

# --- hybrid smoke: on the mixed-density planted graph the planner must
# split the intra diagonal into >= 2 density classes with distinct
# kernels, price the split below both uniform plans, and cache-hit on
# the second invocation.
hybrid_smoke() {
    local bin
    if ! bin="$(find_bin)"; then
        echo "hybrid smoke: adaptgear binary not found, skipping"
        return 0
    fi
    local tmp
    tmp="$(mktemp -d)"
    cat > "$tmp/manifest.json" <<'EOF'
{
  "version": 1, "community": 16,
  "buckets": {
    "b512k": {"vertices": 524288, "edges": 8388608, "features": 32,
               "hidden": 32, "classes": 4, "blocks": 32768}
  },
  "artifacts": []
}
EOF
    run "$bin" plan --dataset planted-mixed --artifacts "$tmp" --explain \
        | tee "$tmp/explain.txt"
    echo "==> hybrid smoke: the plan must carry two intra classes"
    grep -q "intra classes: 2" "$tmp/explain.txt"
    grep -q "dense_intra" "$tmp/explain.txt"
    grep -q "sparse_intra" "$tmp/explain.txt"
    echo "==> $bin plan (hybrid replan must hit the plan cache)"
    "$bin" plan --dataset planted-mixed --artifacts "$tmp" | tee "$tmp/second.txt"
    grep -q "cache hit" "$tmp/second.txt"
    rm -rf "$tmp"
}
hybrid_smoke

if [[ "${1:-}" == "bench" ]]; then
    run cargo bench --bench serve
fi

echo "ci.sh: all checks passed"
