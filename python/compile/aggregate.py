"""Differentiable subgraph aggregation: custom VJPs over the Pallas kernels.

``pallas_call`` has no automatic transpose rule, so each kernel is wrapped
in a ``jax.custom_vjp`` whose backward pass is *another aggregation*:

    y = A @ x        =>       dL/dx = A.T @ dL/dy

For the CSR kernels the propagation matrices AdaptGear trains with (GCN's
D^-1/2 (A+I) D^-1/2, GIN's A for an undirected graph) are symmetric, and
the intra (block-diagonal) / inter (off-diagonal) splits of a symmetric
matrix are themselves symmetric, so backward reuses the forward kernel
unchanged.  COO and dense-block have exact cheap transposes (swap src/dst;
transpose each block) and use them, so those two kernels are correct for
asymmetric adjacencies too.

Graph-topology operands receive symbolic-zero cotangents (``float0`` for
integer arrays) — gradients flow only through the feature path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.coo_scatter import coo_aggregate
from .kernels.csr_inter import csr_inter_aggregate
from .kernels.csr_intra import csr_intra_aggregate
from .kernels.dense_block import dense_block_aggregate

INTRA_NONE = "none"


def _int_zero(a):
    return np.zeros(np.shape(a), dtype=jax.dtypes.float0)


# -- CSR inter ---------------------------------------------------------------

@jax.custom_vjp
def csr_inter(row_ptr, col_idx, val, x):
    return csr_inter_aggregate(row_ptr, col_idx, val, x)


def _csr_inter_fwd(row_ptr, col_idx, val, x):
    return csr_inter_aggregate(row_ptr, col_idx, val, x), (row_ptr, col_idx, val)


def _csr_inter_bwd(res, dy):
    row_ptr, col_idx, val = res
    # symmetric adjacency: A.T @ dy == A @ dy
    return (_int_zero(row_ptr), _int_zero(col_idx), jnp.zeros_like(val),
            csr_inter_aggregate(row_ptr, col_idx, val, dy))


csr_inter.defvjp(_csr_inter_fwd, _csr_inter_bwd)


# -- CSR intra ---------------------------------------------------------------

@jax.custom_vjp
def csr_intra(row_ptr, col_local, val, x):
    return csr_intra_aggregate(row_ptr, col_local, val, x)


def _csr_intra_fwd(row_ptr, col_local, val, x):
    return csr_intra_aggregate(row_ptr, col_local, val, x), (row_ptr, col_local, val)


def _csr_intra_bwd(res, dy):
    row_ptr, col_local, val = res
    return (_int_zero(row_ptr), _int_zero(col_local), jnp.zeros_like(val),
            csr_intra_aggregate(row_ptr, col_local, val, dy))


csr_intra.defvjp(_csr_intra_fwd, _csr_intra_bwd)


# -- COO ---------------------------------------------------------------------

@jax.custom_vjp
def coo(src, dst, val, x):
    return coo_aggregate(src, dst, val, x)


def _coo_fwd(src, dst, val, x):
    return coo_aggregate(src, dst, val, x), (src, dst, val)


def _coo_bwd(res, dy):
    src, dst, val = res
    # exact transpose: swap src/dst
    return (_int_zero(src), _int_zero(dst), jnp.zeros_like(val),
            coo_aggregate(dst, src, val, dy))


coo.defvjp(_coo_fwd, _coo_bwd)


# -- dense block -------------------------------------------------------------

@jax.custom_vjp
def dense_block(blocks, x):
    return dense_block_aggregate(blocks, x)


def _dense_fwd(blocks, x):
    return dense_block_aggregate(blocks, x), blocks


def _dense_bwd(blocks, dy):
    # exact transpose: per-block transposition
    return (jnp.zeros_like(blocks),
            dense_block_aggregate(jnp.swapaxes(blocks, 1, 2), dy))


dense_block.defvjp(_dense_fwd, _dense_bwd)


# -- dispatcher ----------------------------------------------------------------

#: operand arity per kernel kind (excluding the feature operand).
KERNEL_ARITY = {"csr_inter": 3, "csr_intra": 3, "coo": 3, "dense_block": 1, INTRA_NONE: 0}

_DISPATCH = {
    "csr_inter": csr_inter,
    "csr_intra": csr_intra,
    "coo": coo,
    "dense_block": dense_block,
}


def aggregate(kind, ops, x):
    """Run one subgraph aggregation: ``kind`` over operand tuple ``ops``."""
    if kind == INTRA_NONE:
        raise ValueError("aggregate() called with kind='none'")
    return _DISPATCH[kind](*ops, x)


def aggregate_combined(intra_kind, inter_kind, intra_ops, inter_ops, x):
    """Full-graph propagation: intra-subgraph + inter-subgraph partials.

    With ``intra_kind == 'none'`` the whole graph is expected in the inter
    operands (full-graph-level baselines, AdaptGear O1).
    """
    y = aggregate(inter_kind, inter_ops, x)
    if intra_kind != INTRA_NONE:
        y = y + aggregate(intra_kind, intra_ops, x)
    return y
