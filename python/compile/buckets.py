"""Static shape buckets for AOT compilation.

HLO artifacts have static shapes, but graphs do not.  AdaptGear's Rust
coordinator pads every (sub)graph into the smallest fitting bucket; zero
padding is exact for aggregate-sum (padding edges carry weight 0, padding
rows are masked out of the loss).

Bucket geometry mirrors the paper's setup: community size 16 (METIS
community size used throughout the evaluation, Sec. 5), hidden dim per the
GCN/GIN defaults.
"""

from dataclasses import dataclass


COMMUNITY = 16  # paper's METIS community size (Sec. 5 / Fig. 4)


@dataclass(frozen=True)
class Bucket:
    """One static-shape compilation bucket.

    Attributes:
      name:      manifest key (appears in artifact filenames).
      vertices:  padded vertex count (multiple of COMMUNITY).
      edges:     padded edge capacity for EACH of the intra / inter
                 subgraph operand sets.
      features:  padded input feature width.
      hidden:    hidden width of both GNN models.
      classes:   padded class count.
    """

    name: str
    vertices: int
    edges: int
    features: int
    hidden: int
    classes: int

    @property
    def blocks(self) -> int:
        """Number of diagonal community blocks."""
        return self.vertices // COMMUNITY


# Kept deliberately small: this session runs Pallas in interpret mode on a
# single-core CPU PJRT client, so these buckets bound the *numerics* path.
# Full-scale datasets run through the native Rust kernels + gpusim for the
# performance figures (see DESIGN.md Sec. 6).
BUCKETS = [
    Bucket(name="b256", vertices=256, edges=1024, features=32, hidden=32, classes=8),
    Bucket(name="b1024", vertices=1024, edges=4096, features=32, hidden=32, classes=8),
]

BUCKETS_BY_NAME = {b.name: b for b in BUCKETS}

# Kernel identifiers.  Intra-community candidates exploit the dense diagonal
# blocks; inter-community candidates handle the sparse remainder.  "none"
# means the model consumes only the inter operands (full-graph baselines).
INTRA_KERNELS = ("csr_intra", "dense_block")
INTER_KERNELS = ("csr_inter", "coo")
MODELS = ("gcn", "gin")
