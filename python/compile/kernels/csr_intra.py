"""Community-resident CSR aggregation kernel for high-density
intra-community subgraphs.

Paper analogue (Fig. 6, right): a CTA owns one community; because an
intra-community edge's endpoints both lie inside the community, the
community's feature tile fits a bounded fast-memory budget and is preloaded
into shared memory, then reused by every row of the community.  The Pallas
adaptation expresses exactly that with a BlockSpec: grid step ``b`` maps the
feature operand to block ``b`` of shape ``[C, F]`` — the tile is
VMEM-resident for the whole step, and column indices are LOCAL (0..C).

Operand contract:
  row_ptr [V+1] i32 (global rows), col_local [E] i32 (0..C), val [E] f32,
  x [V, F] f32 (consumed as [nB, C, F] community tiles)  ->  y [V, F]
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..buckets import COMMUNITY


def _make_kernel(community):
    def kernel(rp_ref, ci_ref, val_ref, xb_ref, o_ref):
        b = pl.program_id(0)
        f = o_ref.shape[1]

        def row_body(r, carry):
            row = b * community + r
            start = rp_ref[row]
            end = rp_ref[row + 1]

            def nz(i, acc):
                lc = ci_ref[i]
                # xb_ref is the community's VMEM-resident tile ("shared
                # memory"); lc is a local index within it.
                return acc + val_ref[i] * xb_ref[lc, :]

            acc = jax.lax.fori_loop(start, end, nz, jnp.zeros((f,), jnp.float32))
            o_ref[r, :] = acc
            return carry

        jax.lax.fori_loop(0, community, row_body, 0)

    return kernel


def csr_intra_aggregate(row_ptr, col_local, val, x, community=COMMUNITY):
    """Aggregate-sum over a local-CSR intra-community subgraph.

    The block-diagonal adjacency is required to be SYMMETRIC; backward
    reuses this kernel unchanged.
    """
    v, f = x.shape
    e = col_local.shape[0]
    if v % community != 0:
        raise ValueError(f"padded vertex count {v} not a multiple of {community}")
    nb = v // community
    return pl.pallas_call(
        _make_kernel(community),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((v + 1,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            # "preload the community's features into shared memory"
            pl.BlockSpec((community, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((community, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, f), jnp.float32),
        interpret=True,
    )(row_ptr, col_local, val, x)
