"""Layer-1 Pallas kernels: density-specialized subgraph aggregation.

Every kernel computes the same contract — ``Y = A @ X`` for its subgraph's
(weighted) adjacency ``A`` — but with a compute/memory schedule specialized
to a density regime, mirroring AdaptGear Sec. 3.2:

============  ======================  =====================================
kernel        paper analogue          schedule
============  ======================  =====================================
csr_inter     CSR inter-community     vertex-parallel row blocks; neighbor
              kernel (CTA -> rows)    features gathered from the full
                                      feature array ("global memory")
csr_intra     CSR intra-community     CTA -> community; the community's
              kernel (shared-memory   feature tile is block-resident in
              resident)               VMEM via BlockSpec and reused
coo           COO edge-parallel       edge-parallel scatter-accumulate
              atomic kernel           (TPU adaptation of atomicAdd)
dense_block   batched-GEMM Tensor-    dense per-community matmul on the
              Core kernel             MXU (``jnp.dot`` per block)
============  ======================  =====================================

All kernels run with ``interpret=True`` so they lower to portable HLO the
CPU PJRT client can execute (real-TPU Mosaic lowering is compile-only in
this environment — see DESIGN.md Sec. 1).
"""

from . import ref  # noqa: F401
from .coo_scatter import coo_aggregate  # noqa: F401
from .csr_inter import csr_inter_aggregate  # noqa: F401
from .csr_intra import csr_intra_aggregate  # noqa: F401
from .dense_block import dense_block_aggregate  # noqa: F401
