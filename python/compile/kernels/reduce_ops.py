"""Aggregate-max and aggregate-mean (paper Sec. 2.1's other reducers).

GCN/GIN train on aggregate-sum, but the paper's operator taxonomy (and
GraphSAGE-style models a downstream user would add) needs ``max`` and
``mean`` too:

* ``mean`` needs NO new kernel: it is aggregate-sum with per-edge weights
  ``1/deg(dst)``, which the Rust packer materializes in the ``val``
  operand (`rust/src/kernels/pack.rs` consumers, see
  `graph::csr::Csr::row_mean_normalized`).
* ``max`` needs a dedicated schedule because it is not linear: this module
  provides the vertex-parallel CSR max kernel (neighbors only; empty
  neighborhoods yield 0, matching DGL's copy-free semantics).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 16

_NEG = -3.0e38  # effectively -inf for f32 without inf-propagation risk


def _make_max_kernel(row_block):
    def kernel(rp_ref, ci_ref, x_ref, o_ref):
        blk = pl.program_id(0)
        f = o_ref.shape[1]

        def row_body(r, carry):
            row = blk * row_block + r
            start = rp_ref[row]
            end = rp_ref[row + 1]

            def nz(i, acc):
                c = ci_ref[i]
                return jnp.maximum(acc, x_ref[c, :])

            acc = jax.lax.fori_loop(start, end, nz, jnp.full((f,), _NEG, jnp.float32))
            # empty neighborhoods -> 0 (no neighbor signal)
            acc = jnp.where(end > start, acc, jnp.zeros((f,), jnp.float32))
            o_ref[r, :] = acc
            return carry

        jax.lax.fori_loop(0, row_block, row_body, 0)

    return kernel


def csr_max_aggregate(row_ptr, col_idx, x):
    """Aggregate-max over a padded CSR topology: ``y[v] = max_u x[u]``."""
    v, f = x.shape
    e = col_idx.shape[0]
    rb = min(ROW_BLOCK, v)
    if v % rb != 0:
        raise ValueError(f"padded vertex count {v} not a multiple of {rb}")
    return pl.pallas_call(
        _make_max_kernel(rb),
        grid=(v // rb,),
        in_specs=[
            pl.BlockSpec((v + 1,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((v, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, f), jnp.float32),
        interpret=True,
    )(row_ptr, col_idx, x)


def mean_weights(row_ptr, n_edges_padded):
    """Edge weights that turn the SUM kernels into MEAN aggregation:
    ``w = 1/deg(dst)`` per edge, zero padding preserved."""
    import numpy as np

    row_ptr = np.asarray(row_ptr)
    vals = np.zeros(n_edges_padded, np.float32)
    n = row_ptr.shape[0] - 1
    for r in range(n):
        deg = int(row_ptr[r + 1]) - int(row_ptr[r])
        if deg:
            vals[int(row_ptr[r]) : int(row_ptr[r + 1])] = 1.0 / deg
    return vals
