"""Edge-parallel COO aggregation kernel.

Paper analogue (Algo. 1): one CUDA thread per edge, ``atomicAdd`` into the
destination row.  TPUs have no atomics; the Pallas adaptation processes an
edge *block* per grid step and serially scatter-accumulates inside the
step while the output block stays resident in VMEM across all grid steps
(the revisited-block idiom).  Parallelism across the feature dimension is
vectorized (a full feature row per accumulate), which is the natural VPU
layout, in place of the paper's thread-per-scalar layout.

Operand contract (padding: src=dst=0, val=0.0 — exact for aggregate-sum):
  src [E] i32, dst [E] i32, val [E] f32, x [V, F] f32  ->  y [V, F] f32
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Edges processed per grid step.  Structure choice, not a CPU tuning knob:
# on a real TPU this is the double-buffered HBM->VMEM edge-stream chunk.
EDGE_BLOCK = 256


def _coo_kernel(src_ref, dst_ref, val_ref, x_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def body(i, carry):
        s = src_ref[i]
        d = dst_ref[i]
        w = val_ref[i]
        # atomicAdd(dst_row, w * src_row) — serialized within the step,
        # safe because the output block is revisited (never flushed)
        # between steps.
        o_ref[d, :] = o_ref[d, :] + w * x_ref[s, :]
        return carry

    jax.lax.fori_loop(0, src_ref.shape[0], body, 0)


def coo_aggregate(src, dst, val, x):
    """Aggregate-sum over a padded COO edge list: returns ``A @ x``."""
    e = src.shape[0]
    v, f = x.shape
    eb = min(EDGE_BLOCK, e)
    if e % eb != 0:
        raise ValueError(f"padded edge count {e} not a multiple of {eb}")
    return pl.pallas_call(
        _coo_kernel,
        grid=(e // eb,),
        in_specs=[
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((v, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((v, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((v, f), jnp.float32),
        interpret=True,
    )(src, dst, val, x)


def coo_aggregate_t(src, dst, val, x):
    """Aggregate with the exact transpose ``A.T @ x`` (swap src/dst)."""
    return coo_aggregate(dst, src, val, x)
