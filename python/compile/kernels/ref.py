"""Pure-jnp correctness oracles for every subgraph kernel format.

Each oracle reconstructs the dense adjacency implied by a padded operand
set and computes ``A @ X`` directly.  The Pallas kernels (and, through the
parity fixtures exported by the Rust test suite, the native Rust kernels)
must match these to float32 tolerance.

Padding semantics (shared contract with rust/src/kernels/spec.rs):
  * CSR    — ``row_ptr`` has ``V+1`` entries and is exact; the tail of
             ``col_idx``/``vals`` up to the padded edge capacity carries
             ``col=0, val=0.0``.
  * COO    — padding edges are ``(src=0, dst=0, val=0.0)``.
  * dense  — block-diagonal ``[nB, C, C]`` array; padding is literal zeros.
  * intra  — column indices are LOCAL to the community (0..C).
"""

import jax.numpy as jnp
import numpy as np


def dense_from_csr(row_ptr, col_idx, vals, n_cols):
    """Dense [V, n_cols] matrix from a (padded) CSR triplet."""
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    vals = np.asarray(vals)
    n_rows = row_ptr.shape[0] - 1
    a = np.zeros((n_rows, n_cols), dtype=np.float32)
    for r in range(n_rows):
        for i in range(int(row_ptr[r]), int(row_ptr[r + 1])):
            a[r, int(col_idx[i])] += float(vals[i])
    return a


def dense_from_coo(src, dst, vals, n):
    """Dense [n, n] matrix from padded COO edges (dst row, src col)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    vals = np.asarray(vals)
    a = np.zeros((n, n), dtype=np.float32)
    for s, d, v in zip(src, dst, vals):
        a[int(d), int(s)] += float(v)
    return a


def dense_from_blocks(blocks):
    """Dense [V, V] block-diagonal matrix from [nB, C, C] blocks."""
    blocks = np.asarray(blocks)
    nb, c, _ = blocks.shape
    a = np.zeros((nb * c, nb * c), dtype=np.float32)
    for b in range(nb):
        a[b * c : (b + 1) * c, b * c : (b + 1) * c] = blocks[b]
    return a


def dense_from_csr_intra(row_ptr, col_idx_local, vals, community):
    """Dense [V, V] matrix from the intra-community local-CSR format."""
    row_ptr = np.asarray(row_ptr)
    col_idx_local = np.asarray(col_idx_local)
    vals = np.asarray(vals)
    n = row_ptr.shape[0] - 1
    a = np.zeros((n, n), dtype=np.float32)
    for r in range(n):
        base = (r // community) * community
        for i in range(int(row_ptr[r]), int(row_ptr[r + 1])):
            a[r, base + int(col_idx_local[i])] += float(vals[i])
    return a


def aggregate_ref(a_dense, x):
    """The single shared contract: aggregate-sum == A @ X."""
    return jnp.asarray(a_dense, dtype=jnp.float32) @ jnp.asarray(x, jnp.float32)


# ---------------------------------------------------------------------------
# Model-level oracles (pure jnp, no Pallas) used by python/tests/test_model.py
# ---------------------------------------------------------------------------


def gcn_forward_ref(params, a_hat, x):
    """2-layer GCN: logits = A_hat relu(A_hat (X W1) + b1) W2 + b2."""
    w1, b1, w2, b2 = params
    h = aggregate_ref(a_hat, x @ w1) + b1
    h = jnp.maximum(h, 0.0)
    return aggregate_ref(a_hat, h @ w2) + b2


def gin_forward_ref(params, a_plain, x):
    """2-layer GIN with 2-layer MLPs and a linear classifier."""
    (eps1, w1a, b1a, w1b, b1b, eps2, w2a, b2a, w2b, b2b, wc, bc) = params
    h = (1.0 + eps1) * x + aggregate_ref(a_plain, x)
    h = jnp.maximum(h @ w1a + b1a, 0.0) @ w1b + b1b
    h = jnp.maximum(h, 0.0)
    h = (1.0 + eps2) * h + aggregate_ref(a_plain, h)
    h = jnp.maximum(h @ w2a + b2a, 0.0) @ w2b + b2b
    h = jnp.maximum(h, 0.0)
    return h @ wc + bc


def masked_ce_ref(logits, labels, mask):
    """Mean masked softmax cross-entropy (matches model.masked_ce)."""
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0] - logz
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom
