"""Vertex-parallel CSR aggregation kernel for low-density inter-community
subgraphs.

Paper analogue (Fig. 6, left): a CTA covers a block of destination rows;
each row walks its CSR neighbor list serially, loading neighbor features
straight from global memory (their indices span the whole vertex range, so
no shared-memory tile can hold them).  The Pallas adaptation keeps the
*output* row block VMEM-resident (BlockSpec over rows) while neighbor rows
are gathered from the full feature array.

Operand contract (row_ptr exact, col/val tails padded with 0/0.0):
  row_ptr [V+1] i32, col_idx [E] i32, val [E] f32, x [V, F] f32 -> [V, F]
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  Matches the paper's community width so row blocks
# and communities stay aligned across kernels.
ROW_BLOCK = 16


def _make_kernel(row_block):
    def kernel(rp_ref, ci_ref, val_ref, x_ref, o_ref):
        blk = pl.program_id(0)
        f = o_ref.shape[1]

        def row_body(r, carry):
            row = blk * row_block + r
            start = rp_ref[row]
            end = rp_ref[row + 1]

            def nz(i, acc):
                c = ci_ref[i]
                # gather one neighbor feature row from "global memory"
                return acc + val_ref[i] * x_ref[c, :]

            acc = jax.lax.fori_loop(start, end, nz, jnp.zeros((f,), jnp.float32))
            o_ref[r, :] = acc
            return carry

        jax.lax.fori_loop(0, row_block, row_body, 0)

    return kernel


def csr_inter_aggregate(row_ptr, col_idx, val, x):
    """Aggregate-sum over a padded CSR triplet: returns ``A @ x``.

    The adjacency is required to be SYMMETRIC (GCN/GIN propagation
    matrices are); the backward pass reuses this kernel unchanged.
    """
    v, f = x.shape
    e = col_idx.shape[0]
    rb = min(ROW_BLOCK, v)
    if v % rb != 0:
        raise ValueError(f"padded vertex count {v} not a multiple of {rb}")
    return pl.pallas_call(
        _make_kernel(rb),
        grid=(v // rb,),
        in_specs=[
            pl.BlockSpec((v + 1,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((v, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, f), jnp.float32),
        interpret=True,
    )(row_ptr, col_idx, val, x)
