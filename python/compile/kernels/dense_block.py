"""Dense block-diagonal batched-GEMM aggregation kernel.

Paper analogue (Sec. 3.2 "Dense-based kernel"): store each community's
adjacency block densely and run a batched GEMM against the community's
feature tile — on the A100 this rides the Tensor Cores.  The TPU
re-expression is direct and *more* natural: each community block becomes
one MXU ``dot`` (the systolic array is exactly the "dense wins at high
density" engine), tiled by BlockSpec so a (C, C) adjacency tile and a
(C, F) feature tile are VMEM-resident per grid step.

Operand contract:
  blocks [nB, C, C] f32 (block-diagonal adjacency),
  x [V, F] f32 reshaped by the caller to [nB, C, F]   ->  y [nB, C, F]
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..buckets import COMMUNITY


# Communities fused per grid step: a (16,16) block underfills the 128x128
# MXU, so each step feeds a batch of community blocks through one systolic
# pass (DESIGN.md Sec. 7). Perf pass iteration 1: 1 -> 16 blocks/step.
BLOCK_BATCH = 16


def _dense_kernel(a_ref, x_ref, o_ref):
    # preferred_element_type pins the MXU accumulator to f32.
    o_ref[...] = jax.lax.dot_general(
        a_ref[...],
        x_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),  # batched matmul
        preferred_element_type=jnp.float32,
    )


def dense_block_aggregate(blocks, x, community=COMMUNITY):
    """Aggregate-sum over a dense block-diagonal adjacency.

    Accepts ``x`` as ``[V, F]`` and returns ``[V, F]``; internally runs a
    batch of community blocks through the MXU per grid step.
    """
    v, f = x.shape
    nb = blocks.shape[0]
    if blocks.shape[1:] != (community, community):
        raise ValueError(f"blocks must be [nB,{community},{community}], got {blocks.shape}")
    if v != nb * community:
        raise ValueError(f"x rows {v} != nB*C {nb * community}")
    bb = min(BLOCK_BATCH, nb)
    if nb % bb != 0:
        raise ValueError(f"block count {nb} not a multiple of batch {bb}")
    xb = x.reshape(nb, community, f)
    out = pl.pallas_call(
        _dense_kernel,
        grid=(nb // bb,),
        in_specs=[
            pl.BlockSpec((bb, community, community), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, community, f), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, community, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, community, f), jnp.float32),
        interpret=True,
    )(blocks, xb)
    return out.reshape(v, f)


def dense_block_aggregate_t(blocks, x, community=COMMUNITY):
    """Exact transpose ``A.T @ x`` via per-block transposition."""
    return dense_block_aggregate(jnp.swapaxes(blocks, 1, 2), x, community)
