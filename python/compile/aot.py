"""AOT driver: lower every kernel/model variant to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator then
loads ``artifacts/*.hlo.txt`` through PJRT and Python never appears on the
request path again.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts emitted per shape bucket:
  kernel_{kind}_{bucket}           one aggregation kernel in isolation
                                   (selector timing + kernel parity tests)
  fwd_{model}_{intra}_{inter}_{b}  forward pass -> logits (serving)
  train_{model}_{intra}_{inter}_{b} fused fwd+bwd+SGD step (training)

plus ``manifest.json`` describing every artifact's operand layout.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .aggregate import INTRA_NONE
from .buckets import BUCKETS, COMMUNITY, INTER_KERNELS, INTRA_KERNELS, MODELS
from .model import build_forward, build_kernel_only, build_train_step, param_shapes

F32, I32 = "f32", "i32"


def intra_operands(kind, bucket):
    """(name, shape, dtype) triples for an intra-subgraph operand set."""
    v, e, nb = bucket.vertices, bucket.edges, bucket.blocks
    if kind == "csr_intra":
        return [("intra_row_ptr", (v + 1,), I32),
                ("intra_col", (e,), I32),
                ("intra_val", (e,), F32)]
    if kind == "dense_block":
        return [("intra_blocks", (nb, COMMUNITY, COMMUNITY), F32)]
    if kind == INTRA_NONE:
        return []
    raise ValueError(kind)


def inter_operands(kind, bucket):
    """(name, shape, dtype) triples for an inter-subgraph operand set."""
    v, e = bucket.vertices, bucket.edges
    if kind == "csr_inter":
        return [("inter_row_ptr", (v + 1,), I32),
                ("inter_col", (e,), I32),
                ("inter_val", (e,), F32)]
    if kind == "coo":
        return [("inter_src", (e,), I32),
                ("inter_dst", (e,), I32),
                ("inter_val", (e,), F32)]
    raise ValueError(kind)


def kernel_operands(kind, bucket):
    """Operands for a kernel-only artifact (kind may be intra or inter)."""
    if kind in ("csr_intra", "dense_block"):
        return intra_operands(kind, bucket)
    return inter_operands(kind, bucket)


def param_operands(model, bucket):
    return [(n, s, F32) for n, s in param_shapes(model, bucket).items()]


def _avals(operands):
    dt = {F32: jax.numpy.float32, I32: jax.numpy.int32}
    return [jax.ShapeDtypeStruct(shape, dt[d]) for _, shape, d in operands]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, operands):
    return to_hlo_text(jax.jit(fn).lower(*_avals(operands)))


def _entry(name, kind, bucket, inputs, outputs, **extra):
    e = {
        "name": name,
        "path": f"{name}.hlo.txt",
        "kind": kind,
        "bucket": bucket.name,
        "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
        "outputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in outputs],
    }
    e.update(extra)
    return e


def build_all(out_dir, quick=False, verbose=True):
    """Lower every variant into ``out_dir``; returns the manifest dict."""
    buckets = BUCKETS[:1] if quick else BUCKETS
    entries = []
    t_start = time.time()

    def emit(name, fn, inputs, kind, bucket, outputs, **extra):
        t0 = time.time()
        text = lower_variant(fn, inputs)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        entries.append(_entry(name, kind, bucket, inputs, outputs, **extra))
        if verbose:
            print(f"  [{time.time()-t0:5.1f}s] {name} ({len(text)} chars)", flush=True)

    for bucket in buckets:
        v, f = bucket.vertices, bucket.features

        # --- kernel-only artifacts (selector timing + parity tests)
        for kind in INTRA_KERNELS + INTER_KERNELS:
            ops = kernel_operands(kind, bucket)
            fn = build_kernel_only(kind, len(ops))
            inputs = ops + [("x", (v, f), F32)]
            emit(f"kernel_{kind}_{bucket.name}", fn, inputs,
                 "kernel", bucket, [("y", (v, f), F32)], kernel=kind)

        # --- model variants
        for model in MODELS:
            params = param_operands(model, bucket)
            for intra in INTRA_KERNELS + (INTRA_NONE,):
                for inter in INTER_KERNELS:
                    iops = intra_operands(intra, bucket)
                    jops = inter_operands(inter, bucket)
                    common = params + iops + jops
                    tag = f"{model}_{intra}_{inter}_{bucket.name}"

                    fwd = build_forward(model, intra, inter,
                                        len(params), len(iops), len(jops))
                    emit(f"fwd_{tag}", fwd, common + [("x", (v, f), F32)],
                         "forward", bucket,
                         [("logits", (v, bucket.classes), F32)],
                         model=model, intra=intra, inter=inter)

                    step = build_train_step(model, intra, inter,
                                            len(params), len(iops), len(jops))
                    emit(f"train_{tag}", step,
                         common + [("x", (v, f), F32),
                                   ("labels", (v,), I32),
                                   ("mask", (v,), F32),
                                   ("lr", (), F32)],
                         "train_step", bucket,
                         [p for p in params] + [("loss", (), F32)],
                         model=model, intra=intra, inter=inter)

    manifest = {
        "version": 1,
        "community": COMMUNITY,
        "generated_by": "python/compile/aot.py",
        "buckets": {
            b.name: {
                "vertices": b.vertices, "edges": b.edges, "features": b.features,
                "hidden": b.hidden, "classes": b.classes, "blocks": b.blocks,
            }
            for b in buckets
        },
        "artifacts": entries,
    }
    if verbose:
        print(f"lowered {len(entries)} artifacts in {time.time()-t_start:.1f}s")
    return manifest


def source_digest():
    """Digest of the compile package — embedded in the manifest so `make`
    can skip rebuilds when nothing changed."""
    h = hashlib.sha256()
    pkg = os.path.dirname(os.path.abspath(__file__))
    for root, _dirs, files in os.walk(pkg):
        for name in sorted(files):
            if name.endswith(".py"):
                with open(os.path.join(root, name), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true",
                    help="smallest bucket only (CI smoke)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    digest = source_digest()
    stamp = os.path.join(args.out, "manifest.json")
    if os.path.exists(stamp):
        try:
            with open(stamp) as fh:
                if json.load(fh).get("source_digest") == digest:
                    print(f"artifacts up to date (digest {digest}); skipping")
                    return
        except (ValueError, OSError):
            pass

    manifest = build_all(args.out, quick=args.quick)
    manifest["source_digest"] = digest
    with open(stamp, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {stamp}")


if __name__ == "__main__":
    sys.exit(main())
