"""Layer-2 JAX models: GCN and GIN over adaptive subgraph kernels.

Mirrors the paper's benchmarks (Sec. 5): 2-layer GCN [Kipf & Welling] and
2-layer GIN [Xu et al.] with the default hidden sizes, where every
neighborhood aggregation routes through one of the Layer-1 Pallas kernels
chosen per subgraph (intra / inter).  ``build_train_step`` returns the
jitted fwd+bwd+SGD function that ``aot.py`` lowers to a single HLO module —
one artifact per (model, intra-kernel, inter-kernel, bucket) variant, so
the Rust selector can swap kernels by swapping executables with identical
operand layouts.

All functions take FLAT argument lists (no pytrees) so the HLO parameter
order is trivially documented in the artifact manifest.
"""

import jax
import jax.numpy as jnp

from .aggregate import INTRA_NONE, aggregate_combined

GCN_PARAM_NAMES = ("w1", "b1", "w2", "b2")
GIN_PARAM_NAMES = (
    "eps1", "w1a", "b1a", "w1b", "b1b",
    "eps2", "w2a", "b2a", "w2b", "b2b",
    "wc", "bc",
)


def param_names(model):
    return {"gcn": GCN_PARAM_NAMES, "gin": GIN_PARAM_NAMES}[model]


def param_shapes(model, bucket):
    """Shapes of each trainable parameter, in manifest order."""
    f, h, c = bucket.features, bucket.hidden, bucket.classes
    if model == "gcn":
        return {"w1": (f, h), "b1": (h,), "w2": (h, c), "b2": (c,)}
    if model == "gin":
        return {
            "eps1": (), "w1a": (f, h), "b1a": (h,), "w1b": (h, h), "b1b": (h,),
            "eps2": (), "w2a": (h, h), "b2a": (h,), "w2b": (h, h), "b2b": (h,),
            "wc": (h, c), "bc": (c,),
        }
    raise ValueError(f"unknown model {model!r}")


def init_params(model, bucket, seed=0):
    """Glorot-ish init, deterministic; mirrored by rust/src/coordinator."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_shapes(model, bucket).items():
        key, sub = jax.random.split(key)
        if not shape:  # eps scalars start at 0
            out.append(jnp.zeros((), jnp.float32))
        elif len(shape) == 1:
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan = shape[0] + shape[1]
            scale = jnp.sqrt(6.0 / fan)
            out.append(jax.random.uniform(sub, shape, jnp.float32, -scale, scale))
    return tuple(out)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def gcn_forward(params, intra_kind, inter_kind, intra_ops, inter_ops, x):
    """logits = A_hat relu(A_hat (X W1) + b1) W2 + b2 (transform-then-aggregate)."""
    w1, b1, w2, b2 = params
    agg = lambda t: aggregate_combined(intra_kind, inter_kind, intra_ops, inter_ops, t)
    h = agg(x @ w1) + b1
    h = jnp.maximum(h, 0.0)
    return agg(h @ w2) + b2


def gin_forward(params, intra_kind, inter_kind, intra_ops, inter_ops, x):
    """GIN-0 style: h <- MLP((1+eps) h + sum-aggregate(h)); linear classifier."""
    (eps1, w1a, b1a, w1b, b1b, eps2, w2a, b2a, w2b, b2b, wc, bc) = params
    agg = lambda t: aggregate_combined(intra_kind, inter_kind, intra_ops, inter_ops, t)
    h = (1.0 + eps1) * x + agg(x)
    h = jnp.maximum(h @ w1a + b1a, 0.0) @ w1b + b1b
    h = jnp.maximum(h, 0.0)
    h = (1.0 + eps2) * h + agg(h)
    h = jnp.maximum(h @ w2a + b2a, 0.0) @ w2b + b2b
    h = jnp.maximum(h, 0.0)
    return h @ wc + bc


_FORWARD = {"gcn": gcn_forward, "gin": gin_forward}


def masked_ce(logits, labels, mask):
    """Mean masked softmax cross-entropy; padding rows carry mask 0."""
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0] - logz
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom


# ---------------------------------------------------------------------------
# variant builders (lowered by aot.py)
# ---------------------------------------------------------------------------


def build_forward(model, intra_kind, inter_kind, n_params, n_intra_ops, n_inter_ops):
    """Flat-arg forward: (params..., intra_ops..., inter_ops..., x) -> logits."""
    fwd = _FORWARD[model]

    def f(*args):
        params = args[:n_params]
        intra_ops = args[n_params : n_params + n_intra_ops]
        inter_ops = args[n_params + n_intra_ops : n_params + n_intra_ops + n_inter_ops]
        x = args[-1]
        return (fwd(params, intra_kind, inter_kind, intra_ops, inter_ops, x),)

    return f


def build_train_step(model, intra_kind, inter_kind, n_params, n_intra_ops, n_inter_ops):
    """Flat-arg SGD step.

    args = (params..., intra_ops..., inter_ops..., x, labels, mask, lr)
    returns (updated params..., loss)
    """
    fwd = _FORWARD[model]

    def step(*args):
        params = args[:n_params]
        intra_ops = args[n_params : n_params + n_intra_ops]
        inter_ops = args[n_params + n_intra_ops : n_params + n_intra_ops + n_inter_ops]
        x, labels, mask, lr = args[-4:]

        def loss_fn(params):
            logits = fwd(params, intra_kind, inter_kind, intra_ops, inter_ops, x)
            return masked_ce(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = tuple(p - lr * g for p, g in zip(params, grads))
        return new_params + (loss,)

    return step


def build_kernel_only(kind, n_ops):
    """Flat-arg single-kernel aggregate: (ops..., x) -> y.  Used by the Rust
    adaptive selector to time each candidate kernel in isolation and by the
    kernel-parity integration tests."""
    from .aggregate import aggregate

    def f(*args):
        return (aggregate(kind, args[:n_ops], args[-1]),)

    return f
