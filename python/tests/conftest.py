"""Shared fixtures: random padded subgraph generators in every format.

These generators are the python twin of rust/src/graph — they produce the
same padded operand layouts the Rust coordinator packs, so a kernel that
passes here is guaranteed to agree with the runtime path.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest  # noqa: F401

COMMUNITY = 16


def random_symmetric_dense(rng, n, density, scale=1.0):
    """Symmetric matrix with ~density fraction of nonzeros."""
    m = rng.random((n, n)) < density
    m = np.triu(m)
    m = m | m.T
    vals = rng.standard_normal((n, n)).astype(np.float32) * scale
    vals = np.triu(vals) + np.triu(vals, 1).T
    return np.where(m, vals, 0.0).astype(np.float32)


def block_diagonal_mask(n, community=COMMUNITY):
    mask = np.zeros((n, n), dtype=bool)
    for b in range(n // community):
        lo, hi = b * community, (b + 1) * community
        mask[lo:hi, lo:hi] = True
    return mask


def split_intra_inter(a, community=COMMUNITY):
    """AdaptGear Sec. 3.3 decomposition: diagonal blocks vs remainder."""
    mask = block_diagonal_mask(a.shape[0], community)
    return np.where(mask, a, 0.0), np.where(mask, 0.0, a)


def to_csr(a, e_pad):
    """Padded CSR (row_ptr exact; col/val tails zero)."""
    n = a.shape[0]
    rp = np.zeros(n + 1, np.int32)
    cols, vals = [], []
    for r in range(n):
        nz = np.nonzero(a[r])[0]
        rp[r + 1] = rp[r] + len(nz)
        cols.extend(nz.tolist())
        vals.extend(a[r, nz].tolist())
    assert len(cols) <= e_pad, f"{len(cols)} edges exceed pad {e_pad}"
    ci = np.zeros(e_pad, np.int32)
    vv = np.zeros(e_pad, np.float32)
    ci[: len(cols)] = cols
    vv[: len(vals)] = vals
    return rp, ci, vv


def to_csr_intra(a_intra, e_pad, community=COMMUNITY):
    """Padded local-CSR for a block-diagonal matrix (cols local to block)."""
    n = a_intra.shape[0]
    rp = np.zeros(n + 1, np.int32)
    cols, vals = [], []
    for r in range(n):
        base = (r // community) * community
        nz = np.nonzero(a_intra[r])[0]
        assert all(base <= c < base + community for c in nz), "edge escapes block"
        rp[r + 1] = rp[r] + len(nz)
        cols.extend((nz - base).tolist())
        vals.extend(a_intra[r, nz].tolist())
    ci = np.zeros(e_pad, np.int32)
    vv = np.zeros(e_pad, np.float32)
    ci[: len(cols)] = cols
    vv[: len(vals)] = vals
    return rp, ci, vv


def to_coo(a, e_pad):
    """Padded COO (padding edges 0,0,0.0)."""
    dsts, srcs = np.nonzero(a)
    assert len(dsts) <= e_pad
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    val = np.zeros(e_pad, np.float32)
    src[: len(srcs)] = srcs
    dst[: len(dsts)] = dsts
    val[: len(dsts)] = a[dsts, srcs]
    return src, dst, val


def to_blocks(a_intra, community=COMMUNITY):
    """Dense [nB, C, C] blocks of a block-diagonal matrix."""
    n = a_intra.shape[0]
    nb = n // community
    blocks = np.zeros((nb, community, community), np.float32)
    for b in range(nb):
        lo, hi = b * community, (b + 1) * community
        blocks[b] = a_intra[lo:hi, lo:hi]
    return blocks


def pad_edges(n_edges, multiple=256):
    return max(multiple, ((n_edges + multiple - 1) // multiple) * multiple)
