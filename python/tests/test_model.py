"""L2 model correctness: Pallas-kernel models vs pure-jnp oracles,
gradient checks, and training-loss descent for every kernel combination."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import (
    COMMUNITY,
    pad_edges,
    random_symmetric_dense,
    split_intra_inter,
    to_blocks,
    to_coo,
    to_csr,
    to_csr_intra,
)
from compile.aggregate import INTRA_NONE, aggregate_combined
from compile.buckets import Bucket
from compile.kernels import ref
from compile.model import (
    build_forward,
    build_train_step,
    gcn_forward,
    gin_forward,
    init_params,
    masked_ce,
    param_shapes,
)

ATOL = 3e-4
N, F, H, CLS = 64, 8, 8, 4
BUCKET = Bucket(name="test", vertices=N, edges=256, features=F, hidden=H, classes=CLS)

COMBOS = [
    ("csr_intra", "csr_inter"),
    ("csr_intra", "coo"),
    ("dense_block", "csr_inter"),
    ("dense_block", "coo"),
    (INTRA_NONE, "csr_inter"),
    (INTRA_NONE, "coo"),
]


def make_graph(seed=0, density=0.12):
    """Symmetric weighted adjacency + every padded operand set."""
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, N, density)
    intra, inter = split_intra_inter(a)
    e = pad_edges(int(max((intra != 0).sum(), (inter != 0).sum())))
    ops = {
        "csr_intra": to_csr_intra(intra, e),
        "dense_block": (to_blocks(intra),),
        "csr_inter": to_csr(inter, e),
        "coo": to_coo(inter, e),
        # full graph packed as inter operands (intra='none' baselines)
        "full_csr_inter": to_csr(a, pad_edges(int((a != 0).sum()))),
        "full_coo": to_coo(a, pad_edges(int((a != 0).sum()))),
    }
    x = rng.standard_normal((N, F)).astype(np.float32)
    labels = rng.integers(0, CLS, N).astype(np.int32)
    mask = (rng.random(N) < 0.7).astype(np.float32)
    return a, ops, x, labels, mask


def pick_ops(ops, intra, inter):
    if intra == INTRA_NONE:
        return (), ops[f"full_{inter}"]
    return ops[intra], ops[inter]


@pytest.mark.parametrize("intra,inter", COMBOS)
def test_aggregate_combined_matches_dense(intra, inter):
    a, ops, x, _, _ = make_graph()
    iops, jops = pick_ops(ops, intra, inter)
    got = aggregate_combined(intra, inter, iops, jops, x)
    expect = ref.aggregate_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@pytest.mark.parametrize("intra,inter", COMBOS)
def test_gcn_forward_matches_ref(intra, inter):
    a, ops, x, _, _ = make_graph(seed=1)
    iops, jops = pick_ops(ops, intra, inter)
    params = init_params("gcn", BUCKET, seed=3)
    got = gcn_forward(params, intra, inter, iops, jops, x)
    expect = ref.gcn_forward_ref(params, a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@pytest.mark.parametrize("intra,inter", [("csr_intra", "coo"), (INTRA_NONE, "csr_inter")])
def test_gin_forward_matches_ref(intra, inter):
    a, ops, x, _, _ = make_graph(seed=2)
    iops, jops = pick_ops(ops, intra, inter)
    params = init_params("gin", BUCKET, seed=4)
    got = gin_forward(params, intra, inter, iops, jops, x)
    expect = ref.gin_forward_ref(params, a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


def test_masked_ce_matches_ref():
    rng = np.random.default_rng(5)
    logits = rng.standard_normal((N, CLS)).astype(np.float32)
    labels = rng.integers(0, CLS, N).astype(np.int32)
    mask = (rng.random(N) < 0.5).astype(np.float32)
    got = masked_ce(jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(mask))
    expect = ref.masked_ce_ref(jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(mask))
    np.testing.assert_allclose(float(got), float(expect), atol=1e-5)


def test_masked_ce_ignores_masked_rows():
    rng = np.random.default_rng(6)
    logits = rng.standard_normal((N, CLS)).astype(np.float32)
    labels = rng.integers(0, CLS, N).astype(np.int32)
    mask = np.zeros(N, np.float32)
    mask[:8] = 1.0
    base = float(masked_ce(jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(mask)))
    logits2 = logits.copy()
    logits2[8:] = 1e3  # garbage on masked rows must not change the loss
    perturbed = float(masked_ce(jnp.asarray(logits2), jnp.asarray(labels), jnp.asarray(mask)))
    assert abs(base - perturbed) < 1e-5


@pytest.mark.parametrize("intra,inter", COMBOS)
def test_gcn_grads_match_dense_reference(intra, inter):
    """custom_vjp backward (kernel re-application) vs autodiff through the
    dense oracle."""
    a, ops, x, labels, mask = make_graph(seed=7)
    iops, jops = pick_ops(ops, intra, inter)
    params = init_params("gcn", BUCKET, seed=8)

    def loss_pallas(params):
        logits = gcn_forward(params, intra, inter, iops, jops, x)
        return masked_ce(logits, labels, mask)

    def loss_ref(params):
        logits = ref.gcn_forward_ref(params, a, x)
        return ref.masked_ce_ref(logits, jnp.asarray(labels), jnp.asarray(mask))

    g1 = jax.grad(loss_pallas)(params)
    g2 = jax.grad(loss_ref)(params)
    for got, expect in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@pytest.mark.parametrize("model", ["gcn", "gin"])
def test_train_step_loss_decreases(model):
    a, ops, x, labels, mask = make_graph(seed=9, density=0.1)
    intra, inter = "csr_intra", "coo"
    iops, jops = pick_ops(ops, intra, inter)
    shapes = param_shapes(model, BUCKET)
    params = init_params(model, BUCKET, seed=10)
    step = build_train_step(model, intra, inter, len(shapes), len(iops), len(jops))
    step = jax.jit(step)

    lr = np.float32(0.05)
    losses = []
    for _ in range(12):
        out = step(*params, *iops, *jops, x, labels, mask, lr)
        params = out[:-1]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.9, f"no descent: {losses}"


def test_train_step_flat_arg_order_is_stable():
    """The manifest contract: flat args in (params, intra, inter, x, labels,
    mask, lr) order.  Shuffling operands must change the result."""
    _, ops, x, labels, mask = make_graph(seed=11)
    iops, jops = pick_ops(ops, "csr_intra", "coo")
    shapes = param_shapes("gcn", BUCKET)
    params = init_params("gcn", BUCKET, seed=12)
    step = build_train_step("gcn", "csr_intra", "coo", len(shapes), len(iops), len(jops))
    out = step(*params, *iops, *jops, x, labels, mask, np.float32(0.1))
    assert len(out) == len(shapes) + 1
    assert out[-1].shape == ()


def test_forward_wrapper_matches_direct_call():
    _, ops, x, _, _ = make_graph(seed=13)
    iops, jops = pick_ops(ops, "dense_block", "csr_inter")
    params = init_params("gcn", BUCKET, seed=14)
    f = build_forward("gcn", "dense_block", "csr_inter", len(params), len(iops), len(jops))
    got = f(*params, *iops, *jops, x)[0]
    expect = gcn_forward(params, "dense_block", "csr_inter", iops, jops, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-6)
