"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps graph sizes and densities for every kernel format and
asserts allclose against the dense reference (ref.py).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import (
    COMMUNITY,
    pad_edges,
    random_symmetric_dense,
    split_intra_inter,
    to_blocks,
    to_coo,
    to_csr,
    to_csr_intra,
)
from compile.kernels import (
    coo_aggregate,
    csr_inter_aggregate,
    csr_intra_aggregate,
    dense_block_aggregate,
    ref,
)
from compile.kernels.coo_scatter import coo_aggregate_t
from compile.kernels.dense_block import dense_block_aggregate_t

ATOL = 2e-4


def _features(rng, n, f):
    return rng.standard_normal((n, f)).astype(np.float32)


# -- deterministic smoke -----------------------------------------------------


def test_coo_identity():
    n = 32
    src = np.arange(n, dtype=np.int32)
    dst = np.arange(n, dtype=np.int32)
    val = np.ones(n, np.float32)
    x = np.eye(n, 8, dtype=np.float32)
    y = np.asarray(coo_aggregate(src, dst, val, x))
    np.testing.assert_allclose(y, x, atol=ATOL)


def test_csr_inter_empty_graph():
    n, e, f = 32, 256, 8
    rp = np.zeros(n + 1, np.int32)
    ci = np.zeros(e, np.int32)
    vv = np.zeros(e, np.float32)
    rng = np.random.default_rng(0)
    x = _features(rng, n, f)
    y = np.asarray(csr_inter_aggregate(rp, ci, vv, x))
    np.testing.assert_allclose(y, np.zeros_like(x), atol=ATOL)


def test_dense_block_zero_blocks():
    n, f = 32, 8
    nb = n // COMMUNITY
    blocks = np.zeros((nb, COMMUNITY, COMMUNITY), np.float32)
    rng = np.random.default_rng(0)
    x = _features(rng, n, f)
    y = np.asarray(dense_block_aggregate(blocks, x))
    np.testing.assert_allclose(y, np.zeros_like(x), atol=ATOL)


def test_coo_duplicate_edges_accumulate():
    """Duplicate (src,dst) pairs must sum — atomicAdd semantics."""
    n, f = 16, 4
    src = np.array([3, 3, 3, 0] + [0] * 12, np.int32)
    dst = np.array([5, 5, 5, 0] + [0] * 12, np.int32)
    val = np.array([1.0, 2.0, 3.0, 0.0] + [0.0] * 12, np.float32)
    rng = np.random.default_rng(1)
    x = _features(rng, n, f)
    y = np.asarray(coo_aggregate(src, dst, val, x))
    np.testing.assert_allclose(y[5], 6.0 * x[3], atol=ATOL)


# -- property sweeps ----------------------------------------------------------

sizes = st.sampled_from([16, 32, 64, 128])
feats = st.sampled_from([4, 8, 32])
densities = st.floats(min_value=0.0, max_value=0.4)


@settings(max_examples=20, deadline=None)
@given(n=sizes, f=feats, density=densities, seed=st.integers(0, 2**31 - 1))
def test_coo_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, n, density)
    e = pad_edges(int((a != 0).sum()))
    src, dst, val = to_coo(a, e)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(ref.dense_from_coo(src, dst, val, n), x)
    got = coo_aggregate(src, dst, val, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(n=sizes, f=feats, density=densities, seed=st.integers(0, 2**31 - 1))
def test_csr_inter_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, n, density)
    e = pad_edges(int((a != 0).sum()))
    rp, ci, vv = to_csr(a, e)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(ref.dense_from_csr(rp, ci, vv, n), x)
    got = csr_inter_aggregate(rp, ci, vv, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(n=sizes, f=feats, density=st.floats(0.0, 0.9), seed=st.integers(0, 2**31 - 1))
def test_csr_intra_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, n, density)
    intra, _ = split_intra_inter(a)
    e = pad_edges(int((intra != 0).sum()))
    rp, ci, vv = to_csr_intra(intra, e)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(
        ref.dense_from_csr_intra(rp, ci, vv, COMMUNITY), x
    )
    got = csr_intra_aggregate(rp, ci, vv, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(n=sizes, f=feats, density=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_dense_block_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, n, density)
    intra, _ = split_intra_inter(a)
    blocks = to_blocks(intra)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(ref.dense_from_blocks(blocks), x)
    got = dense_block_aggregate(blocks, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(n=sizes, f=feats, density=densities, seed=st.integers(0, 2**31 - 1))
def test_all_formats_agree_on_same_graph(n, f, density, seed):
    """The four kernels compute ONE contract: identical results on the
    same decomposed graph, summed across intra+inter partials."""
    rng = np.random.default_rng(seed)
    a = random_symmetric_dense(rng, n, density)
    intra, inter = split_intra_inter(a)
    e = pad_edges(int(max((intra != 0).sum(), (inter != 0).sum())))
    x = _features(rng, n, f)

    expect = ref.aggregate_ref(a, x)

    # combo 1: csr_intra + csr_inter
    rp_i, ci_i, vv_i = to_csr_intra(intra, e)
    rp_j, ci_j, vv_j = to_csr(inter, e)
    got1 = np.asarray(csr_intra_aggregate(rp_i, ci_i, vv_i, x)) + np.asarray(
        csr_inter_aggregate(rp_j, ci_j, vv_j, x)
    )
    np.testing.assert_allclose(got1, np.asarray(expect), atol=ATOL)

    # combo 2: dense_block + coo
    blocks = to_blocks(intra)
    src, dst, val = to_coo(inter, e)
    got2 = np.asarray(dense_block_aggregate(blocks, x)) + np.asarray(
        coo_aggregate(src, dst, val, x)
    )
    np.testing.assert_allclose(got2, np.asarray(expect), atol=ATOL)


# -- transpose variants -------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=sizes, f=feats, seed=st.integers(0, 2**31 - 1))
def test_coo_transpose_exact(n, f, seed):
    """coo_aggregate_t must equal A.T @ x even for ASYMMETRIC A."""
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < 0.2).astype(np.float32) * rng.standard_normal((n, n)).astype(np.float32)
    e = pad_edges(int((a != 0).sum()))
    src, dst, val = to_coo(a, e)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(ref.dense_from_coo(src, dst, val, n).T, x)
    got = coo_aggregate_t(src, dst, val, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


def test_dense_block_transpose_exact():
    rng = np.random.default_rng(7)
    n, f = 64, 8
    a = rng.standard_normal((n, n)).astype(np.float32)
    intra, _ = split_intra_inter(a)  # asymmetric blocks
    blocks = to_blocks(intra)
    x = _features(rng, n, f)
    expect = ref.aggregate_ref(ref.dense_from_blocks(blocks).T, x)
    got = dense_block_aggregate_t(blocks, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=ATOL)


# -- shape validation ---------------------------------------------------------


def test_coo_rejects_ragged_edge_block():
    with pytest.raises(ValueError):
        coo_aggregate(
            np.zeros(300, np.int32), np.zeros(300, np.int32),
            np.zeros(300, np.float32), np.zeros((16, 4), np.float32),
        )


def test_dense_block_rejects_bad_block_shape():
    with pytest.raises(ValueError):
        dense_block_aggregate(
            np.zeros((2, 8, 8), np.float32), np.zeros((32, 4), np.float32)
        )
