"""Aggregate-max / aggregate-mean vs numpy oracles."""

import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import pad_edges, random_symmetric_dense, to_csr
from compile.kernels.csr_inter import csr_inter_aggregate
from compile.kernels.reduce_ops import csr_max_aggregate, mean_weights

ATOL = 2e-4


def max_ref(a, x):
    n = a.shape[0]
    y = np.zeros((n, x.shape[1]), np.float32)
    for r in range(n):
        nz = np.nonzero(a[r])[0]
        if len(nz):
            y[r] = x[nz].max(axis=0)
    return y


def mean_ref(a, x):
    n = a.shape[0]
    y = np.zeros((n, x.shape[1]), np.float32)
    for r in range(n):
        nz = np.nonzero(a[r])[0]
        if len(nz):
            y[r] = x[nz].mean(axis=0)
    return y


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([4, 8]),
    density=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_max_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    a = (random_symmetric_dense(rng, n, density) != 0).astype(np.float32)
    e = pad_edges(int(a.sum()))
    rp, ci, _ = to_csr(a, e)
    x = rng.standard_normal((n, f)).astype(np.float32)
    got = np.asarray(csr_max_aggregate(rp, ci, x))
    np.testing.assert_allclose(got, max_ref(a, x), atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([4, 8]),
    density=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_mean_is_weighted_sum(n, f, density, seed):
    """mean == the SUM kernel fed 1/deg edge weights — no new kernel."""
    rng = np.random.default_rng(seed)
    a = (random_symmetric_dense(rng, n, density) != 0).astype(np.float32)
    e = pad_edges(int(a.sum()))
    rp, ci, _ = to_csr(a, e)
    w = mean_weights(rp, e)
    x = rng.standard_normal((n, f)).astype(np.float32)
    got = np.asarray(csr_inter_aggregate(rp, ci, w, x))
    np.testing.assert_allclose(got, mean_ref(a, x), atol=ATOL)


def test_max_empty_rows_are_zero():
    n, e, f = 32, 256, 4
    rp = np.zeros(n + 1, np.int32)
    ci = np.zeros(e, np.int32)
    x = np.full((n, f), -5.0, np.float32)
    got = np.asarray(csr_max_aggregate(rp, ci, x))
    np.testing.assert_allclose(got, np.zeros((n, f)), atol=0)


def test_max_handles_all_negative_features():
    # a real max kernel must return negatives (not clamp at 0) when
    # neighborhoods are non-empty
    n, f = 16, 4
    a = np.zeros((n, n), np.float32)
    a[0, 1] = 1.0
    e = pad_edges(1)
    rp, ci, _ = to_csr(a, e)
    x = np.full((n, f), -2.0, np.float32)
    got = np.asarray(csr_max_aggregate(rp, ci, x))
    np.testing.assert_allclose(got[0], [-2.0] * f, atol=ATOL)
