//! Serving example — a thin client of the `serve` subsystem.
//!
//! Deploys a briefly-trained model through the [`ModelRegistry`], then
//! drives the micro-batched single-owner event loop with the closed-loop
//! load generator and prints the SLO report: the "deployment" half of the
//! paper's motivation (real-time graph analysis, Sec. 1), now with
//! batched artifact executions instead of one PJRT call per request.
//!
//! ```text
//! cargo run --release --example serve_inference -- --requests 200
//! ```
//!
//! The `serve` subcommand (`cargo run --release -- serve ...`) exposes
//! the same loop with more knobs; this example shows the library API.

use std::time::Duration;

use adaptgear::coordinator::{ModelKind, Run};
use adaptgear::graph::datasets;
use adaptgear::runtime::Engine;
use adaptgear::serve::{loadgen, LoadGenConfig, ModelRegistry, ServeConfig, ServeSession};
use adaptgear::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let spec = datasets::find(args.get_or("dataset", "citeseer")).expect("unknown dataset");

    // -- deploy: plan (from the persistent plan cache when warm), train,
    //    and pre-warm the forward executable — one builder call
    let mut registry = ModelRegistry::new();
    let dep = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(args.get_usize("steps", 60))
        .deploy_as(&mut registry, "demo")?;
    println!(
        "model ready: {} on {} (final loss {:.3}, kernels {}, {} monitor iters{}, forward warmed in {:.2}s)",
        dep.model.as_str(),
        spec.name,
        dep.final_loss,
        dep.chosen(),
        dep.plan.monitor_iters,
        if dep.plan.provenance.cached { " [plan cache hit]" } else { "" },
        dep.warm_secs,
    );
    let (n, f_data) = (dep.n, dep.f_data);

    // -- serve: closed-loop clients perturb node features and ask for
    //    fresh logits; the session coalesces them into micro-batches
    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 8),
        max_wait: Duration::from_micros(args.get_u64("max-wait-us", 2000)),
        queue_depth: args.get_usize("queue-depth", 128),
    };
    let load = LoadGenConfig {
        requests: args.get_usize("requests", 200),
        clients: args.get_usize("clients", 16),
        ..Default::default()
    };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, "demo".to_string(), n, f_data, load);
    let report = session.run()?;
    let summary = gen.join();

    println!("\n{}", report.render());
    println!(
        "throughput {:.1} req/s ({:.1}k vertex-classifications/s) | clients: sent {} shed {}",
        report.throughput_rps,
        report.throughput_rps * n as f64 / 1e3,
        summary.sent,
        summary.shed,
    );
    Ok(())
}
