//! Serving example: train briefly, then serve node-classification
//! requests through the forward artifact, reporting latency percentiles
//! and throughput — the "deployment" half of the paper's motivation
//! (real-time graph analysis, Sec. 1).
//!
//! ```text
//! cargo run --release --example serve_inference -- --requests 200
//! ```

use std::time::Instant;

use adaptgear::coordinator::{pipeline, trainer, Clock, ModelKind, Strategy, TrainConfig};
use adaptgear::graph::datasets;
use adaptgear::runtime::Engine;
use adaptgear::util::cli::Args;
use adaptgear::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 200);
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let spec = datasets::find(args.get_or("dataset", "citeseer")).expect("unknown dataset");

    // -- train a model to serve
    let cfg = TrainConfig { model: ModelKind::Gcn, steps: 60, clock: Clock::Sim, ..Default::default() };
    let scale = pipeline::auto_scale(spec, &engine);
    let data = spec.build_scaled(scale, cfg.seed);
    let (d, _) = adaptgear::coordinator::preprocess(
        Strategy::AdaptGear,
        &data.graph,
        pipeline::propagation_for(cfg.model),
        engine.manifest.community,
        cfg.seed,
    );
    let f_data = engine.manifest.buckets.values().map(|b| b.features).max().unwrap();
    let x0 = data.features(f_data);
    let labels0 = data.labels();
    let n = d.graph.n;
    let mut x = vec![0.0f32; n * f_data];
    let mut labels = vec![0i32; n];
    for old in 0..n {
        let new = d.perm[old] as usize;
        x[new * f_data..(new + 1) * f_data].copy_from_slice(&x0[old * f_data..(old + 1) * f_data]);
        labels[new] = labels0[old];
    }
    let report = trainer::train(&engine, &d, &x, f_data, &labels, &cfg)?;
    println!(
        "model ready: {} on {} (loss {:.3} -> {:.3}, kernels {})",
        cfg.model.as_str(),
        spec.name,
        report.losses[0],
        report.final_loss(),
        report.chosen
    );

    // -- serve: each request perturbs a node's features and asks for
    //    fresh logits over the whole (static-topology) graph
    let mut rng = adaptgear::util::rng::Rng::new(99);
    let mut latencies_s = Vec::with_capacity(requests);
    // warm the forward executable (compile happens once)
    trainer::forward(&engine, &d, report.chosen, cfg.model, &report.params, &x, f_data)?;

    let serve_start = Instant::now();
    for _ in 0..requests {
        let v = rng.usize_below(n);
        let j = rng.usize_below(f_data);
        x[v * f_data + j] += rng.normal_f32() * 0.1;

        let t0 = Instant::now();
        let logits =
            trainer::forward(&engine, &d, report.chosen, cfg.model, &report.params, &x, f_data)?;
        latencies_s.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(&logits);
    }
    let total = serve_start.elapsed().as_secs_f64();

    let ms: Vec<f64> = latencies_s.iter().map(|s| s * 1e3).collect();
    println!("\nserved {requests} full-graph inference requests in {total:.2}s");
    println!(
        "latency  p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | max {:.2} ms",
        stats::percentile(&ms, 50.0),
        stats::percentile(&ms, 95.0),
        stats::percentile(&ms, 99.0),
        stats::max(&ms),
    );
    println!(
        "throughput {:.1} req/s ({:.1}k vertex-classifications/s)",
        requests as f64 / total,
        requests as f64 * n as f64 / total / 1e3,
    );
    Ok(())
}
