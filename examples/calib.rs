//! Cost-model calibration probe: prints the per-strategy iteration
//! breakdown (aggregate / update / overhead / launches) on representative
//! datasets — the tool used to calibrate gpusim against the paper's
//! reported ratios (EXPERIMENTS.md per-figure deltas).
use adaptgear::coordinator::*;
use adaptgear::graph::datasets::DATASETS;
use adaptgear::gpusim::A100;
use adaptgear::partition::{Propagation, Reorder};
fn main() {
    for name in ["pubmed", "artist", "Yeast"] {
        let spec = DATASETS.iter().find(|d| d.name == name).unwrap();
        let scale = (60_000.0 / spec.vertices as f64).min(1.0);
        let g = spec.build_scaled(scale, 42).graph;
        for model in [ModelKind::Gcn, ModelKind::Gin] {
            let prop = match model { ModelKind::Gcn => Propagation::GcnNormalized, _ => Propagation::PlainAdjacency };
            let dims = ModelDims::new(model, spec.features.min(512), 32, spec.classes.min(64));
            println!("\n== {name} {} n={} e={} ==", model.as_str(), g.n, g.directed_edge_count());
            for (label, strat, tile) in [
                ("DGL", Strategy::Dgl, 0usize), ("PyG", Strategy::Pyg, 0),
                ("GNNA", Strategy::GnnAdvisorMetis, 0), ("PCGCN", Strategy::Pcgcn, 16),
                ("O1", Strategy::AdaptGearO1, 0), ("O2", Strategy::AdaptGearO2, 0),
                ("OURS", Strategy::AdaptGear, 0),
            ] {
                let perm = strat.reorder().order(&g, 16, 42);
                let rg = g.relabel(&perm);
                let matrix = match prop { Propagation::GcnNormalized => adaptgear::graph::Csr::gcn_normalized(&rg), _ => adaptgear::graph::Csr::adjacency(&rg) };
                let (intra, inter) = matrix.split_block_diagonal(16);
                let d = adaptgear::partition::Decomposition { graph: rg, perm, intra, inter, community: 16 };
                let it = forward_cost(strat, &d, &dims, &A100, tile);
                println!("{label:<6} total {:>10.1}us  agg {:>10.1} upd {:>8.1} ovh {:>8.1} launches {:>5} (intra nnz {} inter {})",
                    it.total_us(), it.aggregate_us, it.update_us, it.overhead_us, it.kernel_launches, d.intra.nnz(), d.inter.nnz());
            }
        }
    }
}
