//! End-to-end training driver — the repository's headline validation run
//! (recorded in EXPERIMENTS.md §End-to-end).
//!
//! Trains 2-layer GCN **and** GIN on a synthesized cora for several
//! hundred steps through the full stack — Rust coordinator → adaptive
//! selector (wall clock over PJRT kernels) → AOT Pallas train-step
//! artifacts — logging the loss curve and final train accuracy.
//!
//! ```text
//! cargo run --release --example train_gcn [-- --dataset cora --steps 300]
//! ```

use adaptgear::coordinator::{pipeline, trainer, ModelKind, Strategy, TrainConfig};
use adaptgear::graph::datasets;
use adaptgear::partition::Decomposition;
use adaptgear::plan::{MonitorPlanner, PlanRequest, Planner};
use adaptgear::runtime::Engine;
use adaptgear::util::cli::Args;

fn accuracy(
    engine: &Engine,
    d: &Decomposition,
    report: &trainer::TrainReport,
    model: ModelKind,
    x: &[f32],
    f_data: usize,
    labels: &[i32],
    classes: usize,
) -> anyhow::Result<f64> {
    let logits = trainer::forward(engine, d, report.chosen(), model, &report.params, x, f_data)?;
    let n = d.graph.n;
    let width = logits.len() / engine.manifest.buckets[&report.bucket].vertices;
    let mut correct = 0usize;
    for v in 0..n {
        let row = &logits[v * width..v * width + classes.min(width)];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        if pred == labels[v].rem_euclid(classes as i32) {
            correct += 1;
        }
    }
    Ok(correct as f64 / n as f64)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dataset = args.get_or("dataset", "cora");
    let steps = args.get_usize("steps", 300);

    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let spec = datasets::find(dataset).expect("unknown dataset");

    for model in [ModelKind::Gcn, ModelKind::Gin] {
        println!("\n================ {} on {} ================", model.as_str().to_uppercase(), spec.name);
        let cfg = TrainConfig {
            model,
            steps,
            lr: args.get_f64("lr", 0.05) as f32,
            seed: args.get_u64("seed", 0),
        };

        // materialize + preprocess + fit a bucket (same staging path as
        // pipeline::Run, but keep the intermediates for the accuracy
        // computation)
        let staged = pipeline::stage(
            &engine.manifest,
            spec,
            model,
            Strategy::AdaptGear,
            None,
            cfg.seed,
        )?;
        println!(
            "scale {:.3}: {} vertices, {} edges | reorder {:.3}s decompose {:.3}s",
            staged.scale,
            staged.data.graph.n,
            staged.data.graph.directed_edge_count(),
            staged.times.reorder_secs,
            staged.times.decompose_secs
        );
        let (data, d) = (&staged.data, &staged.d);

        // features/labels permuted into the reordered id space
        let f_data = engine.manifest.buckets.values().map(|b| b.features).max().unwrap();
        let (x, labels) = adaptgear::coordinator::apply_perm(
            &d.perm,
            &data.features(f_data),
            &data.labels(),
            f_data,
        );

        // plan: wall-clock monitoring of the kernel candidates over PJRT
        let req = PlanRequest::labeled(
            d,
            model,
            &staged.bucket,
            spec.name,
            staged.scale,
            Strategy::AdaptGear.reorder(),
            cfg.seed,
        );
        let plan = MonitorPlanner::wall(&engine, 3).plan(&req)?;

        let t0 = std::time::Instant::now();
        let report = trainer::train(&engine, d, &x, f_data, &labels, &cfg, &plan)?;
        let wall = t0.elapsed().as_secs_f64();

        println!(
            "plan: {} (monitor {} iters, {:.1}us overhead) | bucket {}",
            report.chosen(),
            report.plan.monitor_iters,
            report.plan.monitor_overhead_us,
            report.bucket
        );
        let every = (report.losses.len() / 12).max(1);
        for (i, l) in report.losses.iter().enumerate() {
            if i % every == 0 || i + 1 == report.losses.len() {
                println!("  step {i:>5}  loss {l:.5}");
            }
        }
        let classes = engine.manifest.buckets[&report.bucket].classes;
        let acc = accuracy(&engine, d, &report, model, &x, f_data, &labels, classes)?;
        println!(
            "loss {:.4} -> {:.4} | train accuracy {:.1}% | {} steps in {:.1}s ({:.2} ms/step)",
            report.losses.first().unwrap(),
            report.final_loss(),
            acc * 100.0,
            report.losses.len(),
            wall,
            report.mean_step_secs() * 1e3,
        );
        assert!(
            report.final_loss() < report.losses[0] * 0.8,
            "training failed to descend"
        );
    }
    Ok(())
}
