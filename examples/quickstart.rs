//! Quickstart: the Fig. 7 user flow in ~30 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Loads a small dataset, decomposes it into intra/inter-community
//! subgraphs, lets the adaptive selector pick kernels, and trains a GCN
//! for a few steps through the AOT-compiled PJRT artifacts.

use adaptgear::coordinator::{pipeline, Clock, ModelKind, TrainConfig};
use adaptgear::graph::datasets;
use adaptgear::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Runtime over the AOT artifacts (`make artifacts` builds them).
    let engine = Engine::new("artifacts")?;

    // 2. Pick a dataset from the Table 1 registry.
    let spec = datasets::find("cora").expect("registry always has cora");

    // 3. Preprocess + adaptively select kernels + train, end to end.
    let cfg = TrainConfig {
        model: ModelKind::Gcn,
        steps: 40,
        clock: Clock::Wall, // time candidate kernels through PJRT
        ..Default::default()
    };
    let report = pipeline::run(&engine, spec, &cfg, None)?;

    println!(
        "trained {} ({} vertices) in bucket {}",
        report.dataset, report.vertices, report.train.bucket
    );
    println!(
        "selector chose {} (intra candidates: {:?} / inter: {:?})",
        report.train.chosen,
        report.train.selector.intra_times,
        report.train.selector.inter_times,
    );
    println!(
        "loss {:.4} -> {:.4} over {} steps ({:.2} ms/step)",
        report.train.losses.first().unwrap(),
        report.train.final_loss(),
        report.train.losses.len(),
        report.train.mean_step_secs() * 1e3,
    );
    Ok(())
}
