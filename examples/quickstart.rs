//! Quickstart: the Fig. 7 user flow in ~30 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Loads a small dataset, decomposes it into intra/inter-community
//! subgraphs, lets a planner pick kernels (wall-clock monitoring through
//! PJRT), and trains a GCN for a few steps through the AOT-compiled
//! artifacts — all through the one [`Run`] builder entrypoint.

use adaptgear::coordinator::{ModelKind, Run};
use adaptgear::graph::datasets;
use adaptgear::plan::MonitorPlanner;
use adaptgear::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Runtime over the AOT artifacts (`make artifacts` builds them).
    let engine = Engine::new("artifacts")?;

    // 2. Pick a dataset from the Table 1 registry.
    let spec = datasets::find("cora").expect("registry always has cora");

    // 3. Preprocess + plan kernels + train, end to end.
    let report = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(40)
        .planner(MonitorPlanner::wall(&engine, 3)) // time candidates through PJRT
        .train()?;

    println!(
        "trained {} ({} vertices) in bucket {}",
        report.dataset, report.vertices, report.train.bucket
    );
    let plan = &report.train.plan;
    println!(
        "planner chose {} after {} monitor iters (intra times: {:?} / inter: {:?})",
        plan.chosen, plan.monitor_iters, plan.intra_times, plan.inter_times,
    );
    println!(
        "loss {:.4} -> {:.4} over {} steps ({:.2} ms/step)",
        report.train.losses.first().unwrap(),
        report.train.final_loss(),
        report.train.losses.len(),
        report.train.mean_step_secs() * 1e3,
    );
    Ok(())
}
