//! Format explorer — the Sec. 2 motivation study as an interactive tool.
//!
//! Generates an RMAT graph at a chosen density, decomposes it, and prints
//! (a) simulated V100/A100 costs for every kernel candidate on each
//! subgraph and (b) REAL PJRT wall times of the Pallas kernel artifacts,
//! so you can watch the adaptive choice flip as density moves.
//!
//! ```text
//! cargo run --release --example format_explorer -- --vertices 512 --avg-degree 8
//! ```

use adaptgear::graph::generate::rmat;
use adaptgear::gpusim::{kernel_cost, A100, V100};
use adaptgear::kernels::pack;
use adaptgear::kernels::{KernelKind, INTER_CANDIDATES, INTRA_CANDIDATES};
use adaptgear::partition::{Decomposition, Propagation, Reorder};
use adaptgear::runtime::{Engine, Manifest};
use adaptgear::util::cli::Args;
use adaptgear::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("vertices", 512);
    let avg_degree = args.get_f64("avg-degree", 8.0);
    let seed = args.get_u64("seed", 1);

    let mut rng = Rng::new(seed);
    let g = rmat(n, (n as f64 * avg_degree / 2.0) as usize, &mut rng);
    println!(
        "RMAT: {} vertices, {} directed edges, density {:.2e}",
        g.n,
        g.directed_edge_count(),
        g.density()
    );

    let d = Decomposition::build(&g, Reorder::Metis, Propagation::GcnNormalized, 16, seed);
    println!(
        "decomposed: intra nnz {} / inter nnz {}",
        d.intra.nnz(),
        d.inter.nnz()
    );

    // -- simulated costs on both GPUs
    let f = 32;
    println!("\nsimulated aggregate cost (f={f}):");
    println!("{:<10} {:<14} {:>12} {:>12}", "subgraph", "kernel", "V100 (us)", "A100 (us)");
    for kind in INTRA_CANDIDATES {
        let v = kernel_cost(kind, &d.intra, f, 16, &V100).time_us;
        let a = kernel_cost(kind, &d.intra, f, 16, &A100).time_us;
        println!("{:<10} {:<14} {v:>12.2} {a:>12.2}", "intra", kind.as_str());
    }
    for kind in INTER_CANDIDATES {
        let v = kernel_cost(kind, &d.inter, f, 16, &V100).time_us;
        let a = kernel_cost(kind, &d.inter, f, 16, &A100).time_us;
        println!("{:<10} {:<14} {v:>12.2} {a:>12.2}", "inter", kind.as_str());
    }
    let whole = d.whole();
    for kind in [KernelKind::CsrInter, KernelKind::Coo, KernelKind::DenseFull] {
        let v = kernel_cost(kind, &whole, f, 16, &V100).time_us;
        let a = kernel_cost(kind, &whole, f, 16, &A100).time_us;
        println!("{:<10} {:<14} {v:>12.2} {a:>12.2}", "full", kind.as_str());
    }

    // -- real PJRT wall times of the Pallas artifacts
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let Some(bucket) = engine.manifest.fit_bucket(n, d.intra.nnz().max(d.inter.nnz())) else {
        println!("\n(no AOT bucket fits this size; shrink --vertices for the PJRT half)");
        return Ok(());
    };
    let bucket = bucket.clone();
    let x: Vec<f32> = (0..n * bucket.features).map(|_| rng.normal_f32()).collect();
    let xp = pack::pack_features(&x, n, bucket.features, &bucket)?;

    println!("\nreal PJRT (CPU) wall time per launch, bucket {}:", bucket.name);
    for (role, kinds, matrix) in [
        ("intra", &INTRA_CANDIDATES[..], &d.intra),
        ("inter", &INTER_CANDIDATES[..], &d.inter),
    ] {
        for &kind in kinds {
            let name = Manifest::kernel_name(kind.as_str(), &bucket.name);
            let mut ops = pack::pack_kernel_operands(kind, matrix, 16, &bucket)?;
            ops.push(xp.clone());
            engine.run(&name, &ops)?; // warm (compile)
            let t0 = std::time::Instant::now();
            let reps = 10;
            for _ in 0..reps {
                engine.run(&name, &ops)?;
            }
            println!(
                "{role:<10} {:<14} {:>12.1} us",
                kind.as_str(),
                t0.elapsed().as_secs_f64() * 1e6 / reps as f64
            );
        }
    }
    println!("\n(PJRT CPU wall time validates numerics + relative kernel structure;\n GPU time comes from the gpusim columns above — see DESIGN.md Sec. 2)");
    Ok(())
}
