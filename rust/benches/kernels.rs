//! Micro-benchmarks of the native kernel schedules and the gpusim cost
//! evaluation itself (the L3 hot paths the perf pass optimizes —
//! EXPERIMENTS.md §Perf).
//!
//! ```text
//! cargo bench --bench kernels
//! ```

use adaptgear::graph::generate::planted_partition;
use adaptgear::graph::{Csr, DenseBlocks};
use adaptgear::gpusim::{kernel_cost, A100};
use adaptgear::kernels::native;
use adaptgear::kernels::KernelKind;
use adaptgear::partition::{Decomposition, Propagation, Reorder};
use adaptgear::util::bench::Bench;
use adaptgear::util::rng::Rng;

fn main() {
    let bench = Bench::default();
    let mut rng = Rng::new(7);

    for &(n, p_intra, p_inter, f) in
        &[(4096usize, 0.4f64, 0.005f64, 32usize), (16384, 0.3, 0.001, 64)]
    {
        let g = planted_partition(n, 16, p_intra, p_inter, &mut rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0);
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        let blocks = DenseBlocks::from_block_diagonal_csr(&d.intra, 16);
        let inter_trips = d.inter.to_triplets();
        println!(
            "\n-- n={n} f={f} intra_nnz={} inter_nnz={} --",
            d.intra.nnz(),
            d.inter.nnz()
        );

        bench.bench(&format!("native/csr_inter/n{n}/f{f}"), || {
            std::hint::black_box(native::csr_inter_spmm(&d.inter, &x, f));
        });
        bench.bench(&format!("native/csr_intra/n{n}/f{f}"), || {
            std::hint::black_box(native::csr_intra_spmm(&d.intra, &x, f, 16));
        });
        bench.bench(&format!("native/coo/n{n}/f{f}"), || {
            std::hint::black_box(native::coo_spmm(n, &inter_trips, &x, f));
        });
        bench.bench(&format!("native/dense_block/n{n}/f{f}"), || {
            std::hint::black_box(native::dense_block_spmm(&blocks, &x, f));
        });
        bench.bench(&format!("native/reference_spmm/n{n}/f{f}"), || {
            std::hint::black_box(d.inter.spmm(&x, f));
        });

        // the cost-model evaluation itself is on the selector's hot path
        bench.bench(&format!("gpusim/kernel_cost_csr/n{n}/f{f}"), || {
            std::hint::black_box(kernel_cost(KernelKind::CsrInter, &d.inter, f, 16, &A100));
        });
        bench.bench(&format!("gpusim/kernel_cost_dense/n{n}/f{f}"), || {
            std::hint::black_box(kernel_cost(KernelKind::DenseBlock, &d.intra, f, 16, &A100));
        });
    }

    // graph-construction substrate costs
    let mut rng = Rng::new(9);
    let g = planted_partition(32768, 16, 0.3, 0.0005, &mut rng);
    bench.bench("graph/gcn_normalized/n32768", || {
        std::hint::black_box(Csr::gcn_normalized(&g));
    });
    let a = Csr::gcn_normalized(&g);
    bench.bench("graph/split_block_diagonal/n32768", || {
        std::hint::black_box(a.split_block_diagonal(16));
    });
    bench.bench("graph/transpose/n32768", || {
        std::hint::black_box(a.transpose());
    });
}
