//! Kernel microbenches — thin wrapper over `adaptgear::bench::kernels`
//! (per-kernel spmm/pack across density classes + gpusim calibration),
//! emitting `BENCH_kernels.json` through the shared report writer.
//!
//! ```text
//! cargo bench --bench kernels [-- --quick] [-- --out DIR]
//! ```

use adaptgear::bench::{kernels, BenchConfig};
use adaptgear::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = BenchConfig {
        quick: args.flag("quick"),
        out: args.get_or("out", ".").into(),
        ..Default::default()
    };
    let report = kernels::run(&cfg)?;
    let path = report.write_at(&cfg.out)?;
    println!("wrote {}", path.display());
    Ok(())
}
