//! Partitioner benchmarks: speed and ordering quality of the METIS-like
//! multilevel partitioner vs the rabbit-like modularity orderer — the
//! preprocessing half of the Sec. 6.3 overhead study.
//!
//! ```text
//! cargo bench --bench partition
//! ```

use adaptgear::graph::generate::planted_partition;
use adaptgear::graph::stats;
use adaptgear::partition::{metis_order, quality, rabbit_order};
use adaptgear::util::bench::Bench;
use adaptgear::util::rng::Rng;

fn main() {
    let bench = Bench::quick();

    for &n in &[4096usize, 16384, 65536] {
        let mut rng = Rng::new(3);
        let g = planted_partition(n, 16, 0.45, 2.0 / n as f64, &mut rng);
        let mut shuffle: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut shuffle);
        let hidden = g.relabel(&shuffle);
        println!("\n-- n={n} edges={} --", hidden.directed_edge_count());

        bench.bench(&format!("metis_order/n{n}"), || {
            std::hint::black_box(metis_order(&hidden, 16, 1));
        });
        bench.bench(&format!("rabbit_order/n{n}"), || {
            std::hint::black_box(rabbit_order(&hidden, 16));
        });

        // ordering quality: fraction of edges captured inside communities
        for (name, perm) in [
            ("metis", metis_order(&hidden, 16, 1)),
            ("rabbit", rabbit_order(&hidden, 16)),
        ] {
            let reordered = hidden.relabel(&perm);
            let split = stats::density_split(&reordered, 16);
            let parts = quality::parts_from_order(&perm, 16);
            println!(
                "   quality/{name:<7} intra_frac={:.3} modularity={:.3} cut={}",
                split.intra_edges as f64 / hidden.edge_count().max(1) as f64,
                quality::modularity(&hidden, &parts),
                quality::edge_cut(&hidden, &parts),
            );
        }
    }
}
