//! Partitioner benchmarks — thin wrapper over `adaptgear::bench::plan`,
//! whose suite absorbed the metis-vs-rabbit speed/quality study (the
//! preprocessing half of the Sec. 6.3 overhead analysis) alongside the
//! planner sweep and PlanStore latencies. Emits `BENCH_plan.json`
//! through the shared report writer.
//!
//! ```text
//! cargo bench --bench partition [-- --quick] [-- --out DIR]
//! ```

use adaptgear::bench::{plan, BenchConfig};
use adaptgear::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = BenchConfig {
        quick: args.flag("quick"),
        out: args.get_or("out", ".").into(),
        ..Default::default()
    };
    let report = plan::run(&cfg)?;
    let path = report.write_at(&cfg.out)?;
    println!("wrote {}", path.display());
    Ok(())
}
