//! Figure/table regeneration harness — one section per evaluation artifact
//! in the paper (DESIGN.md Sec. 4 maps each to its modules).
//!
//! ```text
//! cargo bench --bench figures -- all            # everything
//! cargo bench --bench figures -- fig8 fig11     # a subset
//! ADAPTGEAR_FULL_SCALE=1 cargo bench ...        # no vertex cap (slow)
//! ```
//!
//! Times are gpusim estimates (DESIGN.md Sec. 2: no GPU exists here); the
//! reproduction target is the *shape* — who wins, by what factor, where
//! the crossovers fall.

use std::collections::HashMap;

use adaptgear::bench::{BenchReport, Direction};
use adaptgear::coordinator::{forward_cost, preprocess, ModelDims, ModelKind, Strategy};
use adaptgear::graph::datasets::{DatasetSpec, DATASETS};
use adaptgear::graph::generate::rmat;
use adaptgear::graph::{stats, Csr, Graph};
use adaptgear::gpusim::{kernel_cost, GpuModel, IterationCost, A100, V100};
use adaptgear::kernels::KernelKind;
use adaptgear::partition::{Decomposition, Propagation, Reorder};
use adaptgear::util::rng::Rng;
use adaptgear::util::stats::geomean;

const COMMUNITY: usize = 16;

/// Default vertex cap so the full figure sweep finishes in minutes on one
/// core; ADAPTGEAR_FULL_SCALE=1 removes it (see EXPERIMENTS.md).
fn vertex_cap() -> usize {
    if std::env::var("ADAPTGEAR_FULL_SCALE").is_ok() {
        usize::MAX
    } else {
        60_000
    }
}

fn scale_for(spec: &DatasetSpec) -> f64 {
    (vertex_cap() as f64 / spec.vertices as f64).min(1.0)
}

/// Dataset -> (reorder -> decomposition) cache shared across figures.
struct Prep {
    graphs: HashMap<&'static str, Graph>,
    decomps: HashMap<(&'static str, &'static str, u8), Decomposition>,
}

impl Prep {
    fn new() -> Prep {
        Prep { graphs: HashMap::new(), decomps: HashMap::new() }
    }

    fn graph(&mut self, spec: &DatasetSpec) -> &Graph {
        self.graphs.entry(spec.name).or_insert_with(|| {
            let scale = scale_for(spec);
            spec.build_scaled(scale, 42).graph
        })
    }

    fn decomp(
        &mut self,
        spec: &DatasetSpec,
        reorder: Reorder,
        propagation: Propagation,
    ) -> &Decomposition {
        let rkey = match reorder {
            Reorder::Metis => "metis",
            Reorder::Rabbit => "rabbit",
            Reorder::Identity => "identity",
        };
        let pkey = match propagation {
            Propagation::GcnNormalized => 0u8,
            Propagation::PlainAdjacency => 1u8,
        };
        if !self.decomps.contains_key(&(spec.name, rkey, pkey)) {
            let g = self.graph(spec).clone();
            let perm = reorder.order(&g, COMMUNITY, 42);
            let graph = g.relabel(&perm);
            let matrix = match propagation {
                Propagation::GcnNormalized => Csr::gcn_normalized(&graph),
                Propagation::PlainAdjacency => Csr::adjacency(&graph),
            };
            let (intra, inter) = matrix.split_block_diagonal(COMMUNITY);
            self.decomps.insert(
                (spec.name, rkey, pkey),
                Decomposition { graph, perm, intra, inter, community: COMMUNITY },
            );
        }
        &self.decomps[&(spec.name, rkey, pkey)]
    }
}

fn dims_for(spec: &DatasetSpec, kind: ModelKind) -> ModelDims {
    // hidden 32 (paper default config); classes per dataset; features
    // capped so reduced-scale update GEMMs stay comparable
    ModelDims::new(kind, spec.features.min(512), 32, spec.classes.min(64))
}

/// Simulated per-iteration training time for a strategy (fwd+bwd ~= 2.6x
/// forward, the standard fwd/bwd flop ratio).
fn training_iter(
    strategy: Strategy,
    prep: &mut Prep,
    spec: &DatasetSpec,
    model: ModelKind,
    gpu: &GpuModel,
    tile: usize,
) -> IterationCost {
    let prop = match model {
        ModelKind::Gcn => Propagation::GcnNormalized,
        ModelKind::Gin => Propagation::PlainAdjacency,
    };
    let d = prep.decomp(spec, strategy.reorder(), prop);
    forward_cost(strategy, d, &dims_for(spec, model), gpu, tile).scaled(2.6)
}

// ---------------------------------------------------------------------------
// Fig. 2b — graph format performance vs density (RMAT, pubmed-sized)
// ---------------------------------------------------------------------------
fn fig2b() {
    println!("\n=== Fig 2b: aggregate-sum time vs density, RMAT n=19717, A100, f=32 ===");
    println!("{:>10} {:>12} {:>12} {:>12} {:>8}", "density", "Dense(us)", "CSR(us)", "COO(us)", "winner");
    let n = 19717usize;
    let f = 32;
    let mut rng = Rng::new(2);
    let print_row = |density: f64, dense: f64, csr: f64, coo: f64, tag: &str| {
        let winner = if dense <= csr && dense <= coo {
            "Dense"
        } else if csr <= coo {
            "CSR"
        } else {
            "COO"
        };
        println!("{density:>10.2e} {dense:>12.1} {csr:>12.1} {coo:>12.1} {winner:>8}{tag}");
    };
    for &edge_factor in &[1usize, 4, 16, 64, 256, 1024] {
        let m = n * edge_factor / 2;
        let g = rmat(n, m, &mut rng);
        let a = Csr::adjacency(&g);
        let density = a.nnz() as f64 / (n as f64 * n as f64);
        let dense = kernel_cost(KernelKind::DenseFull, &a, f, COMMUNITY, &A100).time_us;
        let csr = kernel_cost(KernelKind::CsrInter, &a, f, COMMUNITY, &A100).time_us;
        let coo = kernel_cost(KernelKind::Coo, &a, f, COMMUNITY, &A100).time_us;
        print_row(density, dense, csr, coo, "");
    }
    // High-density points: the 100M+-edge CSR does not fit memory, so use
    // the closed-form costs (the 19717-row feature matrix fully fits L2).
    use adaptgear::gpusim::kernel_cost::{coo_cost_analytic, csr_cost_analytic, dense_full_cost};
    for density in [0.1f64, 0.25, 0.5] {
        let nnz = (density * n as f64 * n as f64) as usize;
        let dense = dense_full_cost(n, f, &A100).time_us;
        let csr = csr_cost_analytic(n, nnz, f, 1.0, &A100).time_us;
        let coo = coo_cost_analytic(nnz, f, 1.0, &A100).time_us;
        print_row(density, dense, csr, coo, " (analytic)");
    }
    println!("paper shape: Dense wins at high density, CSR mid, COO at extreme sparsity");
}

// ---------------------------------------------------------------------------
// Fig. 3a — community reordering clusters the adjacency matrix
// ---------------------------------------------------------------------------
fn fig3a(prep: &mut Prep) {
    println!("\n=== Fig 3a: citeseer adjacency before/after community reordering ===");
    let spec = DATASETS.iter().find(|d| d.name == "citeseer").unwrap();
    let g = prep.graph(spec).clone();
    println!("before (random order):");
    print!("{}", stats::render_heat_grid(&stats::adjacency_heat_grid(&g, 20)));
    let d = prep.decomp(spec, Reorder::Metis, Propagation::GcnNormalized);
    println!("after (metis-like order, diagonal = intra-community):");
    print!("{}", stats::render_heat_grid(&stats::adjacency_heat_grid(&d.graph, 20)));
    let before = stats::density_split(&g, COMMUNITY);
    let after = stats::density_split(&d.graph, COMMUNITY);
    println!(
        "intra edges {} -> {}  intra density {:.2e} -> {:.2e}",
        before.intra_edges, after.intra_edges, before.intra, after.intra
    );
}

// ---------------------------------------------------------------------------
// Fig. 3b — GNNAdvisor vs PCGCN: execution time AND L2 hit rate
// ---------------------------------------------------------------------------
fn fig3b(prep: &mut Prep) {
    // The paper profiles the GCN *first-layer aggregate* at the dataset's
    // raw feature width with nsight; we do the same against the L2 model.
    println!("\n=== Fig 3b: GCN layer-1 aggregate time + L2 hit rate, A100 ===");
    println!("{:<10} {:<12} {:>12} {:>10}", "dataset", "system", "time(us)", "L2 hit");
    use adaptgear::coordinator::strategy::{gnnadvisor_aggregate_cost, pcgcn_aggregate_cost};
    for name in ["citeseer", "pubmed"] {
        let spec = DATASETS.iter().find(|d| d.name == name).unwrap();
        let width = spec.features; // raw first-layer width (500 / 3703)
        let d = prep.decomp(spec, Reorder::Metis, Propagation::GcnNormalized);
        let gnna = gnnadvisor_aggregate_cost(d, width, &A100);
        // PCGCN at its best tile size (generous to the baseline)
        let pcgcn = [64usize, 256, 512]
            .iter()
            .map(|&t| pcgcn_aggregate_cost(d, width, t, &A100))
            .min_by(|a, b| a.total_us().partial_cmp(&b.total_us()).unwrap())
            .unwrap();
        for (label, it) in [("GNNAdvisor", &gnna), ("PCGCN", &pcgcn)] {
            println!(
                "{name:<10} {label:<12} {:>12.1} {:>9.1}%",
                it.total_us(),
                it.l2_hit_rate() * 100.0
            );
        }
    }
    println!("paper shape: PCGCN higher hit rate but longer time (merge + tile overhead)");
}

// ---------------------------------------------------------------------------
// Fig. 4 — full/intra/inter density per dataset after reordering
// ---------------------------------------------------------------------------
fn fig4(prep: &mut Prep) {
    println!("\n=== Fig 4: average density of full/intra/inter subgraphs (community=16) ===");
    println!("{:<28} {:>11} {:>11} {:>11} {:>10}", "dataset", "full", "intra", "inter", "intra/inter");
    for spec in DATASETS {
        let d = prep.decomp(spec, Reorder::Metis, Propagation::GcnNormalized);
        let s = stats::density_split(&d.graph, COMMUNITY);
        println!(
            "{:<28} {:>11.2e} {:>11.2e} {:>11.2e} {:>9.0}x",
            spec.name,
            s.full,
            s.intra,
            s.inter,
            if s.inter > 0.0 { s.intra / s.inter } else { f64::INFINITY }
        );
    }
    println!("paper shape: intra density orders of magnitude above inter, varying per dataset");
}

// ---------------------------------------------------------------------------
// Fig. 8 — end-to-end normalized training time vs DGL/PyG (2 GPUs, 2 models)
// ---------------------------------------------------------------------------
fn fig8(prep: &mut Prep, report: &mut BenchReport) {
    println!("\n=== Fig 8: speedup over frameworks (higher = better, AdaptGear = baseline 1.0) ===");
    let mut all_dgl = Vec::new();
    let mut all_pyg = Vec::new();
    let mut gcn_speedups = Vec::new();
    let mut gin_speedups = Vec::new();
    for gpu in [&V100, &A100] {
        for model in [ModelKind::Gcn, ModelKind::Gin] {
            println!("\n--- {} / {} ---", gpu.name, model.as_str().to_uppercase());
            println!("{:<28} {:>8} {:>8}", "dataset", "vs DGL", "vs PyG");
            for spec in DATASETS {
                let ours = training_iter(Strategy::AdaptGear, prep, spec, model, gpu, 0).total_us();
                let dgl = training_iter(Strategy::Dgl, prep, spec, model, gpu, 0).total_us();
                let pyg = training_iter(Strategy::Pyg, prep, spec, model, gpu, 0).total_us();
                all_dgl.push(dgl / ours);
                all_pyg.push(pyg / ours);
                match model {
                    ModelKind::Gcn => gcn_speedups.extend([dgl / ours, pyg / ours]),
                    ModelKind::Gin => gin_speedups.extend([dgl / ours, pyg / ours]),
                }
                println!("{:<28} {:>7.2}x {:>7.2}x", spec.name, dgl / ours, pyg / ours);
            }
        }
    }
    println!(
        "\ngeomean speedup: vs DGL {:.2}x (paper 1.83x), vs PyG {:.2}x (paper 2.16x)",
        geomean(&all_dgl),
        geomean(&all_pyg)
    );
    println!(
        "geomean by model: GCN {:.2}x (paper 1.69x), GIN {:.2}x (paper 2.33x)",
        geomean(&gcn_speedups),
        geomean(&gin_speedups)
    );
    report.push("fig8/geomean_vs_dgl", geomean(&all_dgl), "x", Direction::Higher);
    report.push("fig8/geomean_vs_pyg", geomean(&all_pyg), "x", Direction::Higher);
    report.push("fig8/geomean_gcn", geomean(&gcn_speedups), "x", Direction::Higher);
    report.push("fig8/geomean_gin", geomean(&gin_speedups), "x", Direction::Higher);
}

// ---------------------------------------------------------------------------
// Fig. 9 — vs GNNAdvisor (rabbit + metis preprocessing), A100
// ---------------------------------------------------------------------------
fn fig9(prep: &mut Prep, report: &mut BenchReport) {
    println!("\n=== Fig 9: speedup over GNNAdvisor on A100 (GCN + GIN) ===");
    let mut rabbit = Vec::new();
    let mut metis = Vec::new();
    for model in [ModelKind::Gcn, ModelKind::Gin] {
        println!("\n--- {} ---", model.as_str().to_uppercase());
        println!("{:<28} {:>14} {:>14}", "dataset", "vs GNNA-Rabbit", "vs GNNA-Metis");
        for spec in DATASETS {
            let ours = training_iter(Strategy::AdaptGear, prep, spec, model, &A100, 0).total_us();
            let r = training_iter(Strategy::GnnAdvisorRabbit, prep, spec, model, &A100, 0).total_us();
            let m = training_iter(Strategy::GnnAdvisorMetis, prep, spec, model, &A100, 0).total_us();
            rabbit.push(r / ours);
            metis.push(m / ours);
            println!("{:<28} {:>13.2}x {:>13.2}x", spec.name, r / ours, m / ours);
        }
    }
    println!(
        "\ngeomean: vs GNNA-Rabbit {:.2}x (paper 1.40x), vs GNNA-Metis {:.2}x (paper 1.41x)",
        geomean(&rabbit),
        geomean(&metis)
    );
    report.push("fig9/geomean_vs_gnna_rabbit", geomean(&rabbit), "x", Direction::Higher);
    report.push("fig9/geomean_vs_gnna_metis", geomean(&metis), "x", Direction::Higher);
}

// ---------------------------------------------------------------------------
// Fig. 10 — vs PCGCN with its tile size swept 2..1024, GCN, A100
// ---------------------------------------------------------------------------
fn fig10(prep: &mut Prep, report: &mut BenchReport) {
    println!("\n=== Fig 10: speedup over best-tile PCGCN (GCN, A100) ===");
    println!("{:<28} {:>10} {:>12}", "dataset", "best tile", "speedup");
    let mut speedups = Vec::new();
    for spec in DATASETS {
        let ours = training_iter(Strategy::AdaptGear, prep, spec, ModelKind::Gcn, &A100, 0).total_us();
        let mut best = f64::INFINITY;
        let mut best_tile = 0usize;
        let mut tile = 2usize;
        while tile <= 1024 {
            let t = training_iter(Strategy::Pcgcn, prep, spec, ModelKind::Gcn, &A100, tile).total_us();
            if t < best {
                best = t;
                best_tile = tile;
            }
            tile *= 2; // the paper's sweep: 2..1024 at x2 intervals
        }
        speedups.push(best / ours);
        println!("{:<28} {:>10} {:>11.2}x", spec.name, best_tile, best / ours);
    }
    println!("geomean: {:.2}x  (paper: 2.30x on A100)", geomean(&speedups));
    report.push("fig10/geomean_vs_pcgcn", geomean(&speedups), "x", Direction::Higher);
}

// ---------------------------------------------------------------------------
// Fig. 11 — ablation: O1 (full-graph CSR) / O2 (static subgraph) / O3 (adaptive)
// ---------------------------------------------------------------------------
fn fig11(prep: &mut Prep) {
    println!("\n=== Fig 11: AdaptGear optimization versions (GCN, A100), speedup over O1 ===");
    println!("{:<28} {:>8} {:>8} {:>8}", "dataset", "O1", "O2", "O3");
    for spec in DATASETS {
        let o1 = training_iter(Strategy::AdaptGearO1, prep, spec, ModelKind::Gcn, &A100, 0).total_us();
        let o2 = training_iter(Strategy::AdaptGearO2, prep, spec, ModelKind::Gcn, &A100, 0).total_us();
        let o3 = training_iter(Strategy::AdaptGear, prep, spec, ModelKind::Gcn, &A100, 0).total_us();
        println!("{:<28} {:>8.2} {:>8.2} {:>8.2}", spec.name, 1.0, o1 / o2, o1 / o3);
    }
    println!("paper shape: gains vary per dataset; O3 best on the larger datasets,\n  while small-working-set graphs favor O1 on the A100 (40 MB L2 absorbs them)");
}

// ---------------------------------------------------------------------------
// Fig. 12 — memory overhead of subgraph topology storage
// ---------------------------------------------------------------------------
fn fig12(prep: &mut Prep, report: &mut BenchReport) {
    use adaptgear::coordinator::metrics::memory_breakdown;
    println!("\n=== Fig 12: topology share of peak training memory (GCN) ===");
    println!("{:<28} {:>12} {:>12} {:>10}", "dataset", "topo(MB)", "total(MB)", "topo %");
    let mut fracs = Vec::new();
    for spec in DATASETS {
        let d = prep.decomp(spec, Reorder::Metis, Propagation::GcnNormalized);
        let m = memory_breakdown(d, &dims_for(spec, ModelKind::Gcn));
        fracs.push(m.topo_fraction() * 100.0);
        println!(
            "{:<28} {:>12.2} {:>12.2} {:>9.2}%",
            spec.name,
            m.topo_bytes as f64 / 1e6,
            m.total() as f64 / 1e6,
            m.topo_fraction() * 100.0
        );
    }
    let mean_share = fracs.iter().sum::<f64>() / fracs.len() as f64;
    println!("mean topology share: {mean_share:.2}%  (paper: 4.47% average)");
    report.push("fig12/mean_topo_share_pct", mean_share, "%", Direction::Lower);
}

// ---------------------------------------------------------------------------
// Table 2 — taxonomy with measured launch/merge overhead per category
// ---------------------------------------------------------------------------
fn table2(prep: &mut Prep) {
    println!("\n=== Table 2: kernel-mapping granularity vs measured runtime overhead ===");
    let spec = DATASETS.iter().find(|d| d.name == "pubmed").unwrap();
    println!(
        "{:<12} {:<9} {:<22} {:>9} {:>13}",
        "granularity", "format", "system", "launches", "overhead(us)"
    );
    for (gran, label, strat, tile) in [
        ("full-graph", "static", Strategy::GnnAdvisorMetis, 0usize),
        ("block", "adaptive", Strategy::Pcgcn, COMMUNITY),
        ("subgraph", "adaptive", Strategy::AdaptGear, 0),
    ] {
        let it = training_iter(strat, prep, spec, ModelKind::Gcn, &A100, tile);
        println!(
            "{gran:<12} {label:<9} {:<22} {:>9} {:>13.1}",
            strat.as_str(),
            it.kernel_launches,
            it.overhead_us + it.kernel_launches as f64 * A100.launch_us
        );
    }
    println!("paper shape: full-graph low overhead, block high, subgraph low");
}

// ---------------------------------------------------------------------------
// Sec. 6.3 — preprocessing + selector runtime overhead (amazon0601)
// ---------------------------------------------------------------------------
fn overhead() {
    println!("\n=== Sec 6.3: runtime overhead (amazon0601-like) ===");
    let spec = DATASETS.iter().find(|d| d.name == "amazon0601").unwrap();
    let scale = scale_for(spec);
    let g = spec.build_scaled(scale, 42).graph;
    let (d, times) =
        preprocess(Strategy::AdaptGear, &g, Propagation::GcnNormalized, COMMUNITY, 42);
    println!(
        "scale {:.3}: vertices={} edges={}",
        scale,
        d.graph.n,
        d.graph.directed_edge_count()
    );
    println!("graph reorder:   {:.3}s   (paper: 0.59s at full scale)", times.reorder_secs);
    println!("graph decompose: {:.3}s   (paper: 0.08s at full scale)", times.decompose_secs);
    let mut monitor_us = 0.0;
    for kind in [KernelKind::CsrIntra, KernelKind::DenseBlock] {
        monitor_us += kernel_cost(kind, &d.intra, 32, COMMUNITY, &A100).time_us * 3.0;
    }
    for kind in [KernelKind::CsrInter, KernelKind::Coo] {
        monitor_us += kernel_cost(kind, &d.inter, 32, COMMUNITY, &A100).time_us * 3.0;
    }
    println!(
        "selector monitoring: {:.4}s simulated GPU time (paper: < 0.1s)",
        monitor_us / 1e6
    );
    println!("all negligible vs hours-scale training (paper Sec 6.3)");
}

// ---------------------------------------------------------------------------
// Ablation: community-size sensitivity (paper Sec. 4.2 exposes the METIS
// community size as the preprocessing parameter; Sec. 5 fixes it to 16)
// ---------------------------------------------------------------------------
fn ablation_community(prep: &mut Prep) {
    use adaptgear::gpusim::kernel_cost::subgraph_pair_cost;
    use adaptgear::partition::metis_order;
    println!("\n=== Ablation: community size (pubmed-like, GCN widths, A100) ===");
    println!("{:>6} {:>12} {:>12} {:>14}", "C", "intra frac", "agg (us)", "row_ptr(KB)");
    let spec = DATASETS.iter().find(|d| d.name == "pubmed").unwrap();
    let g = prep.graph(spec).clone();
    for community in [8usize, 16, 32, 64, 128] {
        let perm = metis_order(&g, community, 42);
        let graph = g.relabel(&perm);
        let matrix = Csr::gcn_normalized(&graph);
        let (intra, inter) = matrix.split_block_diagonal(community);
        let intra_frac = intra.nnz() as f64 / matrix.nnz() as f64;
        let d = Decomposition {
            graph: graph.clone(),
            perm: perm.clone(),
            intra: intra.clone(),
            inter: inter.clone(),
            community,
        };
        let pair = adaptgear::coordinator::best_adaptive_pair(&d, 32, &A100);
        let (ic, jc) =
            subgraph_pair_cost(pair.intra.unwrap(), pair.inter, &intra, &inter, 32, community, &A100);
        println!(
            "{community:>6} {intra_frac:>12.3} {:>12.1} {:>14.1}",
            ic.time_us + jc.time_us,
            (graph.n + 1) as f64 * 4.0 / 1e3,
        );
    }
    println!("paper choice C=16 trades intra coverage against dense-block padding waste");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name || a == "all");

    let t0 = std::time::Instant::now();
    let mut prep = Prep::new();
    // Headline geomeans flow through the shared bench report schema so
    // figure regressions gate exactly like every other BENCH_*.json.
    let mut report = BenchReport::new("figures", false);
    if want("fig2b") {
        fig2b();
    }
    if want("fig3a") {
        fig3a(&mut prep);
    }
    if want("fig3b") {
        fig3b(&mut prep);
    }
    if want("fig4") {
        fig4(&mut prep);
    }
    if want("fig8") {
        fig8(&mut prep, &mut report);
    }
    if want("fig9") {
        fig9(&mut prep, &mut report);
    }
    if want("fig10") {
        fig10(&mut prep, &mut report);
    }
    if want("fig11") {
        fig11(&mut prep);
    }
    if want("fig12") {
        fig12(&mut prep, &mut report);
    }
    if want("table2") {
        table2(&mut prep);
    }
    if want("overhead") {
        overhead();
    }
    if want("community") {
        ablation_community(&mut prep);
    }
    if !report.metrics.is_empty() {
        report.note("scale_cap", format!("{}", vertex_cap()));
        match report.write_at(std::path::Path::new(".")) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("figures: could not write report: {e:#}"),
        }
    }
    println!("\n[figures done in {:.1}s]", t0.elapsed().as_secs_f64());
}
