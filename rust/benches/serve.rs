//! Serving throughput/latency bench — thin wrapper over
//! `adaptgear::bench::serve` (closed-loop loadgen at max-batch 1 vs 16),
//! emitting `BENCH_serve.json` through the shared report writer. Skips
//! cleanly (exit 0, schema-valid skip report) when `artifacts/` is not
//! built, mirroring the integration tests.
//!
//! ```text
//! cargo bench --bench serve [-- --quick] [-- --out DIR]
//! ```

use adaptgear::bench::{serve, BenchConfig};
use adaptgear::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = BenchConfig {
        quick: args.flag("quick"),
        artifacts: args.get_or("artifacts", "artifacts").to_string(),
        out: args.get_or("out", ".").into(),
        ..Default::default()
    };
    let report = serve::run(&cfg)?;
    let path = report.write_at(&cfg.out)?;
    println!("wrote {}", path.display());
    Ok(())
}
