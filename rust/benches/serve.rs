//! Serving throughput/latency bench: drives the `serve` subsystem with
//! the closed-loop synthetic load generator at max-batch 1 (no
//! coalescing) and max-batch 16, and emits `BENCH_serve.json` with
//! throughput and tail latency for both — the batching win is the ratio.
//!
//! ```text
//! cargo bench --bench serve [-- --requests 400]
//! ```
//!
//! Skips cleanly (exit 0) when `artifacts/` is not built, mirroring the
//! integration tests.

use std::time::Duration;

use adaptgear::coordinator::ModelKind;
use adaptgear::graph::datasets;
use adaptgear::runtime::Engine;
use adaptgear::serve::{
    loadgen, DeploymentSpec, LoadGenConfig, ModelRegistry, ServeConfig, ServeSession, SloReport,
};
use adaptgear::util::cli::Args;
use adaptgear::util::json::{self, Json};

fn serve_once(
    engine: &Engine,
    registry: &mut ModelRegistry,
    deployment: &str,
    n: usize,
    f_data: usize,
    max_batch: usize,
    requests: usize,
) -> anyhow::Result<SloReport> {
    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
    };
    let load = LoadGenConfig { requests, clients: 32, ..Default::default() };
    let (session, client) = ServeSession::new(engine, registry, cfg);
    let gen = loadgen::spawn(client, deployment.to_string(), n, f_data, load);
    let report = session.run()?;
    gen.join();
    Ok(report)
}

fn config_json(max_batch: usize, r: &SloReport) -> Json {
    Json::obj(vec![
        ("max_batch", Json::num(max_batch as f64)),
        ("throughput_rps", Json::num(r.throughput_rps)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p99_ms", Json::num(r.p99_ms)),
        ("served", Json::num(r.served as f64)),
        ("forward_calls", Json::num(r.forward_calls as f64)),
        ("mean_occupancy", Json::num(r.mean_occupancy)),
        ("shed_rate", Json::num(r.shed_rate)),
    ])
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("skipping bench serve: artifacts/ not built (run `make artifacts`)");
        return Ok(());
    }
    let args = Args::from_env();
    let requests = args.get_usize("requests", 400);
    let dataset = args.get_or("dataset", "citeseer");

    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let spec = datasets::find(dataset).expect("unknown dataset");
    let mut registry = ModelRegistry::new();
    let mut dspec = DeploymentSpec::new("bench", spec, ModelKind::Gcn);
    dspec.steps = 40;
    let dep = registry.deploy(&engine, dspec)?;
    let (n, f_data) = (dep.n, dep.f_data);
    println!(
        "deployed {} on {} ({} vertices, kernels {})",
        dep.model.as_str(),
        spec.name,
        n,
        dep.chosen()
    );

    let unbatched = serve_once(&engine, &mut registry, "bench", n, f_data, 1, requests)?;
    println!("\n-- max-batch 1 (no coalescing) --\n{}", unbatched.render());
    let batched = serve_once(&engine, &mut registry, "bench", n, f_data, 16, requests)?;
    println!("\n-- max-batch 16 --\n{}", batched.render());

    let speedup = if unbatched.throughput_rps > 0.0 {
        batched.throughput_rps / unbatched.throughput_rps
    } else {
        0.0
    };
    println!(
        "batching speedup {speedup:.2}x ({:.1} -> {:.1} req/s, {} -> {} forwards)",
        unbatched.throughput_rps,
        batched.throughput_rps,
        unbatched.forward_calls,
        batched.forward_calls
    );

    let out = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("dataset", Json::str(spec.name)),
        ("requests", Json::num(requests as f64)),
        (
            "configs",
            Json::Arr(vec![config_json(1, &unbatched), config_json(16, &batched)]),
        ),
        ("batching_speedup", Json::num(speedup)),
        ("detail", Json::Arr(vec![unbatched.to_json(), batched.to_json()])),
    ]);
    std::fs::write("BENCH_serve.json", json::write(&out))?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
