//! Property tests for the feature-dimension sparsity path (DESIGN.md
//! Sec. 15): top-k selection fused into the native GCN, the SparseFeat
//! aggregation schedule, and the hand-derived backward masking.
//!
//! Three contracts:
//!
//! * `TopK(k = F)` is the dense model — bitwise, not approximately —
//!   through the REAL execution path (`SimCostPlanner` assignment
//!   compiled to [`AssignmentExec`]), so turning the feature axis on at
//!   full width can never perturb a converged run;
//! * [`sparse_aggregate`] over per-row top-k compressed features equals
//!   the dense aggregation of the masked matrix on every lane, across
//!   random k/F ratios, densities, and ragged (non-multiple-of-16)
//!   sizes;
//! * the top-k backward matches finite differences on the lanes the
//!   selection keeps (perturbing `w2` never flips the selection, which
//!   is what makes the numeric gradient well-defined).

use adaptgear::coordinator::ModelKind;
use adaptgear::gpusim::A100;
use adaptgear::graph::generate::planted_partition;
use adaptgear::graph::{Csr, Graph};
use adaptgear::kernels::{sparse_aggregate, AssignmentExec, FeatMode, GcnModel, SparseFeat};
use adaptgear::partition::{Decomposition, Propagation, Reorder};
use adaptgear::plan::{PlanRequest, Planner, SimCostPlanner};
use adaptgear::runtime::BucketInfo;
use adaptgear::util::prop;
use adaptgear::util::rng::Rng;

/// Plan a decomposition with the real planner and compile the class
/// assignment to native schedules — the path `train --sampled` drives.
fn planned_exec(d: &Decomposition, f: usize, hidden: usize) -> AssignmentExec {
    let bucket = BucketInfo {
        name: "feat-prop".to_string(),
        vertices: d.graph.n,
        edges: d.intra.nnz() + d.inter.nnz() + 8,
        features: f,
        hidden,
        classes: 4,
        blocks: d.graph.n.div_ceil(16),
    };
    let plan = SimCostPlanner::new(&A100)
        .plan(&PlanRequest::new(d, ModelKind::Gcn, &bucket))
        .expect("planning");
    AssignmentExec::build(d, &plan.assignment).expect("compiling the plan")
}

#[test]
fn topk_full_width_is_bitwise_dense_through_planner_path() {
    prop::check("TopK(k=F) == Dense bitwise via AssignmentExec", 8, |rng| {
        let n = (rng.usize_below(6) + 3) * 16;
        let g = planted_partition(n, 16, 0.3 + rng.f64() * 0.4, 0.02, rng);
        let d = Decomposition::build(&g, Reorder::Metis, Propagation::GcnNormalized, 16, 1);
        let f = rng.usize_below(6) + 2;
        let h = rng.usize_below(12) + 4;
        let exec = planned_exec(&d, f, h);
        let at = d.whole().transpose();
        let agg = |t: &[f32], w: usize| exec.aggregate(t, w);
        let agg_t = |t: &[f32], w: usize| at.spmm(t, w);

        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        let labels: Vec<i32> = (0..n).map(|_| rng.usize_below(4) as i32).collect();
        let mask: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.7 { 1.0 } else { 0.0 }).collect();

        let seed = rng.below(1 << 20);
        let mut dense = GcnModel::init(f, h, 4, seed);
        // k = h exactly, and k > h for good measure on half the cases
        let k = h + rng.usize_below(2) * 3;
        let mut topk = GcnModel::init(f, h, 4, seed).with_feat_mode(FeatMode::TopK(k));

        let yd = dense.forward(agg, &x, n);
        let yt = topk.forward(agg, &x, n);
        prop::require(yd == yt, "full-width top-k forward diverged from dense")?;
        for step in 0..3 {
            let ld = dense.train_step(agg, agg_t, &x, n, &labels, &mask, 0.1);
            let lt = topk.train_step(agg, agg_t, &x, n, &labels, &mask, 0.1);
            prop::require(
                ld.to_bits() == lt.to_bits(),
                &format!("loss diverged at step {step}: {ld} vs {lt}"),
            )?;
        }
        prop::require(
            dense.w1 == topk.w1 && dense.b1 == topk.b1 && dense.w2 == topk.w2
                && dense.b2 == topk.b2,
            "parameters diverged after full-width top-k training",
        )
    });
}

#[test]
fn sparse_aggregate_equals_dense_on_masked_lanes() {
    prop::check("sparse_aggregate == spmm(to_dense)", 25, |rng| {
        // Ragged sizes on purpose: nothing here may assume 16-alignment.
        let n = rng.usize_below(90) + 3;
        let m = rng.usize_below(4 * n) + n;
        let g = Graph::from_edges(
            n,
            (0..m).map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32)),
        );
        let a = Csr::gcn_normalized(&g);
        let f = rng.usize_below(12) + 1;
        let k = rng.usize_below(f) + 1;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();

        let sf = SparseFeat::from_dense(&x, n, f, k);
        prop::require(sf.density() <= 1.0 && sf.density() > 0.0, "density out of range")?;
        let got = sparse_aggregate(&a, &sf);
        let expect = a.spmm(&sf.to_dense(), f);
        prop::require(got.len() == expect.len(), "output shape mismatch")?;
        for (i, (p, q)) in got.iter().zip(&expect).enumerate() {
            prop::require_close(*p as f64, *q as f64, 1e-4, &format!("lane {i}"))?;
        }
        // k = f must reproduce the fully dense aggregation bitwise-ish
        if k == f {
            let dense = a.spmm(&x, f);
            for (p, q) in got.iter().zip(&dense) {
                prop::require_close(*p as f64, *q as f64, 1e-4, "full-k lane")?;
            }
        }
        Ok(())
    });
}

#[test]
fn topk_backward_matches_finite_differences() {
    // Finite-difference gradcheck of the top-k masked backward. The
    // perturbed coordinates live in `w2`: the selection is a function of
    // `w1`/`b1` only, so an eps-nudge of `w2` never flips which lanes
    // survive and the loss stays differentiable at the probe point.
    let mut rng = Rng::new(0x70f3);
    let g = planted_partition(64, 16, 0.4, 0.03, &mut rng);
    let a = Csr::gcn_normalized(&g);
    let at = a.transpose();
    let n = 64;
    let f = 4;
    let h = 8;
    let k = 3;
    let labels: Vec<i32> = (0..n).map(|v| (v % 3) as i32).collect();
    let mut mask = vec![0.0f32; n];
    for m in mask.iter_mut().take(20) {
        *m = 1.0;
    }
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
    let agg = |t: &[f32], w: usize| a.spmm(t, w);
    let agg_t = |t: &[f32], w: usize| at.spmm(t, w);
    let model0 = GcnModel::init(f, h, 3, 1).with_feat_mode(FeatMode::TopK(k));
    let loss_of = |m: &GcnModel| {
        let z = m.forward(agg, &x, n);
        m.masked_ce(&z, &labels, &mask)
    };
    // analytic gradient via one SGD step with tiny lr: dW ≈ (W - W') / lr
    let lr = 1e-3f32;
    let mut stepped = model0.clone();
    stepped.train_step(&agg, &agg_t, &x, n, &labels, &mask, lr);
    let eps = 1e-2f32;
    let mut nonzero_seen = false;
    for idx in [0usize, 5, 9, 14, 23] {
        let mut plus = model0.clone();
        let mut minus = model0.clone();
        plus.w2[idx] += eps;
        minus.w2[idx] -= eps;
        let numeric = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
        let analytic = (model0.w2[idx] - stepped.w2[idx]) / lr;
        assert!(
            (numeric - analytic).abs() < 2e-2 + 0.2 * numeric.abs(),
            "top-k w2 grad mismatch (idx {idx}): numeric {numeric} analytic {analytic}"
        );
        if analytic.abs() > 1e-6 {
            nonzero_seen = true;
        }
    }
    assert!(nonzero_seen, "every probed w2 gradient was zero — the gradcheck checked nothing");
    // Lanes the selection dropped must carry exactly zero w1 gradient
    // pressure from those rows; the masked model must still have SOME
    // nonzero w1 gradient (the kept lanes).
    let dw1_norm: f32 = model0
        .w1
        .iter()
        .zip(&stepped.w1)
        .map(|(a, b)| ((a - b) / lr).abs())
        .sum();
    assert!(dw1_norm > 1e-6, "top-k masked backward zeroed the entire w1 gradient");
}
