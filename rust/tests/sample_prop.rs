//! Sampling properties (DESIGN.md Sec. 10):
//!
//! 1. **Determinism** — a fixed seed reproduces identical batches,
//!    including through decomposition.
//! 2. **Induced edges are exactly the sampled adjacency** — every batch
//!    CSR entry is an entry of the full propagation matrix (same weight,
//!    mapped through the node table), with no duplicates and no
//!    fabricated edges; under full fanout the sampled rows are complete.
//! 3. **Sampled forward == full-graph forward on the targets** — with
//!    full fanouts at every layer, a 2-layer GCN forward over the batch
//!    subgraph (executed through a planner-produced class assignment,
//!    i.e. the real hybrid execution path) matches the full-graph
//!    forward restricted to the batch's target rows within 1e-4.
//!
//! Engine-free: the native kernel schedules stand in for the PJRT
//! artifacts exactly as in `hybrid_prop.rs`.

use std::collections::HashSet;

use adaptgear::coordinator::ModelKind;
use adaptgear::graph::generate::planted_partition_mixed;
use adaptgear::graph::Csr;
use adaptgear::gpusim::A100;
use adaptgear::kernels::native_model::GcnModel;
use adaptgear::kernels::AssignmentExec;
use adaptgear::partition::Reorder;
use adaptgear::plan::{PlanRequest, Planner, SimCostPlanner};
use adaptgear::runtime::BucketInfo;
use adaptgear::sample::{Fanout, NeighborSampler};
use adaptgear::util::prop;
use adaptgear::util::rng::Rng;

fn full_propagation(rng: &mut Rng) -> (Csr, usize) {
    let n = rng.usize_below(200) + 40;
    let g = planted_partition_mixed(
        n,
        16,
        0.3 + rng.f64() * 0.6,
        rng.f64() * 0.08,
        rng.usize_below(3) + 2,
        rng.f64() * 0.02,
        rng,
    );
    (Csr::gcn_normalized(&g), n)
}

#[test]
fn fixed_seed_implies_identical_batches() {
    prop::check("sampling is deterministic under a seed", 15, |rng| {
        let (a, n) = full_propagation(rng);
        let fanouts = vec![
            Fanout::Uniform(rng.usize_below(6) + 2),
            Fanout::Uniform(rng.usize_below(6) + 2),
        ];
        let sampler = NeighborSampler::new(&a, fanouts).map_err(|e| e.to_string())?;
        let k = rng.usize_below(n.min(40)) + 1;
        let targets: Vec<u32> = (0..k as u32).collect();
        let seed = rng.next_u64();
        let b1 = sampler.sample(&targets, &mut Rng::new(seed));
        let b2 = sampler.sample(&targets, &mut Rng::new(seed));
        prop::require(b1.nodes == b2.nodes, "node tables differ")?;
        prop::require(b1.csr == b2.csr, "batch matrices differ")?;
        // and the decomposition downstream is byte-identical too
        let d1 = b1.decompose(Reorder::Metis, 16, 3);
        let d2 = b2.decompose(Reorder::Metis, 16, 3);
        prop::require(d1.perm == d2.perm, "decomposition perms differ")?;
        prop::require(d1.intra == d2.intra && d1.inter == d2.inter, "splits differ")
    });
}

#[test]
fn induced_subgraph_edges_are_exactly_the_sampled_adjacency() {
    prop::check("batch csr == sampled slice of the full matrix", 15, |rng| {
        let (a, n) = full_propagation(rng);
        let full_fanout = rng.chance(0.5);
        let fanouts = if full_fanout {
            vec![Fanout::Full, Fanout::Full]
        } else {
            vec![Fanout::Uniform(4), Fanout::Uniform(4)]
        };
        let sampler = NeighborSampler::new(&a, fanouts).map_err(|e| e.to_string())?;
        let k = rng.usize_below(n.min(30)) + 1;
        let targets: Vec<u32> = (0..k as u32).collect();
        let batch = sampler.sample(&targets, rng);

        // every batch entry maps to a full-matrix entry with its weight
        let mut seen: HashSet<(u32, u32)> = HashSet::new();
        for (lr, lc, w) in batch.csr.to_triplets() {
            let gr = batch.nodes[lr as usize];
            let gc = batch.nodes[lc as usize];
            prop::require(seen.insert((gr, gc)), "duplicate sampled edge")?;
            let (cols, vals) = a.row(gr as usize);
            let pos = cols.iter().position(|&c| c == gc);
            let Some(pos) = pos else {
                return Err(format!("batch edge ({gr},{gc}) is not in the full matrix"));
            };
            prop::require_close(
                vals[pos] as f64,
                w as f64,
                0.0,
                "sampled weight must equal the full matrix's",
            )?;
        }
        // under full fanout, the target rows carry EVERY full-matrix entry
        if full_fanout {
            for (i, &t) in batch.targets().iter().enumerate() {
                let (gcols, _) = a.row(t as usize);
                let (bcols, _) = batch.csr.row(i);
                prop::require(
                    bcols.len() == gcols.len(),
                    "full-fanout target row is incomplete",
                )?;
            }
        }
        Ok(())
    });
}

/// Plan a batch decomposition with the real planner and execute its class
/// assignment on the native schedules — the same path `train_sampled`
/// drives, so equivalence covers hybrid splits when they occur.
fn planned_aggregate(
    bd: &adaptgear::partition::Decomposition,
) -> impl Fn(&[f32], usize) -> Vec<f32> {
    let bucket = BucketInfo {
        name: "prop".to_string(),
        vertices: bd.graph.n,
        edges: bd.intra.nnz() + bd.inter.nnz() + 8,
        features: 8,
        hidden: 8,
        classes: 4,
        blocks: bd.graph.n.div_ceil(16),
    };
    let plan = SimCostPlanner::new(&A100)
        .plan(&PlanRequest::new(bd, ModelKind::Gcn, &bucket))
        .expect("planning a batch");
    let exec = AssignmentExec::build(bd, &plan.assignment).expect("compiling the plan");
    move |x: &[f32], f: usize| exec.aggregate(x, f)
}

#[test]
fn sampled_forward_equals_full_forward_on_targets() {
    prop::check("full-fanout sampled forward == full-graph forward", 12, |rng| {
        let (a, n) = full_propagation(rng);
        let layers = 2; // matches the 2-layer GCN
        let sampler = NeighborSampler::new(&a, vec![Fanout::Full; layers])
            .map_err(|e| e.to_string())?;
        let k = rng.usize_below(n.min(24)) + 1;
        let mut targets: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut targets);
        targets.truncate(k);
        let batch = sampler.sample(&targets, rng);
        let bd = batch.decompose(Reorder::Metis, 16, 5);

        let f = 6;
        let model = GcnModel::init(f, 8, 4, rng.next_u64());
        let x_full: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();

        // full-graph forward (reference aggregate = whole-matrix spmm)
        let y_full = model.forward(|t: &[f32], w: usize| a.spmm(t, w), &x_full, n);

        // sampled forward through the planned hybrid execution path
        let bx = batch.gather_features(&x_full, f);
        let zeros = vec![0i32; batch.n()];
        let (bx, _) = adaptgear::coordinator::apply_perm(&bd.perm, &bx, &zeros, f);
        let agg = planned_aggregate(&bd);
        let y_batch = model.forward(&agg, &bx, batch.n());

        let rows = batch.target_rows(&bd);
        for (i, &t) in batch.targets().iter().enumerate() {
            let r = rows[i];
            for j in 0..model.c {
                prop::require_close(
                    y_batch[r * model.c + j] as f64,
                    y_full[t as usize * model.c + j] as f64,
                    1e-4,
                    "sampled vs full logits",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn uniform_fanout_bounds_batch_growth() {
    // Not an equivalence property — a budget one: with fanout k the batch
    // can hold at most sum over layers of frontier * k new edges.
    prop::check("fanout caps sampled edges per layer", 10, |rng| {
        let (a, n) = full_propagation(rng);
        let k = rng.usize_below(4) + 1;
        let sampler =
            NeighborSampler::new(&a, vec![Fanout::Uniform(k)]).map_err(|e| e.to_string())?;
        let t = rng.usize_below(n.min(20)) + 1;
        let targets: Vec<u32> = (0..t as u32).collect();
        let batch = sampler.sample(&targets, rng);
        prop::require(
            batch.nnz() <= t * k,
            "one layer at fanout k samples at most k edges per target",
        )?;
        prop::require(batch.n() <= t + t * k, "node growth bounded by fanout")
    });
}

#[test]
fn native_model_on_whole_equals_assignment_exec_path() {
    // Cross-check the two aggregate implementations the equivalence test
    // composes: planned class execution vs whole-matrix spmm on the SAME
    // decomposition.
    prop::check("planned aggregate == whole spmm", 10, |rng| {
        let (a, n) = full_propagation(rng);
        let sampler = NeighborSampler::new(&a, vec![Fanout::Uniform(6), Fanout::Uniform(6)])
            .map_err(|e| e.to_string())?;
        let targets: Vec<u32> = (0..n.min(32) as u32).collect();
        let batch = sampler.sample(&targets, rng);
        let bd = batch.decompose(Reorder::Metis, 16, 2);
        let agg = planned_aggregate(&bd);
        let f = 3;
        let x: Vec<f32> = (0..batch.n() * f).map(|_| rng.normal_f32()).collect();
        let got = agg(&x, f);
        let expect = bd.whole().spmm(&x, f);
        for (g, e) in got.iter().zip(&expect) {
            prop::require_close(*g as f64, *e as f64, 1e-4, "aggregate elem")?;
        }
        Ok(())
    });
}
