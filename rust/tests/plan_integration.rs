//! Integration: the plan lifecycle end to end — compute a plan, persist
//! it, and have a later training run load and honor it without spending
//! monitor iterations (the `adaptgear plan` → `adaptgear train --planner
//! cached` flow, through the library API).
//!
//! Skips (like the other integration suites) when `artifacts/` is not
//! built.

use adaptgear::coordinator::{ModelKind, Run};
use adaptgear::gpusim::A100;
use adaptgear::plan::{CachedPlanner, MonitorPlanner, PlanStore};
use adaptgear::runtime::Engine;

fn engine_or_skip() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn temp_store(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("adaptgear-planint-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn persisted_plan_is_loaded_and_honored_by_train() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = adaptgear::graph::datasets::find("cora").unwrap();
    let dir = temp_store("train");

    // "adaptgear plan": compute + persist (cold store -> monitoring runs)
    let planned = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(3)
        .planner(CachedPlanner::new(PlanStore::new(&dir), MonitorPlanner::sim(&A100, 3)))
        .train()
        .expect("planning run");
    assert!(planned.train.plan.monitor_iters > 0);
    assert!(!planned.train.plan.provenance.cached);
    assert!(
        PlanStore::new(&dir).contains(planned.train.plan.fingerprint),
        "plan must be persisted"
    );

    // later "adaptgear train --planner cached": loads and honors the plan
    let honored = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(3)
        .planner(CachedPlanner::new(PlanStore::new(&dir), MonitorPlanner::sim(&A100, 3)))
        .train()
        .expect("cached run");
    assert_eq!(honored.train.plan.monitor_iters, 0, "cache hit spends no monitor iters");
    assert!(honored.train.plan.provenance.cached);
    assert_eq!(honored.train.chosen(), planned.train.chosen(), "decision honored");
    assert_eq!(honored.train.plan.fingerprint, planned.train.plan.fingerprint);
    // identical budget + seed + kernels => identical training trajectory
    assert_eq!(honored.train.losses, planned.train.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_for_a_different_model_misses_the_cache() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = adaptgear::graph::datasets::find("cora").unwrap();
    let dir = temp_store("model-miss");

    let gcn = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(2)
        .planner(CachedPlanner::new(PlanStore::new(&dir), MonitorPlanner::sim(&A100, 1)))
        .train()
        .expect("gcn run");
    let gin = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gin)
        .steps(2)
        .planner(CachedPlanner::new(PlanStore::new(&dir), MonitorPlanner::sim(&A100, 1)))
        .train()
        .expect("gin run");
    assert!(!gin.train.plan.provenance.cached, "GIN must not reuse the GCN plan");
    assert_ne!(gcn.train.plan.fingerprint, gin.train.plan.fingerprint);
    let _ = std::fs::remove_dir_all(&dir);
}
