//! Integration: the full training pipeline through PJRT — selector,
//! train-step artifacts, loss descent, forward serving, and determinism.

use adaptgear::coordinator::{pipeline, trainer, ModelKind, Run, Strategy, TrainConfig};
use adaptgear::graph::datasets;
use adaptgear::gpusim::A100;
use adaptgear::partition::Propagation;
use adaptgear::plan::{MonitorPlanner, PlanRequest, Planner};
use adaptgear::runtime::Engine;

fn engine_or_skip() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn quick_cfg(model: ModelKind, steps: usize) -> TrainConfig {
    TrainConfig { model, steps, ..Default::default() }
}

#[test]
fn gcn_loss_descends_on_cora() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = datasets::find("cora").unwrap();
    let report = pipeline::run(&engine, spec, &quick_cfg(ModelKind::Gcn, 40), None).unwrap();
    let losses = &report.train.losses;
    assert_eq!(losses.len(), 40);
    assert!(
        losses[39] < losses[0] * 0.75,
        "no descent: {} -> {}",
        losses[0],
        losses[39]
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn gin_loss_descends_on_citeseer() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = datasets::find("citeseer").unwrap();
    let report = pipeline::run(&engine, spec, &quick_cfg(ModelKind::Gin, 40), None).unwrap();
    let losses = &report.train.losses;
    assert!(
        losses[39] < losses[0] * 0.85,
        "no descent: {} -> {}",
        losses[0],
        losses[39]
    );
}

#[test]
fn wall_clock_planner_picks_runnable_pair() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = datasets::find("cora").unwrap();
    let report = Run::new(&engine)
        .dataset(spec)
        .model(ModelKind::Gcn)
        .steps(5)
        .planner(MonitorPlanner::wall(&engine, 1))
        .train()
        .unwrap();
    // all four candidates measured
    let plan = &report.train.plan;
    assert_eq!(plan.intra_times.len(), 2);
    assert_eq!(plan.inter_times.len(), 2);
    assert!(plan.intra_times.values().all(|t| t.is_finite()));
    assert!(plan.monitor_iters > 0);
    // training proceeded with the winner
    assert_eq!(report.train.losses.len(), 5);
}

#[test]
fn training_is_deterministic_for_fixed_seed() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = datasets::find("cora").unwrap();
    let r1 = pipeline::run(&engine, spec, &quick_cfg(ModelKind::Gcn, 8), None).unwrap();
    let r2 = pipeline::run(&engine, spec, &quick_cfg(ModelKind::Gcn, 8), None).unwrap();
    assert_eq!(r1.train.losses, r2.train.losses);
    assert_eq!(r1.train.chosen(), r2.train.chosen());
    assert_eq!(r1.train.plan.fingerprint, r2.train.plan.fingerprint);
}

#[test]
fn forward_serves_trained_params() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = datasets::find("cora").unwrap();
    let cfg = quick_cfg(ModelKind::Gcn, 25);
    let scale = pipeline::auto_scale(spec, &engine);
    let data = spec.build_scaled(scale, cfg.seed);
    let (d, _) = adaptgear::coordinator::preprocess(
        Strategy::AdaptGear,
        &data.graph,
        Propagation::GcnNormalized,
        engine.manifest.community,
        cfg.seed,
    );
    let f_data = engine.manifest.buckets.values().map(|b| b.features).max().unwrap();
    let n = d.graph.n;
    let (x, labels) = adaptgear::coordinator::apply_perm(
        &d.perm,
        &data.features(f_data),
        &data.labels(),
        f_data,
    );
    let needed_edges = d.intra.nnz().max(d.inter.nnz());
    let bucket = engine.manifest.fit_bucket(n, needed_edges).unwrap().clone();
    let plan = MonitorPlanner::sim(&A100, 1)
        .plan(&PlanRequest::new(&d, cfg.model, &bucket))
        .unwrap();
    let report = trainer::train(&engine, &d, &x, f_data, &labels, &cfg, &plan).unwrap();

    let logits =
        trainer::forward(&engine, &d, report.chosen(), cfg.model, &report.params, &x, f_data)
            .unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));

    // trained model should classify clearly better than chance
    let bucket = &engine.manifest.buckets[&report.bucket];
    let classes = bucket.classes;
    let width = logits.len() / bucket.vertices;
    let mut correct = 0usize;
    for v in 0..n {
        let row = &logits[v * width..v * width + classes.min(width)];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        if pred == labels[v].rem_euclid(classes as i32) {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 1.5 / classes as f64, "accuracy {acc} not above chance");
}

#[test]
fn auto_scale_fits_every_dataset() {
    let Some(engine) = engine_or_skip() else { return };
    for spec in datasets::DATASETS {
        let scale = pipeline::auto_scale(spec, &engine);
        assert!(scale > 0.0 && scale <= 1.0, "{}: scale {scale}", spec.name);
        let n_est = (spec.vertices as f64 * scale) as usize;
        let max_v = engine.manifest.buckets.values().map(|b| b.vertices).max().unwrap();
        assert!(n_est <= max_v + 16, "{}: {n_est} vertices exceed buckets", spec.name);
    }
}

#[test]
fn sim_selector_prefers_dense_on_dense_communities() {
    // dense diagonal blocks at small width: the MXU kernel should win the
    // intra slot on at least the simulated clock
    use adaptgear::coordinator::best_adaptive_pair;
    use adaptgear::graph::generate::planted_partition;
    use adaptgear::partition::{Decomposition, Reorder};
    use adaptgear::util::rng::Rng;

    let mut rng = Rng::new(4);
    let g = planted_partition(2048, 16, 0.85, 0.001, &mut rng);
    let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0);
    let pair = best_adaptive_pair(&d, 32, &adaptgear::gpusim::A100);
    assert!(pair.intra.is_some());
}
