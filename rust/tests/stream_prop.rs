//! Property + acceptance tests for the streaming subsystem
//! (DESIGN.md Sec. 12).
//!
//! The overlay contract is exactness: after ANY legal delta sequence,
//! reads through the [`adaptgear::stream::CsrOverlay`] — and reads after
//! `compact()` — must equal a CSR rebuilt from scratch out of an
//! independent oracle that applies the same symmetric-edge semantics.
//! The replan contract is the paper's: a mutation workload that
//! densifies ONE community must invalidate that block's class and only
//! it, and the swapped plan's forward must stay within 1e-4 of both the
//! whole-graph reference and a cold full re-plan.
//!
//! Engine-free: native kernels + the cost simulator only.

use std::collections::BTreeMap;

use adaptgear::coordinator::{pipeline, preprocess, ModelKind, Strategy};
use adaptgear::graph::datasets;
use adaptgear::graph::generate::planted_partition;
use adaptgear::graph::Csr;
use adaptgear::gpusim::A100;
use adaptgear::kernels::native::aggregate_assignment;
use adaptgear::partition::Decomposition;
use adaptgear::plan::{PlanRequest, Planner, SimCostPlanner};
use adaptgear::runtime::BucketInfo;
use adaptgear::stream::{CsrOverlay, DeltaLog, DeltaOp, StreamConfig, StreamSession};
use adaptgear::util::json;
use adaptgear::util::prop;
use adaptgear::util::rng::Rng;

/// Independent model of the delta semantics: a symmetric weight map plus
/// a vertex count. Deliberately structured nothing like the overlay.
struct Oracle {
    n: usize,
    entries: BTreeMap<(u32, u32), f32>,
}

impl Oracle {
    fn of(base: &Csr) -> Oracle {
        let entries = base.to_triplets().into_iter().map(|(r, c, w)| ((r, c), w)).collect();
        Oracle { n: base.n_rows, entries }
    }

    fn apply(&mut self, op: DeltaOp) {
        match op {
            DeltaOp::InsertEdge { u, v, w } => {
                self.entries.insert((u, v), w);
                self.entries.insert((v, u), w);
            }
            DeltaOp::DeleteEdge { u, v } => {
                self.entries.remove(&(u, v));
                self.entries.remove(&(v, u));
            }
            DeltaOp::Reweight { u, v, w } => {
                if self.entries.contains_key(&(u, v)) {
                    self.entries.insert((u, v), w);
                    self.entries.insert((v, u), w);
                }
            }
            DeltaOp::AddVertices { count } => self.n += count,
        }
    }

    /// Row-major, columns ascending — the `to_triplets` read contract.
    fn triplets(&self) -> Vec<(u32, u32, f32)> {
        self.entries.iter().map(|(&(r, c), &w)| (r, c, w)).collect()
    }

    fn to_csr(&self) -> Csr {
        Csr::from_triplets(self.n, self.n, self.triplets())
    }
}

/// Draw one random op, biased toward pairs that actually exist so
/// deletes and reweights hit the structural paths, not just no-ops.
fn random_op(rng: &mut Rng, oracle: &Oracle) -> DeltaOp {
    let pair = |rng: &mut Rng, oracle: &Oracle| -> (u32, u32) {
        if !oracle.entries.is_empty() && rng.chance(0.5) {
            let keys: Vec<(u32, u32)> = oracle.entries.keys().copied().collect();
            keys[rng.usize_below(keys.len())]
        } else {
            (rng.below(oracle.n as u64) as u32, rng.below(oracle.n as u64) as u32)
        }
    };
    match rng.below(8) {
        0..=2 => {
            let (u, v) = pair(rng, oracle);
            DeltaOp::InsertEdge { u, v, w: rng.normal_f32().abs() + 0.05 }
        }
        3..=4 => {
            let (u, v) = pair(rng, oracle);
            DeltaOp::DeleteEdge { u, v }
        }
        5..=6 => {
            let (u, v) = pair(rng, oracle);
            DeltaOp::Reweight { u, v, w: rng.normal_f32().abs() + 0.05 }
        }
        _ => DeltaOp::AddVertices { count: rng.usize_below(4) + 1 },
    }
}

#[test]
fn overlay_reads_match_a_from_scratch_rebuild() {
    prop::check("overlay == rebuilt CSR, pre- and post-compact", 20, |rng| {
        let n0 = rng.usize_below(64) + 32;
        let g = planted_partition(n0, 16, 0.3, 0.05, rng);
        let base = Csr::gcn_normalized(&g);
        let mut oracle = Oracle::of(&base);
        let mut overlay = CsrOverlay::new(base);
        let mut log = DeltaLog::new();

        let ops = rng.usize_below(120) + 80;
        for _ in 0..ops {
            let op = random_op(rng, &oracle);
            overlay.apply(&log.append(op)).map_err(|e| e.to_string())?;
            oracle.apply(op);
        }

        // staged reads: triplets, nnz, and the spmm all agree
        prop::require(overlay.n_rows() == oracle.n, "vertex counts agree")?;
        prop::require(overlay.nnz() == oracle.entries.len(), "nnz agrees")?;
        prop::require(overlay.to_triplets() == oracle.triplets(), "triplets agree")?;
        let f = rng.usize_below(3) + 1;
        let x: Vec<f32> = (0..oracle.n * f).map(|_| rng.normal_f32()).collect();
        let want = oracle.to_csr().spmm(&x, f);
        for (a, b) in overlay.spmm(&x, f).iter().zip(&want) {
            prop::require_close(*a as f64, *b as f64, 1e-5, "staged spmm")?;
        }

        // compaction moves storage, never meaning
        overlay.compact();
        prop::require(overlay.staged_rows() == 0, "compact clears the overlay")?;
        prop::require(overlay.to_triplets() == oracle.triplets(), "post-compact triplets")?;
        for (a, b) in overlay.spmm(&x, f).iter().zip(&want) {
            prop::require_close(*a as f64, *b as f64, 1e-5, "post-compact spmm")?;
        }
        Ok(())
    });
}

#[test]
fn serialized_log_replays_to_the_identical_graph() {
    prop::check("JSON roundtrip + replay == live overlay", 15, |rng| {
        let n0 = rng.usize_below(48) + 32;
        let g = planted_partition(n0, 16, 0.3, 0.05, rng);
        let base = Csr::gcn_normalized(&g);
        let mut oracle = Oracle::of(&base);
        let mut live = CsrOverlay::new(base.clone());
        let mut log = DeltaLog::new();
        for _ in 0..rng.usize_below(60) + 40 {
            let op = random_op(rng, &oracle);
            live.apply(&log.append(op)).map_err(|e| e.to_string())?;
            oracle.apply(op);
        }

        let text = json::write(&log.to_json());
        let back = DeltaLog::from_json(&json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        prop::require(back.entries() == log.entries(), "entries roundtrip")?;

        let mut replayed = CsrOverlay::new(base);
        for delta in back.entries() {
            replayed.apply(delta).map_err(|e| e.to_string())?;
        }
        prop::require(replayed.version() == live.version(), "versions agree")?;
        prop::require(replayed.to_triplets() == live.to_triplets(), "replay == live")?;
        Ok(())
    });
}

fn bucket_for(d: &Decomposition, slack: usize) -> BucketInfo {
    BucketInfo {
        name: "bstream".into(),
        vertices: d.graph.n + slack,
        edges: d.intra.nnz() + d.inter.nnz() + 4 * slack + 4096,
        features: 16,
        hidden: 16,
        classes: 4,
        blocks: d.graph.n.div_ceil(d.community.max(1)) + slack / d.community.max(1),
    }
}

#[test]
fn weight_only_churn_never_triggers_a_replan() {
    prop::check("reweights are structurally invisible", 8, |rng| {
        let n = rng.usize_below(96) + 64;
        let g = planted_partition(n, 16, 0.5, 0.03, rng);
        let d = Decomposition::build(
            &g,
            adaptgear::partition::Reorder::Identity,
            adaptgear::partition::Propagation::GcnNormalized,
            16,
            0,
        );
        let bucket = bucket_for(&d, 32);
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .map_err(|e| e.to_string())?;
        let mut s = StreamSession::new(&d, plan, bucket, StreamConfig::new(ModelKind::Gcn, &A100));
        let trips = d.whole().to_triplets();
        for _ in 0..30 {
            let (u, v, _) = trips[rng.usize_below(trips.len())];
            s.apply(DeltaOp::Reweight { u, v, w: rng.normal_f32().abs() + 0.01 })
                .map_err(|e| e.to_string())?;
        }
        prop::require(s.maybe_replan().map_err(|e| e.to_string())?.is_none(), "no drift")?;
        prop::require(s.graph_version() == 0, "version untouched")?;
        Ok(())
    });
}

/// THE acceptance workload: on planted-mixed, densify one community and
/// check the blast radius — at least one plan class invalidated (the
/// `plan.replan.class` counter moves) but NOT all of them, the new
/// assignment covers the mutated decomposition, and the swapped forward
/// matches both a cold full re-plan and the whole-graph `spmm` to 1e-4.
#[test]
fn densifying_one_community_invalidates_some_but_not_all_classes() {
    let community = 16;
    let spec = datasets::find("planted-mixed").expect("registry dataset");
    let scale = 768.0 / spec.vertices as f64;
    let data = spec.build_scaled(scale, 11);
    let (d, _) = preprocess(
        Strategy::AdaptGear,
        &data.graph,
        pipeline::propagation_for(ModelKind::Gcn),
        community,
        11,
    );
    let n = d.graph.n;
    let bucket = bucket_for(&d, 64);
    let plan = SimCostPlanner::new(&A100)
        .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
        .unwrap();
    let planned_classes = plan.assignment.classes.len();
    let mut session =
        StreamSession::new(&d, plan, bucket.clone(), StreamConfig::new(ModelKind::Gcn, &A100));

    // densify ONE community to near-clique; every other block untouched
    let before = adaptgear::obs::snapshot().counters.get("plan.replan.class").copied().unwrap_or(0);
    let lo = community as u32; // block 1
    for u in lo..lo + community as u32 {
        for v in (u + 1)..lo + community as u32 {
            session.apply(DeltaOp::InsertEdge { u, v, w: 0.3 }).unwrap();
        }
    }
    let r = session.maybe_replan().unwrap().expect("densified community must drift");
    let after = adaptgear::obs::snapshot().counters.get("plan.replan.class").copied().unwrap_or(0);
    let invalidated = (after - before) as usize;
    assert!(invalidated >= 1, "at least one class must be invalidated");
    assert!(
        invalidated < planned_classes,
        "one mutated community must not invalidate all {planned_classes} classes \
         (got {invalidated})"
    );
    assert_eq!(r.drifted.len(), invalidated, "counter mirrors the drift report");
    assert!(r.plan.assignment.covers(&r.d).is_ok(), "new plan covers the mutated graph");
    assert_eq!(r.graph_version, 1);

    // numerical acceptance: swapped forward vs whole graph AND vs a cold
    // full re-plan of the mutated decomposition
    let f = 8;
    let mut rng = Rng::new(0xacce);
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
    let swapped = aggregate_assignment(&r.d, &r.plan.assignment, &x, f).unwrap();
    let whole = r.d.whole().spmm(&x, f);
    let mut cold_req = PlanRequest::new(&r.d, ModelKind::Gcn, &bucket);
    cold_req.graph_version = r.graph_version;
    let cold = SimCostPlanner::new(&A100).plan(&cold_req).unwrap();
    let cold_fwd = aggregate_assignment(&r.d, &cold.assignment, &x, f).unwrap();
    for i in 0..n * f {
        assert!(
            (swapped[i] - whole[i]).abs() < 1e-4,
            "swapped forward diverged from whole-graph spmm at {i}"
        );
        assert!(
            (swapped[i] - cold_fwd[i]).abs() < 1e-4,
            "swapped forward diverged from the cold re-plan at {i}"
        );
    }
}
