//! Property: hybrid per-class execution is EXACT — running the dense
//! class (dense-block schedule) + the sparse class (community-resident
//! CSR schedule) + inter (vertex-parallel CSR / edge-parallel COO) and
//! summing the three outputs matches the whole-graph CSR `spmm` within
//! 1e-4, across random densities, random thresholds, and ragged vertex
//! counts. This is the numerical contract that lets a planner split the
//! block diagonal freely: zero padding is exact for aggregate-sum.
//!
//! Engine-free: uses the native CPU kernel schedules (the PJRT artifacts
//! are separately held to the same contract by `kernel_parity.rs`).

use adaptgear::graph::generate::planted_partition_mixed;
use adaptgear::graph::DenseBlocks;
use adaptgear::kernels::native;
use adaptgear::kernels::TileSparse;
use adaptgear::partition::{Decomposition, DensityClass, Propagation, Reorder};
use adaptgear::util::prop;
use adaptgear::util::rng::Rng;

#[test]
fn hybrid_class_execution_matches_whole_graph_spmm() {
    prop::check("dense class + sparse class + inter == whole", 25, |rng| {
        // random size, deliberately often ragged
        let n = rng.usize_below(300) + 20;
        let p_dense = 0.3 + rng.f64() * 0.65;
        let p_sparse = rng.f64() * 0.1;
        let p_inter = rng.f64() * 0.02;
        let period = rng.usize_below(3) + 2;
        let g = planted_partition_mixed(n, 16, p_dense, p_sparse, period, p_inter, rng);
        let reorder = if rng.chance(0.5) { Reorder::Identity } else { Reorder::Metis };
        let d = Decomposition::build(&g, reorder, Propagation::GcnNormalized, 16, 7);

        // random threshold anywhere in [0, 1.1): both degenerate and
        // genuinely hybrid splits must stay exact
        let threshold = rng.f64() * 1.1;
        let split = d.split_intra(threshold);
        prop::require(
            (1..=2).contains(&split.classes.len()),
            "split yields 1 or 2 classes",
        )?;

        let f = rng.usize_below(5) + 1;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();

        // execute each class on its own schedule
        let mut acc = vec![0.0f32; n * f];
        if let Some(dense) = split.class(DensityClass::Dense) {
            let blocks = DenseBlocks::from_block_diagonal_csr(&dense.matrix, 16);
            for (a, b) in acc.iter_mut().zip(native::dense_block_spmm(&blocks, &x, f)) {
                *a += b;
            }
        }
        if let Some(sparse) = split.class(DensityClass::Sparse) {
            for (a, b) in acc
                .iter_mut()
                .zip(native::csr_intra_spmm(&sparse.matrix, &x, f, 16))
            {
                *a += b;
            }
        }
        // inter on both of its candidate schedules — each must complete
        // the sum exactly
        let via_csr = native::csr_inter_spmm(&d.inter, &x, f);
        let via_coo = native::coo_spmm(n, &d.inter.to_triplets(), &x, f);
        let expect = d.whole().spmm(&x, f);
        for (i, &e) in expect.iter().enumerate() {
            prop::require_close(
                (acc[i] + via_csr[i]) as f64,
                e as f64,
                1e-4,
                "hybrid classes + csr_inter",
            )?;
            prop::require_close(
                (acc[i] + via_coo[i]) as f64,
                e as f64,
                1e-4,
                "hybrid classes + coo",
            )?;
        }
        Ok(())
    });
}

#[test]
fn tile_sparse_class_execution_matches_whole_graph_spmm() {
    // The same exactness contract for the tile-sparse schedule: swept
    // over random densities and ragged sizes, running EITHER intra class
    // on compacted 16x16 tiles (dense class on tiles + sparse on its CSR
    // schedule, and both classes on tiles) plus inter must reproduce the
    // whole-graph CSR spmm within 1e-4.
    prop::check("tile class(es) + inter == whole", 25, |rng| {
        let n = rng.usize_below(300) + 20;
        let p_dense = 0.3 + rng.f64() * 0.65;
        let p_sparse = rng.f64() * 0.1;
        let p_inter = rng.f64() * 0.02;
        let g = planted_partition_mixed(n, 16, p_dense, p_sparse, 3, p_inter, rng);
        let reorder = if rng.chance(0.5) { Reorder::Identity } else { Reorder::Metis };
        let d = Decomposition::build(&g, reorder, Propagation::GcnNormalized, 16, 5);
        let threshold = rng.f64() * 1.1;
        let split = d.split_intra(threshold);

        let f = rng.usize_below(5) + 1;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        let inter_part = native::csr_inter_spmm(&d.inter, &x, f);
        let expect = d.whole().spmm(&x, f);

        // dense class on the tile schedule, sparse on its CSR schedule
        let mut mixed = inter_part.clone();
        // every class on the tile schedule (the uniform-collapse case)
        let mut all_tiles = inter_part;
        if let Some(dense) = split.class(DensityClass::Dense) {
            let tiles = TileSparse::from_block_diagonal_csr(&dense.matrix, 16);
            for ((m, t), got) in mixed
                .iter_mut()
                .zip(all_tiles.iter_mut())
                .zip(native::tile_sparse_spmm(&tiles, &x, f))
            {
                *m += got;
                *t += got;
            }
        }
        if let Some(sparse) = split.class(DensityClass::Sparse) {
            let tiles = TileSparse::from_block_diagonal_csr(&sparse.matrix, 16);
            let via_tiles = native::tile_sparse_spmm(&tiles, &x, f);
            let via_csr = native::csr_intra_spmm(&sparse.matrix, &x, f, 16);
            for ((m, t), (a, b)) in mixed
                .iter_mut()
                .zip(all_tiles.iter_mut())
                .zip(via_csr.iter().zip(via_tiles))
            {
                *m += a;
                *t += b;
            }
        }
        for (i, &e) in expect.iter().enumerate() {
            prop::require_close(mixed[i] as f64, e as f64, 1e-4, "tile dense + csr sparse")?;
            prop::require_close(all_tiles[i] as f64, e as f64, 1e-4, "all classes on tiles")?;
        }
        Ok(())
    });
}

#[test]
fn merged_sparse_class_into_inter_is_exact() {
    // The trainer's artifact lowering: dense class in the intra slot,
    // sparse class MERGED into the inter operand. The merged matrix on
    // the inter schedule plus the dense class must equal the whole.
    prop::check("dense class + (sparse ∪ inter) == whole", 25, |rng| {
        let n = rng.usize_below(250) + 17;
        let g = planted_partition_mixed(n, 16, 0.8, rng.f64() * 0.08, 3, 0.01, rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 3);
        let threshold = 0.2 + rng.f64() * 0.5;
        let split = d.split_intra(threshold);
        let f = 2;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();

        let mut merged_trips = d.inter.to_triplets();
        let mut acc = vec![0.0f32; n * f];
        if let Some(dense) = split.class(DensityClass::Dense) {
            let blocks = DenseBlocks::from_block_diagonal_csr(&dense.matrix, 16);
            acc = native::dense_block_spmm(&blocks, &x, f);
        }
        if let Some(sparse) = split.class(DensityClass::Sparse) {
            merged_trips.extend(sparse.matrix.to_triplets());
        }
        let merged = adaptgear::graph::Csr::from_triplets(n, n, merged_trips);
        let inter_part = native::csr_inter_spmm(&merged, &x, f);
        let expect = d.whole().spmm(&x, f);
        for i in 0..n * f {
            prop::require_close(
                (acc[i] + inter_part[i]) as f64,
                expect[i] as f64,
                1e-4,
                "merged lowering",
            )?;
        }
        Ok(())
    });
}
