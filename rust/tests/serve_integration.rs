//! Integration: the serving subsystem end to end through PJRT — deploy,
//! micro-batched event loop, closed-loop load, SLO accounting.
//!
//! Skips (like `training_integration`) when `artifacts/` is not built.

use std::time::Duration;

use adaptgear::coordinator::{trainer, ModelKind};
use adaptgear::graph::datasets;
use adaptgear::gpusim::A100;
use adaptgear::partition::Decomposition;
use adaptgear::plan::{
    CachedPlanner, MonitorPlanner, PlanRequest, PlanStore, Planner, SimCostPlanner,
};
use adaptgear::runtime::Engine;
use adaptgear::serve::{
    loadgen, DeploymentSpec, LoadGenConfig, ModelRegistry, PlanSwap, ServeConfig, ServeError,
    ServeSession,
};
use adaptgear::stream::{CsrOverlay, DeltaLog, DeltaOp};

fn engine_or_skip() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn deploy(engine: &Engine, registry: &mut ModelRegistry, name: &str) -> (usize, usize) {
    let spec = datasets::find("cora").unwrap();
    let mut dspec = DeploymentSpec::new(name, spec, ModelKind::Gcn);
    dspec.steps = 20;
    let dep = registry.deploy(engine, dspec).expect("deploy");
    (dep.n, dep.f_data)
}

#[test]
fn closed_loop_serving_batches_and_answers_everything() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    let (n, f_data) = deploy(&engine, &mut registry, "cora-gcn");

    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_depth: 64,
    };
    let load = LoadGenConfig { requests: 64, clients: 8, seed: 5, ..Default::default() };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, "cora-gcn".to_string(), n, f_data, load);
    let report = session.run().expect("serve loop");
    let summary = gen.join();

    // every offered request is accounted for exactly once
    assert_eq!(summary.sent, 64);
    assert_eq!(summary.answered + summary.shed + summary.failed, summary.sent);
    assert_eq!(report.served, summary.answered);
    assert_eq!(report.shed, summary.shed);
    assert_eq!(report.errors, summary.failed);

    // batching is real: 8 closed-loop clients against one coordinator
    // must coalesce, so strictly fewer forwards than requests served
    assert!(report.served > 0);
    assert!(
        report.forward_calls < report.served,
        "no batching: {} forwards for {} served",
        report.forward_calls,
        report.served
    );
    assert!(report.mean_occupancy > 1.0);
    let occupancy_total: usize = report.occupancy.iter().map(|(s, c)| s * c).sum();
    assert_eq!(occupancy_total, report.served, "histogram covers every served request");

    // SLO numbers are well-formed
    assert!(report.p50_ms > 0.0 && report.p50_ms.is_finite());
    assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn unknown_deployment_gets_error_replies_not_hangs() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    deploy(&engine, &mut registry, "cora-gcn");

    let cfg = ServeConfig { max_batch: 4, max_wait: Duration::from_millis(1), queue_depth: 16 };
    let load = LoadGenConfig { requests: 8, clients: 2, seed: 1, ..Default::default() };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, "no-such-model".to_string(), 100, 8, load);
    let report = session.run().expect("serve loop");
    let summary = gen.join();

    assert_eq!(report.served, 0);
    assert_eq!(summary.failed, 8, "every request must get an error reply");
    assert_eq!(report.errors, 8);
}

#[test]
fn out_of_range_vertex_is_an_error_not_a_clamped_answer() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    deploy(&engine, &mut registry, "cora-gcn");

    let cfg = ServeConfig { max_batch: 1, max_wait: Duration::ZERO, queue_depth: 4 };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let handle = std::thread::spawn(move || {
        let bad = client.call("cora-gcn", usize::MAX / 2, 0, 0.1);
        let good = client.call("cora-gcn", 0, 0, 0.1);
        (bad, good)
    });
    let report = session.run().expect("serve loop");
    let (bad, good) = handle.join().unwrap();

    match bad {
        Err(ServeError::Remote(msg)) => assert!(msg.contains("out of range"), "{msg}"),
        other => panic!("expected Remote out-of-range error, got {other:?}"),
    }
    assert!(good.is_ok(), "in-range request after a bad one must still serve");
    assert_eq!(report.served, 1);
    assert_eq!(report.errors, 1);
}

#[test]
fn warm_plan_store_skips_monitoring_on_redeploy() {
    let Some(engine) = engine_or_skip() else { return };
    let tmp = std::env::temp_dir().join(format!("adaptgear-redeploy-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    let spec = datasets::find("cora").unwrap();
    let mut registry = ModelRegistry::new();

    // first deployment: cold store, the monitor runs and the plan persists
    let mut cold = CachedPlanner::new(PlanStore::new(&tmp), MonitorPlanner::sim(&A100, 3));
    let mut dspec = DeploymentSpec::new("first", spec, ModelKind::Gcn);
    dspec.steps = 10;
    let (cold_iters, cold_cached, cold_chosen) = {
        let dep = registry.deploy_planned(&engine, dspec, &mut cold).expect("first deploy");
        (dep.plan.monitor_iters, dep.plan.provenance.cached, dep.chosen())
    };
    assert!(cold_iters > 0, "cold deploy must monitor");
    assert!(!cold_cached);

    // second deployment of the same (dataset, model, seed) shape: the
    // warm store serves the decision — zero monitor iterations
    let mut warm = CachedPlanner::new(PlanStore::new(&tmp), MonitorPlanner::sim(&A100, 3));
    let mut dspec = DeploymentSpec::new("second", spec, ModelKind::Gcn);
    dspec.steps = 10;
    let dep = registry.deploy_planned(&engine, dspec, &mut warm).expect("second deploy");
    assert_eq!(dep.plan.monitor_iters, 0, "warm store must skip monitoring");
    assert!(dep.plan.provenance.cached, "plan must be served from cache");
    assert_eq!(dep.chosen(), cold_chosen, "cached plan must reproduce the decision");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn registry_double_deploy_through_engine_is_rejected() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    deploy(&engine, &mut registry, "dup");
    let spec = datasets::find("cora").unwrap();
    let mut dspec = DeploymentSpec::new("dup", spec, ModelKind::Gcn);
    dspec.steps = 5;
    let err = registry.deploy(&engine, dspec).unwrap_err();
    assert!(err.to_string().contains("already exists"), "{err}");
    assert_eq!(registry.len(), 1);
}

#[test]
fn plan_swap_lands_mid_traffic_without_draining_the_queue() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    let (n, f_data) = deploy(&engine, &mut registry, "cora-gcn");

    // Prepare the swap OFF the serve thread, before the session borrows
    // the registry: densify one community of the served graph to near-
    // clique, re-plan the mutated decomposition, and pack the new plan's
    // static operands — the event loop's only remaining work is
    // validation plus pointer swaps.
    let (swap, old_fingerprint) = {
        let dep = registry.get("cora-gcn").expect("deployed");
        let community = dep.d.community.max(1);
        let mut overlay = CsrOverlay::new(dep.d.whole());
        let mut log = DeltaLog::new();
        let lo = community as u32;
        for u in lo..lo + community as u32 {
            for v in (u + 1)..lo + community as u32 {
                overlay.apply(&log.append(DeltaOp::InsertEdge { u, v, w: 0.3 })).unwrap();
            }
        }
        let matrix = overlay.to_csr();
        let d2 = Decomposition::from_propagation_ordered(&matrix, community);
        let bucket = engine
            .manifest
            .fit_bucket(d2.graph.n, d2.intra.nnz().max(d2.inter.nnz()))
            .expect("mutated graph still fits a bucket")
            .clone();
        let mut req = PlanRequest::new(&d2, ModelKind::Gcn, &bucket);
        req.graph_version = 1;
        let plan = SimCostPlanner::new(&A100).plan(&req).expect("replan");
        let (fwd_name, fwd_bucket, graph_ops) =
            trainer::plan_forward_operands(&engine.manifest, &d2, &plan, ModelKind::Gcn)
                .expect("pack swap operands");
        let swap = PlanSwap {
            plan,
            d: d2,
            graph_ops,
            fwd_name,
            fwd_bucket,
            new_rows: Vec::new(),
            new_labels: Vec::new(),
        };
        (swap, dep.plan.fingerprint)
    };

    let swaps_before = adaptgear::obs::snapshot()
        .counters
        .get("serve.swap.applied")
        .copied()
        .unwrap_or(0);
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_depth: 64,
    };
    let load = LoadGenConfig { requests: 48, clients: 6, seed: 9, ..Default::default() };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let swapper = client.clone();
    let gen = loadgen::spawn(client, "cora-gcn".to_string(), n, f_data, load);
    let swap_handle = std::thread::spawn(move || {
        // land the swap in-band with live traffic
        std::thread::sleep(Duration::from_millis(5));
        swapper.swap_plan("cora-gcn", swap)
    });
    let report = session.run().expect("serve loop");
    let summary = gen.join();
    let receipt = swap_handle.join().unwrap().expect("swap must apply");

    // the swap acknowledged with the NEW plan's fingerprint
    assert_eq!(receipt.deployment, "cora-gcn");
    assert_ne!(receipt.fingerprint, old_fingerprint);
    let swaps_after = adaptgear::obs::snapshot()
        .counters
        .get("serve.swap.applied")
        .copied()
        .unwrap_or(0);
    assert!(swaps_after > swaps_before, "serve.swap.applied must move");

    // the queue was never drained or rejected: every request offered
    // while the swap landed still got a real answer
    assert_eq!(summary.sent, 48);
    assert_eq!(summary.answered, 48, "no request may be dropped by a swap");
    assert_eq!(summary.shed, 0);
    assert_eq!(summary.failed, 0);
    assert_eq!(report.served, 48);

    // and the registry now serves the swapped plan
    let dep = registry.get("cora-gcn").expect("still deployed");
    assert_eq!(dep.plan.fingerprint, receipt.fingerprint);
    assert_eq!(dep.plan.graph_version, 1);
}

#[test]
fn serial_clients_still_get_answers_with_max_batch_one() {
    let Some(engine) = engine_or_skip() else { return };
    let mut registry = ModelRegistry::new();
    let (n, f_data) = deploy(&engine, &mut registry, "cora-gcn");

    // max_batch 1 = no coalescing: forwards == served
    let cfg = ServeConfig { max_batch: 1, max_wait: Duration::ZERO, queue_depth: 8 };
    let load = LoadGenConfig { requests: 6, clients: 1, seed: 2, ..Default::default() };
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, "cora-gcn".to_string(), n, f_data, load);
    let report = session.run().expect("serve loop");
    let summary = gen.join();

    assert_eq!(summary.answered, 6);
    assert_eq!(report.forward_calls, report.served);
    assert!((report.mean_occupancy - 1.0).abs() < 1e-12);
}
