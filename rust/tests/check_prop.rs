//! Property + mutation tests for `adaptgear check` (DESIGN.md Sec. 13).
//!
//! The property side pins the writer/checker contract from the outside:
//! every artifact the system persists through its public writers — a
//! plan via [`PlanStore::save`], a serialized [`DeltaLog`], a
//! [`BenchReport`] from each of the seven suites, a Chrome trace via
//! `obs::write_trace` — must come back from `check::run_all` with zero
//! Error diagnostics. The mutation side pins the other direction: for
//! each analyzer, corrupting exactly one invariant in an otherwise
//! clean artifact must surface the documented stable lint code.
//!
//! Tests share one process-wide lock: `obs` spans drain through a
//! global registry (`take_trace`), so the trace-writing test must not
//! race parallel tests whose library calls open spans mid-drain.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use adaptgear::bench::{self, BenchConfig, BenchReport};
use adaptgear::check::{self, CheckContext, CheckReport, Diagnostics, LintCode};
use adaptgear::coordinator::{pipeline, ModelKind};
use adaptgear::graph::datasets;
use adaptgear::graph::generate::planted_partition;
use adaptgear::gpusim::A100;
use adaptgear::partition::{Decomposition, Reorder};
use adaptgear::plan::{GearPlan, PlanRequest, PlanStore, Planner, SimCostPlanner};
use adaptgear::runtime::BucketInfo;
use adaptgear::stream::{DeltaLog, DeltaOp};
use adaptgear::util::json::{self, Json};
use adaptgear::util::rng::Rng;

/// Serializes the whole file: see module docs.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("adaptgear-checkprop-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bucket(vertices: usize, blocks: usize) -> BucketInfo {
    BucketInfo {
        name: format!("b{vertices}"),
        vertices,
        edges: 1 << 20,
        features: 32,
        hidden: 32,
        classes: 8,
        blocks,
    }
}

/// An anonymous plan (empty dataset label): tier-1 structural audit in
/// full, re-derivation skipped with AG000.
fn anonymous_plan(seed: u64) -> GearPlan {
    let g = planted_partition(256, 16, 0.5, 0.02, &mut Rng::new(seed));
    let prop = pipeline::propagation_for(ModelKind::Gcn);
    let d = Decomposition::build(&g, Reorder::Metis, prop, 16, seed);
    let b = bucket(256, 16);
    SimCostPlanner::new(&A100).plan(&PlanRequest::new(&d, ModelKind::Gcn, &b)).unwrap()
}

/// A fully labeled plan over a registered synthetic dataset: the
/// analyzer can rebuild the topology from `(dataset, scale, seed)` and
/// actually exercise the AG024/AG025 re-derivation tier.
fn labeled_plan() -> GearPlan {
    let spec = datasets::find("planted-mixed").unwrap();
    // 512 / 524288: exactly representable, so the scale survives the
    // JSON roundtrip bit-for-bit and the re-derived graph is identical.
    let scale = 512.0 / spec.vertices as f64;
    let data = spec.build_scaled(scale, 0);
    let d = Decomposition::build(
        &data.graph,
        Reorder::Metis,
        pipeline::propagation_for(ModelKind::Gcn),
        datasets::COMMUNITY,
        0,
    );
    let b = bucket(d.graph.n, d.graph.n / datasets::COMMUNITY);
    let req =
        PlanRequest::labeled(&d, ModelKind::Gcn, &b, "planted-mixed", scale, Reorder::Metis, 0);
    SimCostPlanner::new(&A100).plan(&req).unwrap()
}

fn sample_log() -> DeltaLog {
    let mut log = DeltaLog::new();
    log.append(DeltaOp::InsertEdge { u: 0, v: 5, w: 1.0 });
    log.append(DeltaOp::Reweight { u: 0, v: 5, w: 0.5 });
    log.append(DeltaOp::DeleteEdge { u: 2, v: 3 }); // no-op delete
    log.append(DeltaOp::AddVertices { count: 2 });
    log
}

fn codes(report: &CheckReport) -> Vec<&'static str> {
    report.diagnostics.iter().map(|d| d.code.code()).collect()
}

fn error_codes(report: &CheckReport) -> Vec<&'static str> {
    report
        .diagnostics
        .iter()
        .filter(|d| d.severity == check::Severity::Error)
        .map(|d| d.code.code())
        .collect()
}

fn ctx(artifacts: &Path) -> CheckContext {
    CheckContext {
        artifacts: artifacts.to_path_buf(),
        plans: artifacts.join("plans").is_dir(),
        traces: vec![],
        deltas: vec![],
        bench_dir: None,
        baseline: None,
    }
}

/// Rewrite one JSON file through `f` (parse, mutate, serialize).
fn mutate_json(path: &Path, f: impl FnOnce(&mut BTreeMap<String, Json>)) {
    let text = std::fs::read_to_string(path).unwrap();
    let mut doc = json::parse(&text).unwrap();
    let Json::Obj(map) = &mut doc else { panic!("{} is not an object", path.display()) };
    f(map);
    std::fs::write(path, json::write(&doc)).unwrap();
}

// ---------------------------------------------------------------------------
// Property: everything the system writes passes its own audit.
// ---------------------------------------------------------------------------

#[test]
fn every_written_artifact_passes_check_with_zero_errors() {
    let _g = lock();
    let root = tmpdir("clean");

    // Plans: one anonymous (re-derivation must skip, not fail), one
    // labeled (re-derivation must run and agree).
    let store = PlanStore::in_artifacts(&root);
    store.save(&anonymous_plan(1)).unwrap();
    let labeled = labeled_plan();
    assert!(!labeled.dataset.is_empty());
    store.save(&labeled).unwrap();

    // Delta log with all four op kinds, serialized to disk.
    let delta_path = root.join("deltas.json");
    std::fs::write(&delta_path, json::write(&sample_log().to_json())).unwrap();

    // All seven bench suites, quick profile, engine-free.
    let bench_dir = root.join("bench");
    let cfg = BenchConfig {
        quick: true,
        artifacts: root.join("no-such-artifacts").display().to_string(),
        out: bench_dir.clone(),
        seed: 7,
    };
    for suite in bench::SUITES {
        let report = bench::run_suite(suite, &cfg).unwrap();
        report.write_at(&bench_dir).unwrap();
    }

    // A real trace through the real exporter: nested spans + counters.
    // (Bench suites above ran before `install`, so only the spans below
    // are recorded; global counters ride along in the snapshot.)
    adaptgear::obs::install();
    {
        let _outer = adaptgear::obs::span("train.step");
        let _inner = adaptgear::obs::span("train.aggregate");
        adaptgear::obs::counter("check.prop.ticks").inc();
    }
    let trace_path = root.join("TRACE_check.json");
    adaptgear::obs::write_trace(&trace_path).unwrap();

    let report = check::run_all(
        &CheckContext {
            traces: vec![trace_path],
            deltas: vec![delta_path],
            bench_dir: Some(bench_dir),
            ..ctx(&root)
        },
        false,
    );
    assert_eq!(
        report.errors(),
        0,
        "fresh artifacts must audit clean:\n{}",
        report.render()
    );
    // The anonymous plan and the missing manifest must surface as
    // explicit Info skips, not silence.
    assert!(report.infos() > 0, "expected AG000 skips:\n{}", report.render());
    assert!(codes(&report).contains(&"AG000"));

    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// Mutations: one corrupted invariant per analyzer => its documented code.
// ---------------------------------------------------------------------------

#[test]
fn graph_mutation_duplicate_perm_entry_is_ag006() {
    let _g = lock();
    let g = planted_partition(128, 16, 0.4, 0.02, &mut Rng::new(3));
    let prop = pipeline::propagation_for(ModelKind::Gcn);
    let mut d = Decomposition::build(&g, Reorder::Metis, prop, 16, 3);
    d.perm[0] = d.perm[1]; // no longer a bijection
    let mut diags = Diagnostics::new("graph");
    check::graph::lint_decomposition(&d, &mut diags);
    assert!(
        diags.as_slice().iter().any(|x| x.code == LintCode::BadPermutation),
        "{:?}",
        diags.as_slice()
    );
}

#[test]
fn plan_mutation_bad_threshold_is_ag022() {
    let _g = lock();
    let root = tmpdir("plan-mut");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&anonymous_plan(2)).unwrap();
    assert_eq!(error_codes(&check::run_all(&ctx(&root), false)), Vec::<&str>::new());

    mutate_json(&path, |map| {
        let Some(Json::Obj(a)) = map.get_mut("assignment") else { panic!("no assignment") };
        a.insert("threshold".into(), Json::num(-1.0));
    });
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG022"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn plan_mutation_nondense_kernel_on_tile_plan_is_ag022() {
    let _g = lock();
    let root = tmpdir("plan-tile");
    let store = PlanStore::in_artifacts(&root);

    // A mid-density planted graph prices its dense class onto the
    // tile-sparse schedule; the clean pass proves `check` DECODES a
    // tile_sparse plan end to end (AG020 structural tier + the AG027
    // argmin-agreement audit over the persisted candidate costs).
    let n = 131072;
    let g = adaptgear::graph::generate::planted_partition_mixed(
        n,
        64,
        0.95,
        0.005,
        3,
        0.3 / n as f64,
        &mut Rng::new(5),
    );
    let d = Decomposition::build(
        &g,
        Reorder::Identity,
        pipeline::propagation_for(ModelKind::Gcn),
        64,
        0,
    );
    let b = BucketInfo {
        name: "b128k".to_string(),
        vertices: n,
        edges: 8 * 1024 * 1024,
        features: 32,
        hidden: 32,
        classes: 4,
        blocks: n / 64,
    };
    let plan =
        SimCostPlanner::new(&A100).plan(&PlanRequest::new(&d, ModelKind::Gcn, &b)).unwrap();
    assert!(plan.assignment.is_hybrid(), "mid-density graph must plan hybrid");
    assert!(
        plan.assignment.classes.iter().any(|c| c.kernel.as_str() == "tile_sparse"),
        "dense class must price onto the tile schedule"
    );
    let path = store.save(&plan).unwrap();
    let clean = check::run_all(&ctx(&root), false);
    assert_eq!(error_codes(&clean), Vec::<&str>::new(), "{}", clean.render());

    // One corrupted invariant: re-point the dense class at a kernel
    // outside the dense-class registry => AG022.
    mutate_json(&path, |map| {
        let Some(Json::Obj(a)) = map.get_mut("assignment") else { panic!("no assignment") };
        let Some(Json::Arr(classes)) = a.get_mut("classes") else { panic!("no classes") };
        let dense = classes
            .iter_mut()
            .find(|c| c.get("class").as_str() == Some("dense_intra"))
            .expect("hybrid plan carries a dense_intra class");
        let Json::Obj(cm) = dense else { panic!("class entry is not an object") };
        cm.insert("kernel".into(), Json::str("coo"));
    });
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG022"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn plan_mutation_feat_density_out_of_range_or_missing_is_ag035() {
    let _g = lock();
    let root = tmpdir("plan-featdensity");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&anonymous_plan(6)).unwrap();
    assert_eq!(error_codes(&check::run_all(&ctx(&root), false)), Vec::<&str>::new());

    // Out of range: a density above 1 can only come from a broken writer.
    mutate_json(&path, |map| {
        map.insert("feat_density".into(), Json::num(1.5));
    });
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG035"), "{}", report.render());

    // Missing entirely: the decoder is tolerant (defaults to dense), so
    // only the raw-document lint can catch a v4+ plan that dropped the
    // field — that is exactly what AG035 exists for.
    mutate_json(&path, |map| {
        map.remove("feat_density");
    });
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG035"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn plan_mutation_density_drift_is_ag036() {
    let _g = lock();
    let root = tmpdir("plan-drift");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&labeled_plan()).unwrap();

    // Claim near-zero feature density on a plan whose re-derivable
    // synthetic features are dense: the drift check must flag it. The
    // tampered field also breaks the v5 fingerprint (AG024 — density is
    // salted into it), which is why the drift lint runs BEFORE the
    // fingerprint gate.
    mutate_json(&path, |map| {
        map.insert("feat_density".into(), Json::num(0.01));
    });
    let report = check::run_all(&ctx(&root), false);
    let warns: Vec<&str> = report
        .diagnostics
        .iter()
        .filter(|d| d.severity == check::Severity::Warn)
        .map(|d| d.code.code())
        .collect();
    assert!(warns.contains(&"AG036"), "{}", report.render());
    assert!(error_codes(&report).contains(&"AG024"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn plan_mutation_renamed_file_is_ag021() {
    let _g = lock();
    let root = tmpdir("plan-rename");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&anonymous_plan(4)).unwrap();
    std::fs::rename(&path, store.dir().join("plan_0000000000000000.json")).unwrap();
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG021"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn plan_mutation_tampered_fingerprint_is_ag024() {
    let _g = lock();
    let root = tmpdir("plan-fp");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&labeled_plan()).unwrap();
    // Keep file name and fingerprint consistent (dodging AG021) but
    // point both at a fingerprint the labeled topology does not derive.
    mutate_json(&path, |map| {
        map.insert("fingerprint".into(), Json::str("00000000deadbeef"));
    });
    std::fs::rename(&path, store.dir().join("plan_00000000deadbeef.json")).unwrap();
    let report = check::run_all(&ctx(&root), false);
    assert!(error_codes(&report).contains(&"AG024"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn stream_mutation_version_gap_is_ag030() {
    let _g = lock();
    let root = tmpdir("stream-mut");
    let path = root.join("deltas.json");
    let doc = json::write(&sample_log().to_json());
    std::fs::write(&path, doc.replace(r#""version":"4""#, r#""version":"9""#)).unwrap();
    let report = check::run_all(
        &CheckContext { deltas: vec![path], ..ctx(&root) },
        false,
    );
    assert!(error_codes(&report).contains(&"AG030"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn obs_mutation_crossed_spans_are_ag040() {
    let _g = lock();
    let root = tmpdir("obs-mut");
    let path = root.join("TRACE_bad.json");
    std::fs::write(
        &path,
        r#"{"traceEvents":[
            {"cat":"adaptgear","name":"a","ph":"B","pid":1,"tid":1,"ts":1},
            {"cat":"adaptgear","name":"b","ph":"B","pid":1,"tid":1,"ts":2},
            {"cat":"adaptgear","name":"a","ph":"E","pid":1,"tid":1,"ts":3},
            {"cat":"adaptgear","name":"b","ph":"E","pid":1,"tid":1,"ts":4}]}"#,
    )
    .unwrap();
    let report = check::run_all(
        &CheckContext { traces: vec![path], ..ctx(&root) },
        false,
    );
    assert!(error_codes(&report).contains(&"AG040"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn bench_mutation_foreign_schema_is_ag060() {
    let _g = lock();
    let root = tmpdir("bench-mut");
    let mut r = BenchReport::new("kernels", true);
    r.push("spmm/a", 10.0, "us", bench::Direction::Lower);
    let path = r.write_at(&root).unwrap();
    mutate_json(&path, |map| {
        map.insert("schema_version".into(), Json::num(99.0));
    });
    let report = check::run_all(
        &CheckContext { bench_dir: Some(root.clone()), ..ctx(&root) },
        false,
    );
    assert!(error_codes(&report).contains(&"AG060"), "{}", report.render());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn deny_warn_promotes_baseline_drift_to_exit_failure() {
    let _g = lock();
    let root = tmpdir("deny-warn");
    let (base, cur) = (root.join("base"), root.join("cur"));
    let mut old = BenchReport::new("kernels", true);
    old.push("spmm/a", 10.0, "us", bench::Direction::Lower);
    old.push("spmm/vanishing", 5.0, "us", bench::Direction::Lower);
    old.write_at(&base).unwrap();
    let mut new = BenchReport::new("kernels", true);
    new.push("spmm/a", 10.0, "us", bench::Direction::Lower);
    new.write_at(&cur).unwrap();

    let relaxed = check::run_all(
        &CheckContext { bench_dir: Some(cur.clone()), baseline: Some(base.clone()), ..ctx(&root) },
        false,
    );
    assert_eq!(relaxed.errors(), 0, "{}", relaxed.render());
    assert!(codes(&relaxed).contains(&"AG061"));
    assert!(relaxed.warnings() > 0);

    let denied = check::run_all(
        &CheckContext { bench_dir: Some(cur), baseline: Some(base), ..ctx(&root) },
        true,
    );
    assert!(denied.errors() > 0, "--deny warn must promote AG061");
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// CLI exit-code contract: the exact behavior ci.sh check_smoke gates on.
// ---------------------------------------------------------------------------

#[test]
fn check_cli_exits_zero_on_clean_store_and_nonzero_after_corruption() {
    let _g = lock();
    let root = tmpdir("cli");
    let store = PlanStore::in_artifacts(&root);
    let path = store.save(&anonymous_plan(5)).unwrap();

    let run = |tag: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_adaptgear"))
            .current_dir(&root) // hermetic TRACE_*/BENCH_* discovery
            .args([
                "check",
                "--artifacts",
                root.to_str().unwrap(),
                "--out",
                root.join(format!("CHECK_{tag}.json")).to_str().unwrap(),
            ])
            .output()
            .expect("spawning the adaptgear binary")
    };

    let clean = run("clean");
    assert!(
        clean.status.success(),
        "clean store must exit zero:\n{}{}",
        String::from_utf8_lossy(&clean.stdout),
        String::from_utf8_lossy(&clean.stderr)
    );
    let text = std::fs::read_to_string(root.join("CHECK_clean.json")).unwrap();
    assert_eq!(json::parse(&text).unwrap().get("totals").get("errors").as_usize(), Some(0));

    mutate_json(&path, |map| {
        let Some(Json::Obj(a)) = map.get_mut("assignment") else { panic!("no assignment") };
        a.insert("threshold".into(), Json::num(-1.0));
    });
    let broken = run("broken");
    assert!(!broken.status.success(), "corrupt plan must exit non-zero");
    let stdout = String::from_utf8_lossy(&broken.stdout);
    assert!(stdout.contains("AG022"), "stdout must carry the lint code:\n{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}
