//! End-to-end tests of the `adaptgear bench` check/validate CLI — the
//! exact exit-code contract `./ci.sh bench` and the GitHub workflow gate
//! on — plus a JSON roundtrip property test over randomized reports.
//!
//! The CLI tests fabricate reports through the library API (no timing,
//! so they are fully deterministic) and drive the real binary via
//! `CARGO_BIN_EXE_adaptgear`.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use adaptgear::bench::{BenchReport, Direction};
use adaptgear::util::{json, prop};
use adaptgear::util::rng::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("adaptgear-benchcli-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_report(dir: &Path, suite: &str, metrics: &[(&str, f64)]) {
    let mut r = BenchReport::new(suite, true);
    for &(name, value) in metrics {
        r.push(name, value, "us", Direction::Lower);
    }
    r.write_at(dir).unwrap();
}

fn bench(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_adaptgear"))
        .arg("bench")
        .args(args)
        .output()
        .expect("spawning the adaptgear binary")
}

fn check(baseline: &Path, current: &Path, extra: &[&str]) -> Output {
    let mut args = vec![
        "--check",
        "--suite",
        "kernels",
        "--baseline",
        baseline.to_str().unwrap(),
        "--out",
        current.to_str().unwrap(),
    ];
    args.extend_from_slice(extra);
    bench(&args)
}

#[test]
fn check_passes_on_identical_reports() {
    let root = tmpdir("identical");
    let (base, cur) = (root.join("base"), root.join("cur"));
    for dir in [&base, &cur] {
        write_report(dir, "kernels", &[("spmm/a", 100.0), ("spmm/b", 5.0)]);
    }
    let out = check(&base, &cur, &[]);
    assert!(
        out.status.success(),
        "identical reports must pass: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("bench check passed"));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn check_fails_on_injected_2x_regression() {
    // The acceptance shape: current is 2x worse than baseline on a
    // lower-is-better metric — far past the default tolerance.
    let root = tmpdir("regression");
    let (base, cur) = (root.join("base"), root.join("cur"));
    write_report(&base, "kernels", &[("spmm/hot", 100.0)]);
    write_report(&cur, "kernels", &[("spmm/hot", 200.0)]);
    let out = check(&base, &cur, &[]);
    assert!(!out.status.success(), "2x regression must exit non-zero");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("spmm/hot"), "report must name the metric: {stdout}");
    assert!(stdout.contains("REGR"), "{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn check_respects_the_tolerance_flag() {
    let root = tmpdir("tolerance");
    let (base, cur) = (root.join("base"), root.join("cur"));
    write_report(&base, "kernels", &[("spmm/hot", 100.0)]);
    write_report(&cur, "kernels", &[("spmm/hot", 140.0)]);
    // 40% worse: passes the default 50%, fails an explicit 25%
    assert!(check(&base, &cur, &[]).status.success());
    assert!(!check(&base, &cur, &["--tolerance", "0.25"]).status.success());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn check_fails_on_schema_version_mismatch() {
    let root = tmpdir("schema");
    let (base, cur) = (root.join("base"), root.join("cur"));
    write_report(&cur, "kernels", &[("spmm/a", 1.0)]);
    std::fs::create_dir_all(&base).unwrap();
    std::fs::write(
        base.join("BENCH_kernels.json"),
        r#"{"schema_version":99,"suite":"kernels","quick":true,"context":{},"metrics":[]}"#,
    )
    .unwrap();
    let out = check(&base, &cur, &[]);
    assert!(!out.status.success(), "old-schema baseline must fail the check");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("schema version mismatch"), "{stderr}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn check_without_baseline_file_skips_with_message() {
    let root = tmpdir("nobaseline");
    let (base, cur) = (root.join("base"), root.join("cur"));
    std::fs::create_dir_all(&base).unwrap();
    write_report(&cur, "kernels", &[("spmm/a", 1.0)]);
    let out = check(&base, &cur, &[]);
    assert!(out.status.success(), "missing baseline is a skip, not a failure");
    assert!(String::from_utf8_lossy(&out.stdout).contains("no baseline file"));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn validate_accepts_good_and_rejects_corrupt_reports() {
    let root = tmpdir("validate");
    write_report(&root, "kernels", &[("spmm/a", 1.0)]);
    let out = bench(&["--validate", "--suite", "kernels", "--out", root.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("BENCH_kernels.json"));

    // corrupt the file: validation must now fail
    std::fs::write(root.join("BENCH_kernels.json"), "{not json").unwrap();
    let out = bench(&["--validate", "--suite", "kernels", "--out", root.to_str().unwrap()]);
    assert!(!out.status.success());

    // and a report claiming the wrong suite is rejected too
    write_report(&root, "plan", &[]);
    std::fs::rename(root.join("BENCH_plan.json"), root.join("BENCH_kernels.json")).unwrap();
    let out = bench(&["--validate", "--suite", "kernels", "--out", root.to_str().unwrap()]);
    assert!(!out.status.success());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn unknown_suite_is_rejected() {
    let out = bench(&["--validate", "--suite", "nope"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--suite"));
}

// ---------------------------------------------------------------------------
// Property: every representable report survives the JSON roundtrip exactly.
// ---------------------------------------------------------------------------

fn random_report(rng: &mut Rng) -> BenchReport {
    let suites = ["kernels", "plan", "train", "serve", "figures"];
    let units = ["us", "ms", "rps", "x", "frac", ""];
    let directions = [Direction::Lower, Direction::Higher, Direction::None];
    let mut r = BenchReport::new(suites[rng.usize_below(suites.len())], rng.below(2) == 1);
    if rng.below(2) == 1 {
        // exercise string escaping in context values
        r.note("workload", "n=2048 \"quoted\" \\ caf\u{e9} \u{2713}\n tab\t");
    }
    for i in 0..rng.usize_below(8) {
        let value = match rng.below(4) {
            0 => 0.0,
            1 => rng.normal() * 1e6,
            2 => -(rng.f64() * 1e-9),
            _ => rng.f64() * 1e12,
        };
        r.push(
            format!("m{i}/{}", ["lat", "thr", "q"][rng.usize_below(3)]),
            value,
            units[rng.usize_below(units.len())],
            directions[rng.usize_below(directions.len())],
        );
    }
    r
}

#[test]
fn report_json_roundtrip_property() {
    prop::check("bench report JSON roundtrip", 200, |rng| {
        let r = random_report(rng);
        let text = json::write(&r.to_json());
        let back = BenchReport::from_json(
            &json::parse(&text).map_err(|e| format!("reparse failed: {e}"))?,
        )
        .map_err(|e| format!("decode failed: {e:#}"))?;
        prop::require(back == r, "report != roundtripped report")?;
        // and the canonical text is a fixed point
        prop::require(
            json::write(&back.to_json()) == text,
            "canonical JSON text not a fixed point",
        )
    });
}
