//! Integration: every AOT Pallas kernel artifact must agree with the
//! native Rust kernel schedules through the real PJRT path — the
//! cross-layer correctness contract (L1 Pallas == L3 native).
//!
//! Requires `make artifacts` (skips gracefully when absent, so plain
//! `cargo test` works before artifacts are built).

use adaptgear::graph::generate::planted_partition;
use adaptgear::kernels::pack;
use adaptgear::kernels::{native, KernelKind};
use adaptgear::partition::{Decomposition, Propagation, Reorder};
use adaptgear::runtime::{Engine, Manifest};
use adaptgear::util::rng::Rng;

fn engine_or_skip() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn random_decomposition(n: usize, seed: u64, density: (f64, f64)) -> Decomposition {
    let mut rng = Rng::new(seed);
    let g = planted_partition(n, 16, density.0, density.1, &mut rng);
    Decomposition::build(&g, Reorder::Metis, Propagation::GcnNormalized, 16, seed)
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn pallas_kernels_match_native_on_every_bucket() {
    let Some(engine) = engine_or_skip() else { return };
    for bucket in engine.manifest.buckets.values() {
        let n = bucket.vertices / 2;
        let d = random_decomposition(n, 42 + bucket.vertices as u64, (0.12, 0.004));
        let f = bucket.features;
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        let x_packed = pack::pack_features(&x, n, f, bucket).unwrap();

        // padded-x native reference helper
        let xp = x_packed.as_f32().unwrap();

        for (kind, matrix) in [
            (KernelKind::CsrIntra, &d.intra),
            (KernelKind::DenseBlock, &d.intra),
            (KernelKind::CsrInter, &d.inter),
            (KernelKind::Coo, &d.inter),
        ] {
            let name = Manifest::kernel_name(kind.as_str(), &bucket.name);
            let mut ops = pack::pack_kernel_operands(kind, matrix, 16, bucket).unwrap();
            ops.push(x_packed.clone());
            let out = engine.run(&name, &ops).unwrap();
            let y: Vec<f32> = out[0].to_vec().unwrap();

            let expect = match kind {
                KernelKind::CsrInter => native::csr_inter_spmm(matrix, &x, f),
                KernelKind::CsrIntra => native::csr_intra_spmm(matrix, &x, f, 16),
                KernelKind::Coo => native::coo_spmm(n, &matrix.to_triplets(), &x, f),
                KernelKind::DenseBlock => {
                    let blocks =
                        adaptgear::graph::DenseBlocks::from_block_diagonal_csr(matrix, 16);
                    native::dense_block_spmm(&blocks, &x, f)
                }
                KernelKind::DenseFull => unreachable!(),
            };
            // compare the real (unpadded) rows
            let err = max_err(&y[..n * f], &expect);
            assert!(err < 1e-3, "{name}: max err {err}");
            // padded rows must be exactly zero
            assert!(
                y[n * f..].iter().all(|&v| v == 0.0),
                "{name}: nonzero output in padding"
            );
            // sanity: packed x preserved real rows
            assert_eq!(&xp[..n * f], &x[..]);
        }
    }
}

#[test]
fn decomposed_pair_sums_to_whole_through_pjrt() {
    let Some(engine) = engine_or_skip() else { return };
    let bucket = engine.manifest.buckets.values().min_by_key(|b| b.vertices).unwrap();
    let n = bucket.vertices / 2;
    let d = random_decomposition(n, 99, (0.15, 0.008));
    let f = bucket.features;
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
    let x_packed = pack::pack_features(&x, n, f, bucket).unwrap();

    // intra via dense_block + inter via coo, summed
    let mut intra_ops =
        pack::pack_kernel_operands(KernelKind::DenseBlock, &d.intra, 16, bucket).unwrap();
    intra_ops.push(x_packed.clone());
    let mut inter_ops = pack::pack_kernel_operands(KernelKind::Coo, &d.inter, 16, bucket).unwrap();
    inter_ops.push(x_packed.clone());

    let yi: Vec<f32> = engine
        .run(&Manifest::kernel_name("dense_block", &bucket.name), &intra_ops)
        .unwrap()[0]
        .to_vec()
        .unwrap();
    let yj: Vec<f32> = engine
        .run(&Manifest::kernel_name("coo", &bucket.name), &inter_ops)
        .unwrap()[0]
        .to_vec()
        .unwrap();
    let got: Vec<f32> = yi.iter().zip(&yj).map(|(a, b)| a + b).collect();

    let expect = d.whole().spmm(&x, f);
    let err = max_err(&got[..n * f], &expect);
    assert!(err < 1e-3, "decomposed sum != whole: {err}");
}

#[test]
fn empty_subgraph_artifacts_return_zero() {
    let Some(engine) = engine_or_skip() else { return };
    let bucket = engine.manifest.buckets.values().min_by_key(|b| b.vertices).unwrap();
    let v = bucket.vertices;
    let e = bucket.edges;
    let f = bucket.features;
    let x: Vec<f32> = (0..v * f).map(|i| (i % 13) as f32).collect();
    let args = vec![
        adaptgear::runtime::Tensor::i32(vec![0; e], &[e]),
        adaptgear::runtime::Tensor::i32(vec![0; e], &[e]),
        adaptgear::runtime::Tensor::f32(vec![0.0; e], &[e]),
        adaptgear::runtime::Tensor::f32(x, &[v, f]),
    ];
    let out = engine.run(&Manifest::kernel_name("coo", &bucket.name), &args).unwrap();
    let y: Vec<f32> = out[0].to_vec().unwrap();
    assert!(y.iter().all(|&v| v == 0.0));
}

#[test]
fn engine_rejects_wrong_operands() {
    let Some(engine) = engine_or_skip() else { return };
    let bucket = engine.manifest.buckets.values().min_by_key(|b| b.vertices).unwrap();
    let name = Manifest::kernel_name("coo", &bucket.name);
    // wrong arity
    assert!(engine.run(&name, &[]).is_err());
    // wrong dtype in slot 0
    let e = bucket.edges;
    let v = bucket.vertices;
    let f = bucket.features;
    let bad = vec![
        adaptgear::runtime::Tensor::f32(vec![0.0; e], &[e]), // should be i32
        adaptgear::runtime::Tensor::i32(vec![0; e], &[e]),
        adaptgear::runtime::Tensor::f32(vec![0.0; e], &[e]),
        adaptgear::runtime::Tensor::f32(vec![0.0; v * f], &[v, f]),
    ];
    assert!(engine.run(&name, &bad).is_err());
}
