//! Native (CPU) 2-layer GCN — forward, masked cross-entropy, and a
//! hand-derived backward pass.
//!
//! Mirrors `python/compile/model.py::gcn_forward` exactly:
//! `logits = A relu(A (X W1) + b1) W2 + b2` with mean masked softmax
//! cross-entropy, Glorot-uniform matrix init and zero biases. Two uses:
//!
//! * the **native sampled-training backend** — `train --sampled` runs
//!   end to end on a bare checkout (no PJRT artifacts), executing each
//!   batch's aggregation through the plan's class assignment
//!   ([`crate::kernels::native::AssignmentExec`]);
//! * the **sampled-vs-full equivalence property tests**, which need one
//!   forward definition shared by both sides.
//!
//! The aggregate is injected as two closures (`agg` for `A·`, `agg_t`
//! for `Aᵀ·` in the backward pass) because sampled batch matrices are
//! NOT symmetric — only the rows the sampler completed are present.

use crate::util::rng::Rng;

/// `[n,k] @ [k,m]` row-major.
pub fn matmul(x: &[f32], n: usize, k: usize, w: &[f32], m: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), k * m);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        for p in 0..k {
            let xv = x[i * k + p];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[p * m..(p + 1) * m];
            let orow = &mut out[i * m..(i + 1) * m];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// `xᵀ @ y`: `[n,k]ᵀ [n,m] -> [k,m]` (weight gradients).
fn matmul_tn(x: &[f32], n: usize, k: usize, y: &[f32], m: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(y.len(), n * m);
    let mut out = vec![0.0f32; k * m];
    for i in 0..n {
        for p in 0..k {
            let xv = x[i * k + p];
            if xv == 0.0 {
                continue;
            }
            let yrow = &y[i * m..(i + 1) * m];
            let orow = &mut out[p * m..(p + 1) * m];
            for (o, &yv) in orow.iter_mut().zip(yrow) {
                *o += xv * yv;
            }
        }
    }
    out
}

/// `x @ wᵀ`: `[n,m] [k,m]ᵀ -> [n,k]` (activation gradients).
fn matmul_nt(x: &[f32], n: usize, m: usize, w: &[f32], k: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * m);
    debug_assert_eq!(w.len(), k * m);
    let mut out = vec![0.0f32; n * k];
    for i in 0..n {
        let xrow = &x[i * m..(i + 1) * m];
        for p in 0..k {
            let wrow = &w[p * m..(p + 1) * m];
            let mut acc = 0.0f32;
            for (&xv, &wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            out[i * k + p] = acc;
        }
    }
    out
}

/// How the hidden activation treats the feature dimension.
///
/// `TopK(k)` fuses a MaxK-style selection into the nonlinearity: after
/// ReLU, each row keeps only its `k` largest lanes (lower index wins
/// ties) and zeroes the rest, so the second aggregation runs at feature
/// density `k / h`. `TopK(k >= h)` is exactly `Dense` — every lane
/// survives — and the trainer relies on that being bitwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatMode {
    /// Plain ReLU: every hidden lane propagates.
    Dense,
    /// ReLU then keep the top-`k` lanes per row, zero the rest.
    TopK(usize),
}

/// Zero every lane of each `f`-wide row except its `k` largest by value
/// (ties break toward the lower index — the same deterministic rule as
/// [`crate::kernels::native::SparseFeat::from_dense`]).
pub fn topk_mask_rows(x: &mut [f32], f: usize, k: usize) {
    if k >= f {
        return;
    }
    let mut order: Vec<u32> = Vec::with_capacity(f);
    for row in x.chunks_mut(f) {
        order.clear();
        order.extend(0..f as u32);
        order.sort_by(|&a, &b| {
            row[b as usize]
                .partial_cmp(&row[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        for &c in &order[k..] {
            row[c as usize] = 0.0;
        }
    }
}

/// A 2-layer GCN's parameters on the host.
#[derive(Debug, Clone)]
pub struct GcnModel {
    pub f: usize,
    pub h: usize,
    pub c: usize,
    /// Hidden-activation mode: dense ReLU or fused top-k selection.
    pub feat_mode: FeatMode,
    /// `[f, h]`
    pub w1: Vec<f32>,
    /// `[h]`
    pub b1: Vec<f32>,
    /// `[h, c]`
    pub w2: Vec<f32>,
    /// `[c]`
    pub b2: Vec<f32>,
}

impl GcnModel {
    /// Glorot-uniform matrices, zero biases — the same scheme (and the
    /// same seed salt) as the PJRT trainer's `init_param`.
    pub fn init(f: usize, h: usize, c: usize, seed: u64) -> GcnModel {
        let mut rng = Rng::new(seed ^ 0x9a9a);
        let mut glorot = |rows: usize, cols: usize| -> Vec<f32> {
            let scale = (6.0 / (rows + cols) as f64).sqrt() as f32;
            (0..rows * cols).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
        };
        let w1 = glorot(f, h);
        let w2 = glorot(h, c);
        GcnModel {
            f,
            h,
            c,
            feat_mode: FeatMode::Dense,
            w1,
            b1: vec![0.0; h],
            w2,
            b2: vec![0.0; c],
        }
    }

    /// Builder: set the hidden-activation feature mode.
    pub fn with_feat_mode(mut self, mode: FeatMode) -> GcnModel {
        self.feat_mode = mode;
        self
    }

    /// `logits = agg(relu(agg(x W1) + b1) W2) + b2`, `x` is `[n, f]`.
    pub fn forward<A: Fn(&[f32], usize) -> Vec<f32>>(
        &self,
        agg: A,
        x: &[f32],
        n: usize,
    ) -> Vec<f32> {
        let (h1r, _) = self.forward_hidden(&agg, x, n);
        let mut z = agg(&matmul(&h1r, n, self.h, &self.w2, self.c), self.c);
        for row in z.chunks_mut(self.c) {
            for (v, &b) in row.iter_mut().zip(&self.b2) {
                *v += b;
            }
        }
        z
    }

    /// Shared front half: returns `(masked relu(h1), h1-pre-relu)`. Under
    /// [`FeatMode::TopK`] the first component additionally zeroes every
    /// lane outside each row's top-k; `k >= h` short-circuits so the
    /// dense path's exact float sequence is preserved bitwise.
    fn forward_hidden<A: Fn(&[f32], usize) -> Vec<f32>>(
        &self,
        agg: &A,
        x: &[f32],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        debug_assert_eq!(x.len(), n * self.f);
        let mut h1 = agg(&matmul(x, n, self.f, &self.w1, self.h), self.h);
        for row in h1.chunks_mut(self.h) {
            for (v, &b) in row.iter_mut().zip(&self.b1) {
                *v += b;
            }
        }
        let mut h1r: Vec<f32> = h1.iter().map(|&v| v.max(0.0)).collect();
        if let FeatMode::TopK(k) = self.feat_mode {
            topk_mask_rows(&mut h1r, self.h, k);
        }
        (h1r, h1)
    }

    /// Mean masked softmax cross-entropy over `logits [n, c]` (the
    /// `masked_ce` of `python/compile/model.py`).
    pub fn masked_ce(&self, logits: &[f32], labels: &[i32], mask: &[f32]) -> f32 {
        let n = labels.len();
        let denom = mask.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f64;
        for i in 0..n {
            if mask[i] == 0.0 {
                continue;
            }
            let row = &logits[i * self.c..(i + 1) * self.c];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let logz = row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln();
            let y = (labels[i].rem_euclid(self.c as i32)) as usize;
            let ll = (row[y] - max) as f64 - logz;
            loss -= ll * mask[i] as f64;
        }
        (loss / denom as f64) as f32
    }

    /// One SGD step: forward, masked CE, hand-derived backward, in-place
    /// parameter update. `agg` applies `A·`, `agg_t` applies `Aᵀ·`; the
    /// two must be genuine transposes of each other. Returns the loss
    /// BEFORE the update (matching the PJRT train-step artifact).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step<A, T>(
        &mut self,
        agg: A,
        agg_t: T,
        x: &[f32],
        n: usize,
        labels: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> f32
    where
        A: Fn(&[f32], usize) -> Vec<f32>,
        T: Fn(&[f32], usize) -> Vec<f32>,
    {
        let (h1r, h1) = self.forward_hidden(&agg, x, n);
        let h1w2 = matmul(&h1r, n, self.h, &self.w2, self.c);
        let mut z = agg(&h1w2, self.c);
        for row in z.chunks_mut(self.c) {
            for (v, &b) in row.iter_mut().zip(&self.b2) {
                *v += b;
            }
        }
        let loss = self.masked_ce(&z, labels, mask);

        // dL/dz: (softmax - onehot) * mask / denom
        let denom = mask.iter().sum::<f32>().max(1.0);
        let mut dz = vec![0.0f32; n * self.c];
        for i in 0..n {
            if mask[i] == 0.0 {
                continue;
            }
            let row = &z[i * self.c..(i + 1) * self.c];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = row.iter().map(|&v| ((v - max) as f64).exp()).collect();
            let sum: f64 = exps.iter().sum();
            let y = (labels[i].rem_euclid(self.c as i32)) as usize;
            let drow = &mut dz[i * self.c..(i + 1) * self.c];
            for (j, d) in drow.iter_mut().enumerate() {
                let p = (exps[j] / sum) as f32;
                let onehot = if j == y { 1.0 } else { 0.0 };
                *d = (p - onehot) * mask[i] / denom;
            }
        }

        // z = agg(h1r W2) + b2
        let db2: Vec<f32> = (0..self.c)
            .map(|j| (0..n).map(|i| dz[i * self.c + j]).sum())
            .collect();
        let dm2 = agg_t(&dz, self.c); // d(h1r W2)
        let dw2 = matmul_tn(&h1r, n, self.h, &dm2, self.c);
        let dh1r = matmul_nt(&dm2, n, self.c, &self.w2, self.h);
        // relu gate on the pre-activation (bias included), AND'd with the
        // top-k selection: a dropped lane contributed a literal zero
        // forward, so its subgradient is zero. Under FeatMode::Dense
        // `kept > 0.0` is exactly `pre > 0.0` (kept = max(pre, 0)), so the
        // dense gradient is unchanged bitwise.
        let dh1: Vec<f32> = dh1r
            .iter()
            .zip(h1r.iter().zip(&h1))
            .map(|(&g, (&kept, &pre))| if kept > 0.0 && pre > 0.0 { g } else { 0.0 })
            .collect();
        let db1: Vec<f32> = (0..self.h)
            .map(|j| (0..n).map(|i| dh1[i * self.h + j]).sum())
            .collect();
        // h1 = agg(x W1) + b1
        let dn = agg_t(&dh1, self.h);
        let dw1 = matmul_tn(x, n, self.f, &dn, self.h);

        let sgd = |p: &mut [f32], g: &[f32]| {
            for (v, &d) in p.iter_mut().zip(g) {
                *v -= lr * d;
            }
        };
        sgd(&mut self.w1, &dw1);
        sgd(&mut self.b1, &db1);
        sgd(&mut self.w2, &dw2);
        sgd(&mut self.b2, &db2);
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::graph::Csr;

    fn setup(seed: u64) -> (Csr, Csr, usize) {
        let mut rng = Rng::new(seed);
        let g = planted_partition(64, 16, 0.4, 0.03, &mut rng);
        let a = Csr::gcn_normalized(&g);
        let at = a.transpose();
        (a, at, 64)
    }

    #[test]
    fn matmul_shapes_and_values() {
        // [2,3] @ [3,2]
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, 2, 3, &w, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
        // transpose identities: (xᵀ y)[p,j] and (x wᵀ)
        let t = matmul_tn(&x, 2, 3, &[1.0, 0.0, 0.0, 1.0], 2);
        assert_eq!(t.len(), 3 * 2);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        let nt = matmul_nt(&[1.0, 0.0, 0.0, 1.0], 2, 2, &[3.0, 4.0, 5.0, 6.0], 2);
        assert_eq!(nt, vec![3.0, 5.0, 4.0, 6.0]);
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = GcnModel::init(8, 16, 4, 7);
        let b = GcnModel::init(8, 16, 4, 7);
        assert_eq!(a.w1, b.w1);
        assert_eq!(a.w2, b.w2);
        assert!(a.b1.iter().all(|&v| v == 0.0));
        let scale = (6.0f64 / (8 + 16) as f64).sqrt() as f32;
        assert!(a.w1.iter().all(|&v| v.abs() <= scale + 1e-6));
    }

    #[test]
    fn loss_decreases_under_training() {
        let (a, at, n) = setup(3);
        let mut rng = Rng::new(11);
        let f = 8;
        let labels: Vec<i32> = (0..n).map(|v| (v / 16) as i32 % 4).collect();
        // class-indicative features so there is signal to fit
        let x: Vec<f32> = (0..n * f)
            .map(|i| {
                let (v, j) = (i / f, i % f);
                let signal = if j % 4 == labels[v] as usize % 4 { 1.0 } else { 0.0 };
                signal + 0.2 * rng.normal_f32()
            })
            .collect();
        let mask = vec![1.0f32; n];
        let mut model = GcnModel::init(f, 16, 4, 0);
        let agg = |t: &[f32], w: usize| a.spmm(t, w);
        let agg_t = |t: &[f32], w: usize| at.spmm(t, w);
        let first = model.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.2);
        let mut last = first;
        for _ in 0..60 {
            last = model.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.2);
        }
        assert!(last.is_finite());
        assert!(
            last < first * 0.9,
            "loss did not decrease: first {first}, last {last}"
        );
    }

    #[test]
    fn topk_mask_keeps_k_largest_with_lower_index_ties() {
        let mut x = vec![3.0, 1.0, 3.0, 2.0, /* row 2 */ 0.0, 5.0, 4.0, 5.0];
        topk_mask_rows(&mut x, 4, 2);
        assert_eq!(x, vec![3.0, 0.0, 3.0, 0.0, 0.0, 5.0, 0.0, 5.0]);
        // k >= f is the identity
        let mut y = vec![1.0, 2.0];
        topk_mask_rows(&mut y, 2, 5);
        assert_eq!(y, vec![1.0, 2.0]);
    }

    #[test]
    fn topk_full_width_is_bitwise_dense() {
        let (a, at, n) = setup(13);
        let f = 6;
        let x: Vec<f32> = {
            let mut rng = Rng::new(4);
            (0..n * f).map(|_| rng.normal_f32()).collect()
        };
        let labels: Vec<i32> = (0..n).map(|v| (v % 3) as i32).collect();
        let mask = vec![1.0f32; n];
        let agg = |t: &[f32], w: usize| a.spmm(t, w);
        let agg_t = |t: &[f32], w: usize| at.spmm(t, w);
        let mut dense = GcnModel::init(f, 8, 3, 2);
        let mut topk = GcnModel::init(f, 8, 3, 2).with_feat_mode(FeatMode::TopK(8));
        assert_eq!(dense.forward(agg, &x, n), topk.forward(agg, &x, n));
        for _ in 0..3 {
            let ld = dense.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.1);
            let lt = topk.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.1);
            assert_eq!(ld.to_bits(), lt.to_bits());
        }
        assert_eq!(dense.w1, topk.w1);
        assert_eq!(dense.b1, topk.b1);
        assert_eq!(dense.w2, topk.w2);
        assert_eq!(dense.b2, topk.b2);
    }

    #[test]
    fn topk_bounds_active_lanes_and_still_learns() {
        let (a, at, n) = setup(3);
        let mut rng = Rng::new(11);
        let f = 8;
        let h = 16;
        let k = 4;
        let labels: Vec<i32> = (0..n).map(|v| (v / 16) as i32 % 4).collect();
        let x: Vec<f32> = (0..n * f)
            .map(|i| {
                let (v, j) = (i / f, i % f);
                let signal = if j % 4 == labels[v] as usize % 4 { 1.0 } else { 0.0 };
                signal + 0.2 * rng.normal_f32()
            })
            .collect();
        let mask = vec![1.0f32; n];
        let mut model = GcnModel::init(f, h, 4, 0).with_feat_mode(FeatMode::TopK(k));
        let agg = |t: &[f32], w: usize| a.spmm(t, w);
        let agg_t = |t: &[f32], w: usize| at.spmm(t, w);
        let (h1r, _) = model.forward_hidden(&agg, &x, n);
        for row in h1r.chunks(h) {
            assert!(row.iter().filter(|&&v| v != 0.0).count() <= k);
        }
        let first = model.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.2);
        let mut last = first;
        for _ in 0..60 {
            last = model.train_step(&agg, &agg_t, &x, n, &labels, &mask, 0.2);
        }
        assert!(last.is_finite());
        assert!(last < first * 0.9, "top-k loss stuck: first {first}, last {last}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Spot-check the hand-derived backward on a tiny instance.
        let (a, at, n) = setup(5);
        let f = 4;
        let labels: Vec<i32> = (0..n).map(|v| (v % 3) as i32).collect();
        let mut mask = vec![0.0f32; n];
        for m in mask.iter_mut().take(20) {
            *m = 1.0;
        }
        let x: Vec<f32> = {
            let mut rng = Rng::new(2);
            (0..n * f).map(|_| rng.normal_f32()).collect()
        };
        let agg = |t: &[f32], w: usize| a.spmm(t, w);
        let agg_t = |t: &[f32], w: usize| at.spmm(t, w);
        let model0 = GcnModel::init(f, 6, 3, 1);
        let loss_of = |m: &GcnModel| {
            let z = m.forward(agg, &x, n);
            m.masked_ce(&z, &labels, &mask)
        };
        // analytic gradient via one SGD step with tiny lr: dW ≈ (W - W') / lr
        let lr = 1e-3f32;
        let mut stepped = model0.clone();
        stepped.train_step(&agg, &agg_t, &x, n, &labels, &mask, lr);
        // numeric gradient on a few w1/w2 coordinates
        let eps = 1e-2f32;
        for &(mat, idx) in &[(0usize, 0usize), (0, 5), (1, 0), (1, 7)] {
            let mut plus = model0.clone();
            let mut minus = model0.clone();
            {
                let (p, m) = if mat == 0 {
                    (&mut plus.w1[idx], &mut minus.w1[idx])
                } else {
                    (&mut plus.w2[idx], &mut minus.w2[idx])
                };
                *p += eps;
                *m -= eps;
            }
            let numeric = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
            let analytic = if mat == 0 {
                (model0.w1[idx] - stepped.w1[idx]) / lr
            } else {
                (model0.w2[idx] - stepped.w2[idx]) / lr
            };
            assert!(
                (numeric - analytic).abs() < 2e-2 + 0.2 * numeric.abs(),
                "grad mismatch (mat {mat} idx {idx}): numeric {numeric} analytic {analytic}"
            );
        }
    }
}
