//! Subgraph-level kernels: taxonomy and the candidate registry
//! ([`spec`]), native CPU executions mirroring the GPU schedules
//! ([`native`]), `16x16` MMA tile extraction for the tile-sparse class
//! ([`tile`]), a native 2-layer GCN with a hand-derived backward pass for
//! engine-free training ([`native_model`]), and AOT operand packing
//! ([`pack`]).

pub mod native;
pub mod native_model;
pub mod pack;
pub mod spec;
pub mod tile;

pub use native::{sparse_aggregate, AssignmentExec, SparseFeat};
pub use native_model::{FeatMode, GcnModel};
pub use spec::{
    benefits_from_sparse_features, candidates, KernelKind, KernelPair, Role, INTER_CANDIDATES,
    INTRA_CANDIDATES,
};
pub use tile::TileSparse;
