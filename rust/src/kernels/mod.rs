//! Subgraph-level kernels: taxonomy ([`spec`]), native CPU executions
//! mirroring the GPU schedules ([`native`]), and AOT operand packing
//! ([`pack`]).

pub mod native;
pub mod pack;
pub mod spec;

pub use spec::{KernelKind, KernelPair, INTER_CANDIDATES, INTRA_CANDIDATES};
