//! Subgraph-level kernels: taxonomy ([`spec`]), native CPU executions
//! mirroring the GPU schedules ([`native`]), a native 2-layer GCN with a
//! hand-derived backward pass for engine-free training
//! ([`native_model`]), and AOT operand packing ([`pack`]).

pub mod native;
pub mod native_model;
pub mod pack;
pub mod spec;

pub use native::AssignmentExec;
pub use spec::{KernelKind, KernelPair, INTER_CANDIDATES, INTRA_CANDIDATES};
