//! Native (CPU) executions of the four kernel schedules.
//!
//! Each function walks memory in the same order its GPU/Pallas twin does,
//! so (a) the figure benches can run full Table 1 scales that do not fit
//! an AOT bucket, and (b) `gpusim` replays the identical access pattern
//! when estimating cache behaviour. Numerical parity with the Pallas
//! kernels is enforced by `rust/tests/kernel_parity.rs` through the PJRT
//! path.

use crate::graph::{Csr, DenseBlocks};
use crate::kernels::tile::TileSparse;
use crate::partition::Decomposition;

/// Vertex-parallel CSR aggregate (inter-community schedule): row blocks of
/// 16, each row walks its neighbor list and gathers feature rows.
pub fn csr_inter_spmm(a: &Csr, x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.n_cols * f);
    let mut y = vec![0.0f32; a.n_rows * f];
    for block_start in (0..a.n_rows).step_by(16) {
        for r in block_start..(block_start + 16).min(a.n_rows) {
            let (cols, vals) = a.row(r);
            let out = &mut y[r * f..(r + 1) * f];
            for (&c, &w) in cols.iter().zip(vals) {
                let src = &x[c as usize * f..(c as usize + 1) * f];
                for (o, s) in out.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    }
    y
}

/// Community-resident CSR aggregate (intra-community schedule): per
/// community, copy the feature tile once ("shared memory"), then serve all
/// of the community's rows from the tile. `a` must be block-diagonal.
pub fn csr_intra_spmm(a: &Csr, x: &[f32], f: usize, community: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.n_cols * f);
    let mut y = vec![0.0f32; a.n_rows * f];
    let mut tile = vec![0.0f32; community * f];
    for b in 0..a.n_rows.div_ceil(community) {
        let base = b * community;
        // stage the community tile (the shared-memory preload); the tail
        // block may be ragged and stages only its real rows
        let width = community.min(a.n_rows - base);
        tile[..width * f].copy_from_slice(&x[base * f..(base + width) * f]);
        for lr in 0..width {
            let r = base + lr;
            let (cols, vals) = a.row(r);
            let out = &mut y[r * f..(r + 1) * f];
            for (&c, &w) in cols.iter().zip(vals) {
                let lc = c as usize - base; // panics if an edge escapes: contract violation
                let src = &tile[lc * f..(lc + 1) * f];
                for (o, s) in out.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    }
    y
}

/// Edge-parallel COO aggregate: scatter-accumulate per edge (the CPU twin
/// of per-edge atomicAdd).
pub fn coo_spmm(n: usize, edges: &[(u32, u32, f32)], x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), n * f);
    let mut y = vec![0.0f32; n * f];
    for &(dst, src, w) in edges {
        let s = &x[src as usize * f..(src as usize + 1) * f];
        let o = &mut y[dst as usize * f..(dst as usize + 1) * f];
        for (oo, ss) in o.iter_mut().zip(s) {
            *oo += w * ss;
        }
    }
    y
}

/// Dense block-diagonal batched GEMM (MXU schedule): per community a dense
/// (C,C)x(C,F) product including the zeros — the "invalid computation" the
/// paper trades for regularity at high density.
pub fn dense_block_spmm(blocks: &DenseBlocks, x: &[f32], f: usize) -> Vec<f32> {
    blocks.spmm(x, f)
}

/// Tile-sparse aggregate: one dense MMA fragment per non-empty `16x16`
/// tile (the CPU twin of the tensor-core schedule).
pub fn tile_sparse_spmm(tiles: &TileSparse, x: &[f32], f: usize) -> Vec<f32> {
    tiles.spmm(x, f)
}

/// Per-row top-k compressed feature matrix: each of `n` rows keeps its `k`
/// largest-by-value lanes out of `f`, stored as `(vals, cols)` pairs in
/// ascending column order. This is the MaxK-style activation-sparsity
/// layout — the second (feature-dimension) axis the density-aware cost
/// model prices alongside topology.
#[derive(Debug, Clone)]
pub struct SparseFeat {
    /// Row count.
    pub n: usize,
    /// Logical (dense) feature width.
    pub f: usize,
    /// Kept lanes per row (`k <= f`).
    pub k: usize,
    /// Row-major kept values, `n * k` entries.
    pub vals: Vec<f32>,
    /// Row-major kept column indices, `n * k` entries, ascending per row.
    pub cols: Vec<u32>,
}

impl SparseFeat {
    /// Compress `x` (dense `n x f`, row-major) to its per-row top-k lanes
    /// by value. Ties break toward the lower column index, so the
    /// selection is deterministic and matches the fused top-k inside
    /// `GcnModel::forward`.
    pub fn from_dense(x: &[f32], n: usize, f: usize, k: usize) -> SparseFeat {
        assert_eq!(x.len(), n * f);
        let k = k.min(f);
        let mut vals = Vec::with_capacity(n * k);
        let mut cols = Vec::with_capacity(n * k);
        let mut order: Vec<u32> = Vec::with_capacity(f);
        for r in 0..n {
            let row = &x[r * f..(r + 1) * f];
            order.clear();
            order.extend(0..f as u32);
            // descending by value, ascending index on ties — then keep k
            order.sort_by(|&a, &b| {
                row[b as usize]
                    .partial_cmp(&row[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            let mut kept: Vec<u32> = order[..k].to_vec();
            kept.sort_unstable(); // ascending column order within the row
            for &c in &kept {
                cols.push(c);
                vals.push(row[c as usize]);
            }
        }
        SparseFeat { n, f, k, vals, cols }
    }

    /// Expand back to a dense `n x f` matrix with zeros in the dropped
    /// lanes. Exact: kept lanes round-trip bitwise.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut x = vec![0.0f32; self.n * self.f];
        for r in 0..self.n {
            let out = &mut x[r * self.f..(r + 1) * self.f];
            for i in 0..self.k {
                out[self.cols[r * self.k + i] as usize] = self.vals[r * self.k + i];
            }
        }
        x
    }

    /// Fraction of lanes materialized — the `feat_density` the cost model
    /// prices (`rho = k / f`).
    pub fn density(&self) -> f64 {
        if self.f == 0 { 1.0 } else { self.k as f64 / self.f as f64 }
    }
}

/// SpGEMM-style sparse-feature aggregate: `y = A @ to_dense(sf)` computed
/// without materializing the dense operand. Per row of `A`, each neighbor
/// contributes only its `k` live lanes, scattered into the dense output
/// row by stored column index — the CPU twin of the MaxK gather-scatter
/// kernel, exact (not approximate) because the dropped lanes are true
/// zeros.
pub fn sparse_aggregate(a: &Csr, sf: &SparseFeat) -> Vec<f32> {
    assert_eq!(a.n_cols, sf.n);
    let (f, k) = (sf.f, sf.k);
    let mut y = vec![0.0f32; a.n_rows * f];
    for r in 0..a.n_rows {
        let (cols, vals) = a.row(r);
        let out = &mut y[r * f..(r + 1) * f];
        for (&c, &w) in cols.iter().zip(vals) {
            let base = c as usize * k;
            for i in 0..k {
                out[sf.cols[base + i] as usize] += w * sf.vals[base + i];
            }
        }
    }
    y
}

/// One pre-materialized part of a plan's class assignment, bound to its
/// native schedule.
enum PartExec {
    Dense(DenseBlocks),
    Tile(TileSparse),
    IntraCsr(Csr),
    InterCsr(Csr),
    Coo { n: usize, edges: Vec<(u32, u32, f32)> },
}

/// A plan's class assignment compiled to the native CPU schedules: the
/// intra classes (one or two, per the plan's density threshold) plus the
/// inter part, each in its assigned kernel's format. Built once per
/// (decomposition, plan) and reused across aggregate calls — the native
/// twin of `pack_assignment` + artifact execution, used by the sampled
/// trainer's CPU backend and the equivalence property tests.
pub struct AssignmentExec {
    community: usize,
    parts: Vec<PartExec>,
}

impl AssignmentExec {
    /// Compile `assignment` against `d`. Fails only on an assignment that
    /// does not cover `d` (wrong class stats) or routes a class to a
    /// kernel with no native schedule.
    pub fn build(
        d: &Decomposition,
        assignment: &crate::plan::GearAssignment,
    ) -> anyhow::Result<AssignmentExec> {
        assignment.covers(d)?;
        let n = d.graph.n;
        let part_for = |kind: crate::kernels::KernelKind, m: &Csr| -> anyhow::Result<PartExec> {
            use crate::kernels::KernelKind;
            Ok(match kind {
                KernelKind::DenseBlock => {
                    PartExec::Dense(DenseBlocks::from_block_diagonal_csr(m, d.community))
                }
                KernelKind::TileSparse => {
                    PartExec::Tile(TileSparse::from_block_diagonal_csr(m, d.community))
                }
                KernelKind::CsrIntra => PartExec::IntraCsr(m.clone()),
                KernelKind::CsrInter => PartExec::InterCsr(m.clone()),
                KernelKind::Coo => PartExec::Coo { n, edges: m.to_triplets() },
                KernelKind::DenseFull => {
                    anyhow::bail!("dense_full has no class-level native schedule")
                }
            })
        };
        let mut parts = Vec::new();
        if assignment.is_hybrid() {
            let split = d.split_intra(assignment.threshold);
            for class in &split.classes {
                let slot = match class.label {
                    crate::partition::DensityClass::Dense => crate::plan::SubgraphClass::DenseIntra,
                    crate::partition::DensityClass::Sparse => {
                        crate::plan::SubgraphClass::SparseIntra
                    }
                };
                let kind = assignment.kernel_for(slot).ok_or_else(|| {
                    anyhow::anyhow!("assignment has no kernel for {}", slot.as_str())
                })?;
                parts.push(part_for(kind, &class.matrix)?);
            }
        } else {
            let intra = assignment
                .intra_classes()
                .next()
                .ok_or_else(|| anyhow::anyhow!("assignment has no intra class"))?;
            parts.push(part_for(intra.kernel, &d.intra)?);
        }
        let inter = assignment.inter_class()?;
        parts.push(part_for(inter.kernel, &d.inter)?);
        Ok(AssignmentExec { community: d.community, parts })
    }

    /// `y = A @ x` where `A` is the whole propagation matrix, executed as
    /// the plan's parts and summed (exact: the parts partition the
    /// entries and zero padding is exact for aggregate-sum).
    pub fn aggregate(&self, x: &[f32], f: usize) -> Vec<f32> {
        let mut acc: Option<Vec<f32>> = None;
        for part in &self.parts {
            let y = match part {
                PartExec::Dense(blocks) => dense_block_spmm(blocks, x, f),
                PartExec::Tile(tiles) => tile_sparse_spmm(tiles, x, f),
                PartExec::IntraCsr(m) => csr_intra_spmm(m, x, f, self.community),
                PartExec::InterCsr(m) => csr_inter_spmm(m, x, f),
                PartExec::Coo { n, edges } => coo_spmm(*n, edges, x, f),
            };
            match acc.as_mut() {
                None => acc = Some(y),
                Some(a) => {
                    for (o, v) in a.iter_mut().zip(y) {
                        *o += v;
                    }
                }
            }
        }
        acc.unwrap_or_default()
    }
}

/// One-shot convenience over [`AssignmentExec::build`] + aggregate.
pub fn aggregate_assignment(
    d: &Decomposition,
    assignment: &crate::plan::GearAssignment,
    x: &[f32],
    f: usize,
) -> anyhow::Result<Vec<f32>> {
    Ok(AssignmentExec::build(d, assignment)?.aggregate(x, f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::graph::Graph;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng) -> (Csr, Csr, Vec<f32>, usize, usize) {
        let n = (rng.usize_below(6) + 2) * 16;
        let g = planted_partition(n, 16, 0.4, 0.03, rng);
        let a = Csr::gcn_normalized(&g);
        let (intra, inter) = a.split_block_diagonal(16);
        let f = rng.usize_below(6) + 2;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        (intra, inter, x, n, f)
    }

    #[test]
    fn all_schedules_agree_with_reference() {
        prop::check("native kernels == Csr::spmm", 15, |rng| {
            let (intra, inter, x, n, f) = setup(rng);

            let ref_inter = inter.spmm(&x, f);
            let ref_intra = intra.spmm(&x, f);

            let got_inter_csr = csr_inter_spmm(&inter, &x, f);
            let got_inter_coo = coo_spmm(n, &inter.to_triplets(), &x, f);
            let got_intra_csr = csr_intra_spmm(&intra, &x, f, 16);
            let blocks = DenseBlocks::from_block_diagonal_csr(&intra, 16);
            let got_intra_dense = dense_block_spmm(&blocks, &x, f);
            let tiles = TileSparse::from_block_diagonal_csr(&intra, 16);
            let got_intra_tile = tile_sparse_spmm(&tiles, &x, f);

            for (name, got, expect) in [
                ("csr_inter", &got_inter_csr, &ref_inter),
                ("coo", &got_inter_coo, &ref_inter),
                ("csr_intra", &got_intra_csr, &ref_intra),
                ("dense_block", &got_intra_dense, &ref_intra),
                ("tile_sparse", &got_intra_tile, &ref_intra),
            ] {
                for (a, b) in got.iter().zip(expect) {
                    prop::require_close(*a as f64, *b as f64, 1e-4, name)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn intra_and_inter_compose_to_whole() {
        let mut rng = Rng::new(2);
        let (intra, inter, x, n, f) = setup(&mut rng);
        let whole = {
            let mut t = intra.to_triplets();
            t.extend(inter.to_triplets());
            Csr::from_triplets(n, n, t)
        };
        let expect = whole.spmm(&x, f);
        let got: Vec<f32> = csr_intra_spmm(&intra, &x, f, 16)
            .iter()
            .zip(csr_inter_spmm(&inter, &x, f))
            .map(|(a, b)| a + b)
            .collect();
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic]
    fn intra_schedule_rejects_escaping_edges() {
        let a = Csr::from_triplets(32, 32, vec![(0, 20, 1.0)]);
        let x = vec![0.0f32; 32 * 2];
        csr_intra_spmm(&a, &x, 2, 16);
    }

    #[test]
    fn intra_schedule_handles_ragged_tail() {
        prop::check("ragged csr_intra == Csr::spmm", 15, |rng| {
            let n = rng.usize_below(70) + 3; // usually NOT a multiple of 16
            let m = rng.usize_below(3 * n);
            let g = Graph::from_edges(
                n,
                (0..m).map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32)),
            );
            let a = Csr::gcn_normalized(&g);
            let (intra, _) = a.split_block_diagonal(16);
            let f = 2;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let got = csr_intra_spmm(&intra, &x, f, 16);
            for (a, b) in got.iter().zip(&intra.spmm(&x, f)) {
                prop::require_close(*a as f64, *b as f64, 1e-4, "ragged intra elem")?;
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_exec_matches_whole_spmm() {
        // A planner-produced assignment (uniform or hybrid) executed on
        // the native schedules equals the whole-matrix reference.
        use crate::coordinator::ModelKind;
        use crate::gpusim::A100;
        use crate::partition::{Propagation, Reorder};
        use crate::plan::{PlanRequest, Planner, SimCostPlanner};
        use crate::runtime::BucketInfo;

        prop::check("AssignmentExec == whole spmm", 10, |rng| {
            let n = (rng.usize_below(8) + 3) * 16;
            let g = planted_partition(n, 16, 0.4 + rng.f64() * 0.4, 0.02, rng);
            let d = crate::partition::Decomposition::build(
                &g,
                Reorder::Metis,
                Propagation::GcnNormalized,
                16,
                1,
            );
            let bucket = BucketInfo {
                name: "t".into(),
                vertices: n,
                edges: d.intra.nnz() + d.inter.nnz() + 8,
                features: 16,
                hidden: 16,
                classes: 4,
                blocks: n / 16,
            };
            let plan = SimCostPlanner::new(&A100)
                .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
                .map_err(|e| e.to_string())?;
            let exec = super::AssignmentExec::build(&d, &plan.assignment)
                .map_err(|e| e.to_string())?;
            let f = 3;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let got = exec.aggregate(&x, f);
            let expect = d.whole().spmm(&x, f);
            for (a, b) in got.iter().zip(&expect) {
                prop::require_close(*a as f64, *b as f64, 1e-4, "assignment exec elem")?;
            }
            Ok(())
        });
    }

    #[test]
    fn tile_sparse_class_executes_in_assignment() {
        // a hand-built hybrid assignment routing the dense class to
        // TileSparse must compile and match the whole-matrix reference
        use crate::kernels::KernelKind;
        use crate::partition::{DensityClass, Propagation, Reorder};
        use crate::plan::{ClassAssignment, GearAssignment, SubgraphClass};

        let mut rng = Rng::new(9);
        let g = planted_partition(128, 16, 0.5, 0.02, &mut rng);
        let d = crate::partition::Decomposition::build(
            &g,
            Reorder::Identity,
            Propagation::GcnNormalized,
            16,
            0,
        );
        let profile = d.intra_block_profile();
        let mut dens: Vec<f64> = (0..profile.len()).map(|i| profile.density(i)).collect();
        dens.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let threshold = (dens[0] + dens[dens.len() - 1]) / 2.0;
        let split = d.split_intra(threshold);
        if split.classes.len() < 2 {
            return; // degenerate sample: nothing hybrid to execute
        }
        let stat = |label| {
            let c = split.class(label).unwrap();
            (c.blocks.len(), c.rows, c.matrix.nnz())
        };
        let (db, dr, dn) = stat(DensityClass::Dense);
        let (sb, sr, sn) = stat(DensityClass::Sparse);
        let assignment = GearAssignment {
            threshold,
            classes: vec![
                ClassAssignment {
                    class: SubgraphClass::DenseIntra,
                    kernel: KernelKind::TileSparse,
                    blocks: db,
                    rows: dr,
                    nnz: dn,
                    time_us: 1.0,
                },
                ClassAssignment {
                    class: SubgraphClass::SparseIntra,
                    kernel: KernelKind::CsrIntra,
                    blocks: sb,
                    rows: sr,
                    nnz: sn,
                    time_us: 1.0,
                },
                ClassAssignment {
                    class: SubgraphClass::Inter,
                    kernel: KernelKind::CsrInter,
                    blocks: 0,
                    rows: d.inter.n_rows,
                    nnz: d.inter.nnz(),
                    time_us: 1.0,
                },
            ],
            provenance: None,
        };
        let exec = AssignmentExec::build(&d, &assignment).unwrap();
        let f = 4;
        let x: Vec<f32> = (0..128 * f).map(|_| rng.normal_f32()).collect();
        let got = exec.aggregate(&x, f);
        for (a, b) in got.iter().zip(&d.whole().spmm(&x, f)) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn empty_graph_zero_output() {
        let g = Graph::empty(32);
        let a = Csr::adjacency(&g);
        let x = vec![1.0f32; 32 * 3];
        assert!(csr_inter_spmm(&a, &x, 3).iter().all(|&v| v == 0.0));
        assert!(coo_spmm(32, &[], &x, 3).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sparse_feat_full_k_roundtrips_bitwise() {
        let mut rng = Rng::new(11);
        let (n, f) = (17, 5);
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        let sf = SparseFeat::from_dense(&x, n, f, f);
        assert_eq!(sf.density(), 1.0);
        assert_eq!(sf.to_dense(), x, "k = f must keep every lane bitwise");
    }

    #[test]
    fn sparse_feat_keeps_topk_with_lower_index_ties() {
        // row [3, 1, 3, 2] at k=2: ties on 3 break toward index 0, so
        // columns {0, 2} survive
        let x = vec![3.0, 1.0, 3.0, 2.0];
        let sf = SparseFeat::from_dense(&x, 1, 4, 2);
        assert_eq!(sf.cols, vec![0, 2]);
        assert_eq!(sf.vals, vec![3.0, 3.0]);
        assert_eq!(sf.to_dense(), vec![3.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn sparse_aggregate_matches_dense_on_compressed_operand() {
        prop::check("sparse_aggregate == spmm(to_dense)", 15, |rng| {
            let n = rng.usize_below(70) + 3;
            let m = rng.usize_below(3 * n);
            let g = Graph::from_edges(
                n,
                (0..m).map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32)),
            );
            let a = Csr::gcn_normalized(&g);
            let f = rng.usize_below(7) + 1;
            let k = rng.usize_below(f) + 1;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let sf = SparseFeat::from_dense(&x, n, f, k);
            let got = sparse_aggregate(&a, &sf);
            let expect = a.spmm(&sf.to_dense(), f);
            for (gv, ev) in got.iter().zip(&expect) {
                prop::require_close(*gv as f64, *ev as f64, 1e-4, "sparse agg elem")?;
            }
            Ok(())
        });
    }
}
