//! Native (CPU) executions of the four kernel schedules.
//!
//! Each function walks memory in the same order its GPU/Pallas twin does,
//! so (a) the figure benches can run full Table 1 scales that do not fit
//! an AOT bucket, and (b) `gpusim` replays the identical access pattern
//! when estimating cache behaviour. Numerical parity with the Pallas
//! kernels is enforced by `rust/tests/kernel_parity.rs` through the PJRT
//! path.

use crate::graph::{Csr, DenseBlocks};

/// Vertex-parallel CSR aggregate (inter-community schedule): row blocks of
/// 16, each row walks its neighbor list and gathers feature rows.
pub fn csr_inter_spmm(a: &Csr, x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.n_cols * f);
    let mut y = vec![0.0f32; a.n_rows * f];
    for block_start in (0..a.n_rows).step_by(16) {
        for r in block_start..(block_start + 16).min(a.n_rows) {
            let (cols, vals) = a.row(r);
            let out = &mut y[r * f..(r + 1) * f];
            for (&c, &w) in cols.iter().zip(vals) {
                let src = &x[c as usize * f..(c as usize + 1) * f];
                for (o, s) in out.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    }
    y
}

/// Community-resident CSR aggregate (intra-community schedule): per
/// community, copy the feature tile once ("shared memory"), then serve all
/// of the community's rows from the tile. `a` must be block-diagonal.
pub fn csr_intra_spmm(a: &Csr, x: &[f32], f: usize, community: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.n_cols * f);
    let mut y = vec![0.0f32; a.n_rows * f];
    let mut tile = vec![0.0f32; community * f];
    for b in 0..a.n_rows.div_ceil(community) {
        let base = b * community;
        // stage the community tile (the shared-memory preload); the tail
        // block may be ragged and stages only its real rows
        let width = community.min(a.n_rows - base);
        tile[..width * f].copy_from_slice(&x[base * f..(base + width) * f]);
        for lr in 0..width {
            let r = base + lr;
            let (cols, vals) = a.row(r);
            let out = &mut y[r * f..(r + 1) * f];
            for (&c, &w) in cols.iter().zip(vals) {
                let lc = c as usize - base; // panics if an edge escapes: contract violation
                let src = &tile[lc * f..(lc + 1) * f];
                for (o, s) in out.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    }
    y
}

/// Edge-parallel COO aggregate: scatter-accumulate per edge (the CPU twin
/// of per-edge atomicAdd).
pub fn coo_spmm(n: usize, edges: &[(u32, u32, f32)], x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), n * f);
    let mut y = vec![0.0f32; n * f];
    for &(dst, src, w) in edges {
        let s = &x[src as usize * f..(src as usize + 1) * f];
        let o = &mut y[dst as usize * f..(dst as usize + 1) * f];
        for (oo, ss) in o.iter_mut().zip(s) {
            *oo += w * ss;
        }
    }
    y
}

/// Dense block-diagonal batched GEMM (MXU schedule): per community a dense
/// (C,C)x(C,F) product including the zeros — the "invalid computation" the
/// paper trades for regularity at high density.
pub fn dense_block_spmm(blocks: &DenseBlocks, x: &[f32], f: usize) -> Vec<f32> {
    blocks.spmm(x, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::graph::Graph;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng) -> (Csr, Csr, Vec<f32>, usize, usize) {
        let n = (rng.usize_below(6) + 2) * 16;
        let g = planted_partition(n, 16, 0.4, 0.03, rng);
        let a = Csr::gcn_normalized(&g);
        let (intra, inter) = a.split_block_diagonal(16);
        let f = rng.usize_below(6) + 2;
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
        (intra, inter, x, n, f)
    }

    #[test]
    fn all_schedules_agree_with_reference() {
        prop::check("native kernels == Csr::spmm", 15, |rng| {
            let (intra, inter, x, n, f) = setup(rng);

            let ref_inter = inter.spmm(&x, f);
            let ref_intra = intra.spmm(&x, f);

            let got_inter_csr = csr_inter_spmm(&inter, &x, f);
            let got_inter_coo = coo_spmm(n, &inter.to_triplets(), &x, f);
            let got_intra_csr = csr_intra_spmm(&intra, &x, f, 16);
            let blocks = DenseBlocks::from_block_diagonal_csr(&intra, 16);
            let got_intra_dense = dense_block_spmm(&blocks, &x, f);

            for (name, got, expect) in [
                ("csr_inter", &got_inter_csr, &ref_inter),
                ("coo", &got_inter_coo, &ref_inter),
                ("csr_intra", &got_intra_csr, &ref_intra),
                ("dense_block", &got_intra_dense, &ref_intra),
            ] {
                for (a, b) in got.iter().zip(expect) {
                    prop::require_close(*a as f64, *b as f64, 1e-4, name)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn intra_and_inter_compose_to_whole() {
        let mut rng = Rng::new(2);
        let (intra, inter, x, n, f) = setup(&mut rng);
        let whole = {
            let mut t = intra.to_triplets();
            t.extend(inter.to_triplets());
            Csr::from_triplets(n, n, t)
        };
        let expect = whole.spmm(&x, f);
        let got: Vec<f32> = csr_intra_spmm(&intra, &x, f, 16)
            .iter()
            .zip(csr_inter_spmm(&inter, &x, f))
            .map(|(a, b)| a + b)
            .collect();
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic]
    fn intra_schedule_rejects_escaping_edges() {
        let a = Csr::from_triplets(32, 32, vec![(0, 20, 1.0)]);
        let x = vec![0.0f32; 32 * 2];
        csr_intra_spmm(&a, &x, 2, 16);
    }

    #[test]
    fn intra_schedule_handles_ragged_tail() {
        prop::check("ragged csr_intra == Csr::spmm", 15, |rng| {
            let n = rng.usize_below(70) + 3; // usually NOT a multiple of 16
            let m = rng.usize_below(3 * n);
            let g = Graph::from_edges(
                n,
                (0..m).map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32)),
            );
            let a = Csr::gcn_normalized(&g);
            let (intra, _) = a.split_block_diagonal(16);
            let f = 2;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let got = csr_intra_spmm(&intra, &x, f, 16);
            for (a, b) in got.iter().zip(&intra.spmm(&x, f)) {
                prop::require_close(*a as f64, *b as f64, 1e-4, "ragged intra elem")?;
            }
            Ok(())
        });
    }

    #[test]
    fn empty_graph_zero_output() {
        let g = Graph::empty(32);
        let a = Csr::adjacency(&g);
        let x = vec![1.0f32; 32 * 3];
        assert!(csr_inter_spmm(&a, &x, 3).iter().all(|&v| v == 0.0));
        assert!(coo_spmm(32, &[], &x, 3).iter().all(|&v| v == 0.0));
    }
}
