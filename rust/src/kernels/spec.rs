//! Kernel taxonomy: the subgraph-level kernel candidates of Sec. 3.2 and
//! which subgraph role each may serve.

use std::fmt;

/// The four density-specialized kernels (plus the full-graph dense format
/// used only by the Fig. 2b format study).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Vertex-parallel CSR — low/irregular density (inter default).
    CsrInter,
    /// Community-resident CSR ("shared-memory" tile reuse) — intra.
    CsrIntra,
    /// Edge-parallel COO with atomic scatter — extremely low density.
    Coo,
    /// Dense block-diagonal batched GEMM (MXU / Tensor Core) — intra.
    DenseBlock,
    /// Full dense adjacency GEMM — Fig. 2b's "Dense" format curve only.
    DenseFull,
}

impl KernelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::CsrInter => "csr_inter",
            KernelKind::CsrIntra => "csr_intra",
            KernelKind::Coo => "coo",
            KernelKind::DenseBlock => "dense_block",
            KernelKind::DenseFull => "dense_full",
        }
    }

    /// Thin wrapper over the canonical [`FromStr`](std::str::FromStr) path.
    pub fn parse(s: &str) -> Option<KernelKind> {
        s.parse().ok()
    }
}

/// Canonical string dispatch — CLI parsing, manifest lookup, and plan
/// deserialization all come through here.
impl std::str::FromStr for KernelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<KernelKind, Self::Err> {
        match s {
            "csr_inter" => Ok(KernelKind::CsrInter),
            "csr_intra" => Ok(KernelKind::CsrIntra),
            "coo" => Ok(KernelKind::Coo),
            "dense_block" => Ok(KernelKind::DenseBlock),
            "dense_full" => Ok(KernelKind::DenseFull),
            other => Err(anyhow::anyhow!(
                "unknown kernel {other:?} (expected csr_inter|csr_intra|coo|dense_block|dense_full)"
            )),
        }
    }
}

impl fmt::Display for KernelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Candidate kernels for the intra-community subgraph (Sec. 3.3: "two for
/// intra-subgraph").
pub const INTRA_CANDIDATES: [KernelKind; 2] = [KernelKind::CsrIntra, KernelKind::DenseBlock];

/// Candidate kernels for the inter-community subgraph ("two for
/// inter-subgraph").
pub const INTER_CANDIDATES: [KernelKind; 2] = [KernelKind::CsrInter, KernelKind::Coo];

/// A (intra, inter) kernel assignment — one point in AdaptGear's strategy
/// space. `intra == None` encodes the full-graph-level baselines where the
/// whole propagation matrix runs through the inter kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelPair {
    pub intra: Option<KernelKind>,
    pub inter: KernelKind,
}

impl KernelPair {
    pub fn new(intra: KernelKind, inter: KernelKind) -> KernelPair {
        KernelPair { intra: Some(intra), inter }
    }

    pub fn full_graph(inter: KernelKind) -> KernelPair {
        KernelPair { intra: None, inter }
    }

    /// The manifest token for the intra slot ("none" for full-graph).
    pub fn intra_str(&self) -> &'static str {
        self.intra.map(|k| k.as_str()).unwrap_or("none")
    }

    /// All four adaptive combinations the selector explores.
    pub fn all_adaptive() -> Vec<KernelPair> {
        let mut out = Vec::new();
        for i in INTRA_CANDIDATES {
            for j in INTER_CANDIDATES {
                out.push(KernelPair::new(i, j));
            }
        }
        out
    }
}

impl fmt::Display for KernelPair {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}+{}", self.intra_str(), self.inter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for k in [
            KernelKind::CsrInter,
            KernelKind::CsrIntra,
            KernelKind::Coo,
            KernelKind::DenseBlock,
            KernelKind::DenseFull,
        ] {
            assert_eq!(KernelKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(KernelKind::parse("nope"), None);
    }

    #[test]
    fn adaptive_space_is_2x2() {
        let all = KernelPair::all_adaptive();
        assert_eq!(all.len(), 4);
        let uniq: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn full_graph_prints_none() {
        let p = KernelPair::full_graph(KernelKind::CsrInter);
        assert_eq!(p.to_string(), "none+csr_inter");
        assert_eq!(p.intra_str(), "none");
    }
}
