//! Kernel taxonomy: the subgraph-level kernel candidates of Sec. 3.2 and
//! which subgraph role each may serve.

use std::fmt;

/// The density-specialized kernels (plus the full-graph dense format
/// used only by the Fig. 2b format study).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Vertex-parallel CSR — low/irregular density (inter default).
    CsrInter,
    /// Community-resident CSR ("shared-memory" tile reuse) — intra.
    CsrIntra,
    /// Edge-parallel COO with atomic scatter — extremely low density.
    Coo,
    /// Dense block-diagonal batched GEMM (MXU / Tensor Core) — intra.
    DenseBlock,
    /// Non-empty `16x16` tiles column-compacted into MMA fragments
    /// (`kernels::tile`) — the mid-density intra class regime between
    /// `Coo`/`CsrIntra` and `DenseBlock`.
    TileSparse,
    /// Full dense adjacency GEMM — Fig. 2b's "Dense" format curve only.
    DenseFull,
}

impl KernelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::CsrInter => "csr_inter",
            KernelKind::CsrIntra => "csr_intra",
            KernelKind::Coo => "coo",
            KernelKind::DenseBlock => "dense_block",
            KernelKind::TileSparse => "tile_sparse",
            KernelKind::DenseFull => "dense_full",
        }
    }

    /// Thin wrapper over the canonical [`FromStr`](std::str::FromStr) path.
    pub fn parse(s: &str) -> Option<KernelKind> {
        s.parse().ok()
    }
}

/// Canonical string dispatch — CLI parsing, manifest lookup, and plan
/// deserialization all come through here.
impl std::str::FromStr for KernelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<KernelKind, Self::Err> {
        match s {
            "csr_inter" => Ok(KernelKind::CsrInter),
            "csr_intra" => Ok(KernelKind::CsrIntra),
            "coo" => Ok(KernelKind::Coo),
            "dense_block" => Ok(KernelKind::DenseBlock),
            "tile_sparse" => Ok(KernelKind::TileSparse),
            "dense_full" => Ok(KernelKind::DenseFull),
            other => Err(anyhow::anyhow!(
                "unknown kernel {other:?} (expected csr_inter|csr_intra|coo|dense_block|tile_sparse|dense_full)"
            )),
        }
    }
}

impl fmt::Display for KernelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Candidate kernels for the intra-community subgraph (Sec. 3.3: "two for
/// intra-subgraph"). The uniform-intra pair the runtime selector monitors;
/// [`candidates`]`(Role::UniformIntra)` is the canonical accessor.
pub const INTRA_CANDIDATES: [KernelKind; 2] = [KernelKind::CsrIntra, KernelKind::DenseBlock];

/// Candidate kernels for the inter-community subgraph ("two for
/// inter-subgraph"). [`candidates`]`(Role::Inter)` is the canonical
/// accessor.
pub const INTER_CANDIDATES: [KernelKind; 2] = [KernelKind::CsrInter, KernelKind::Coo];

/// What part a kernel candidate would play in a plan — the key of the
/// kernel-zoo registry. The hybrid sweep, the cost model, `plan
/// --explain`, and the bench suite all enumerate candidates exclusively
/// through [`candidates`]; adding a kernel is one registry entry plus its
/// cost (`gpusim::kernel_cost`), pack (`kernels::pack`), and native
/// (`kernels::native`) implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    /// Uniform (single-class) intra plans and the runtime monitor loop.
    /// Exactly [`INTRA_CANDIDATES`] — the monitored 2x2 strategy space
    /// is part of the artifact/selector contract.
    UniformIntra,
    /// The inter-community subgraph. Exactly [`INTER_CANDIDATES`].
    Inter,
    /// The dense class of a hybrid split (blocks at/above threshold).
    DenseClass,
    /// The sparse class of a hybrid split. Its operands merge into the
    /// inter launch at pack time, so only kernels a global sparse format
    /// absorbs exactly are eligible (TileSparse is not).
    SparseClass,
    /// Kernels that can execute in the intra slot of the two-slot AOT
    /// artifact contract — the superset the argmin-agreement bench and
    /// `--explain` enumerate.
    IntraSlot,
}

/// The kernel-zoo registry: every candidate a role may route to. The
/// single source of truth — no candidate array may be hard-coded outside
/// this module (enforced by `adaptgear check`'s self-audit tests and the
/// completeness test below).
pub fn candidates(role: Role) -> &'static [KernelKind] {
    match role {
        Role::UniformIntra => &INTRA_CANDIDATES,
        Role::Inter => &INTER_CANDIDATES,
        Role::DenseClass => &[KernelKind::DenseBlock, KernelKind::TileSparse],
        Role::SparseClass => &[KernelKind::CsrIntra, KernelKind::Coo],
        Role::IntraSlot => {
            &[KernelKind::CsrIntra, KernelKind::DenseBlock, KernelKind::TileSparse]
        }
    }
}

/// Candidate metadata: does this kernel's schedule get cheaper when the
/// feature operand is row-sparse (per-row top-k lanes)? Gather/scatter
/// schedules touch only the live lanes, so their flops and staging bytes
/// scale with feature density; the dense MMA family traverses every lane
/// regardless and is invariant. `gpusim::kernel_cost_density` prices
/// exactly this set density-aware, and `plan --explain` annotates
/// candidates with it.
pub fn benefits_from_sparse_features(kind: KernelKind) -> bool {
    match kind {
        KernelKind::CsrInter | KernelKind::CsrIntra | KernelKind::Coo => true,
        KernelKind::DenseBlock | KernelKind::TileSparse | KernelKind::DenseFull => false,
    }
}

/// A (intra, inter) kernel assignment — one point in AdaptGear's strategy
/// space. `intra == None` encodes the full-graph-level baselines where the
/// whole propagation matrix runs through the inter kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelPair {
    pub intra: Option<KernelKind>,
    pub inter: KernelKind,
}

impl KernelPair {
    pub fn new(intra: KernelKind, inter: KernelKind) -> KernelPair {
        KernelPair { intra: Some(intra), inter }
    }

    pub fn full_graph(inter: KernelKind) -> KernelPair {
        KernelPair { intra: None, inter }
    }

    /// The manifest token for the intra slot ("none" for full-graph).
    pub fn intra_str(&self) -> &'static str {
        self.intra.map(|k| k.as_str()).unwrap_or("none")
    }

    /// All four adaptive combinations the selector explores.
    pub fn all_adaptive() -> Vec<KernelPair> {
        let mut out = Vec::new();
        for i in INTRA_CANDIDATES {
            for j in INTER_CANDIDATES {
                out.push(KernelPair::new(i, j));
            }
        }
        out
    }
}

impl fmt::Display for KernelPair {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}+{}", self.intra_str(), self.inter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for k in [
            KernelKind::CsrInter,
            KernelKind::CsrIntra,
            KernelKind::Coo,
            KernelKind::DenseBlock,
            KernelKind::TileSparse,
            KernelKind::DenseFull,
        ] {
            assert_eq!(KernelKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(KernelKind::parse("nope"), None);
    }

    /// The registry contract: every kernel a role may route to has a
    /// working cost curve AND a working pack routine — adding a registry
    /// entry without its implementations fails here, not in a planner.
    #[test]
    fn registry_candidates_are_complete() {
        use crate::gpusim::kernel_cost::{class_kernel_cost, kernel_cost, ClassDims, CostCtx};
        use crate::gpusim::A100;
        use crate::graph::Csr;
        use crate::runtime::BucketInfo;

        // tiny 2-block block-diagonal intra part + off-diagonal inter part
        let intra = Csr::from_triplets(
            32,
            32,
            vec![(0, 1, 1.0), (3, 2, 0.5), (17, 20, 1.0), (30, 30, 0.25)],
        );
        let inter = Csr::from_triplets(32, 32, vec![(0, 20, 1.0), (25, 3, 0.5)]);
        let bucket = BucketInfo {
            name: "t".into(),
            vertices: 32,
            edges: 64,
            features: 8,
            hidden: 8,
            classes: 4,
            blocks: 2,
        };
        let roles = [
            Role::UniformIntra,
            Role::Inter,
            Role::DenseClass,
            Role::SparseClass,
            Role::IntraSlot,
        ];
        for role in roles {
            let set = candidates(role);
            assert!(!set.is_empty(), "{role:?} has no candidates");
            let uniq: std::collections::HashSet<_> = set.iter().collect();
            assert_eq!(uniq.len(), set.len(), "{role:?} lists a kernel twice");
            for &k in set {
                assert_eq!(KernelKind::parse(k.as_str()), Some(k), "{role:?}/{k} name");
                assert_ne!(k, KernelKind::DenseFull, "figure-only format in {role:?}");
                let (matrix, us) = match role {
                    Role::Inter => {
                        (&inter, kernel_cost(k, &inter, 8, 16, &A100).time_us)
                    }
                    _ => {
                        let dims = ClassDims { kind: k, blocks: 2, rows: 32, nnz: intra.nnz() };
                        (&intra, class_kernel_cost(&CostCtx::new(dims, 8, 16, &A100)).time_us)
                    }
                };
                assert!(us.is_finite() && us > 0.0, "{role:?}/{k} cost {us}");
                crate::kernels::pack::pack_kernel_operands(k, matrix, 16, &bucket)
                    .unwrap_or_else(|e| panic!("{role:?}/{k} has no pack routine: {e}"));
            }
        }
        // slot subset rules: every dense/sparse class kernel either runs
        // in the intra artifact slot or merges into the inter launch
        for &k in candidates(Role::DenseClass) {
            assert!(candidates(Role::IntraSlot).contains(&k), "{k} unexecutable");
        }
        assert_eq!(candidates(Role::UniformIntra), &INTRA_CANDIDATES);
        assert_eq!(candidates(Role::Inter), &INTER_CANDIDATES);
    }

    /// The sparse-feature metadata agrees with the cost model: a kernel
    /// flagged as benefiting must actually price cheaper at low feature
    /// density (at a width where the feature term dominates), and an
    /// unflagged kernel must price identically.
    #[test]
    fn sparse_feature_metadata_matches_cost_model() {
        use crate::gpusim::kernel_cost::{
            class_kernel_cost, kernel_cost, kernel_cost_density, ClassDims, CostCtx,
        };
        use crate::gpusim::A100;
        use crate::graph::Csr;

        let inter = Csr::from_triplets(
            256,
            256,
            (0..512u32).map(|i| (i % 256, (i * 37) % 256, 1.0)).collect(),
        );
        let f = 256;
        for role in [Role::UniformIntra, Role::Inter, Role::DenseClass, Role::SparseClass] {
            for &k in candidates(role) {
                let (dense_us, sparse_us) = if role == Role::Inter {
                    (
                        kernel_cost(k, &inter, f, 16, &A100).time_us,
                        kernel_cost_density(k, &inter, f, 16, &A100, 0.125).time_us,
                    )
                } else {
                    let dims = ClassDims { kind: k, blocks: 40, rows: 640, nnz: 4000 };
                    let ctx = CostCtx::new(dims, f, 16, &A100);
                    (
                        class_kernel_cost(&ctx).time_us,
                        class_kernel_cost(&ctx.with_feat_density(0.125)).time_us,
                    )
                };
                if benefits_from_sparse_features(k) {
                    assert!(
                        sparse_us < dense_us,
                        "{k} flagged sparse-friendly but {sparse_us} !< {dense_us}"
                    );
                } else {
                    assert_eq!(sparse_us, dense_us, "{k} flagged invariant but moved");
                }
            }
        }
        assert!(!benefits_from_sparse_features(KernelKind::DenseFull));
    }

    #[test]
    fn adaptive_space_is_2x2() {
        let all = KernelPair::all_adaptive();
        assert_eq!(all.len(), 4);
        let uniq: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn full_graph_prints_none() {
        let p = KernelPair::full_graph(KernelKind::CsrInter);
        assert_eq!(p.to_string(), "none+csr_inter");
        assert_eq!(p.intra_str(), "none");
    }
}
