//! Bucket packing: translate a [`Decomposition`] into the padded operand
//! tensors an AOT artifact expects (the contract documented in
//! `python/compile/kernels/ref.py`).
//!
//! Zero padding is exact for aggregate-sum: padded CSR rows are empty
//! (row_ptr is exact), padded COO edges carry weight 0, padded vertices
//! are masked out of the loss.

use anyhow::{bail, Result};

use crate::graph::Csr;
use crate::partition::Decomposition;
use crate::runtime::{BucketInfo, Tensor};

use super::spec::KernelKind;

/// Pack the intra/inter subgraph for `kind` into operand tensors, padded
/// to `bucket`. The CSR must fit the bucket's vertex and edge capacity.
pub fn pack_kernel_operands(
    kind: KernelKind,
    matrix: &Csr,
    community: usize,
    bucket: &BucketInfo,
) -> Result<Vec<Tensor>> {
    match kind {
        KernelKind::CsrInter => pack_csr_global(matrix, bucket),
        KernelKind::CsrIntra => pack_csr_local(matrix, community, bucket),
        KernelKind::Coo => pack_coo(matrix, bucket),
        KernelKind::DenseBlock => pack_dense_blocks(matrix, community, bucket),
        KernelKind::DenseFull => bail!("dense_full has no AOT operand packing (Fig. 2b only)"),
    }
}

fn check_capacity(matrix: &Csr, bucket: &BucketInfo) -> Result<()> {
    if matrix.n_rows > bucket.vertices {
        bail!("graph has {} vertices, bucket {} holds {}", matrix.n_rows, bucket.name, bucket.vertices);
    }
    if matrix.nnz() > bucket.edges {
        bail!("subgraph has {} nnz, bucket {} holds {}", matrix.nnz(), bucket.name, bucket.edges);
    }
    Ok(())
}

/// Padded global CSR: row_ptr [V+1] exact, col/val tails zero.
fn pack_csr_global(matrix: &Csr, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let v = bucket.vertices;
    let e = bucket.edges;
    let mut row_ptr = vec![0i32; v + 1];
    for r in 0..matrix.n_rows {
        row_ptr[r + 1] = matrix.row_ptr[r + 1] as i32;
    }
    let last = matrix.row_ptr[matrix.n_rows] as i32;
    for r in matrix.n_rows..v {
        row_ptr[r + 1] = last;
    }
    let mut col = vec![0i32; e];
    let mut val = vec![0f32; e];
    for (i, (&c, &w)) in matrix.col_idx.iter().zip(&matrix.vals).enumerate() {
        col[i] = c as i32;
        val[i] = w;
    }
    Ok(vec![
        Tensor::i32(row_ptr, &[v + 1]),
        Tensor::i32(col, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Padded local CSR for a block-diagonal matrix: columns are local to the
/// community (0..C).
fn pack_csr_local(matrix: &Csr, community: usize, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let v = bucket.vertices;
    let e = bucket.edges;
    let mut row_ptr = vec![0i32; v + 1];
    let mut col = vec![0i32; e];
    let mut val = vec![0f32; e];
    let mut k = 0usize;
    for r in 0..matrix.n_rows {
        let base = (r / community) * community;
        let (cols, vals) = matrix.row(r);
        for (&c, &w) in cols.iter().zip(vals) {
            let c = c as usize;
            if c / community != r / community {
                bail!("entry ({r},{c}) is not block-diagonal; split first");
            }
            col[k] = (c - base) as i32;
            val[k] = w;
            k += 1;
        }
        row_ptr[r + 1] = k as i32;
    }
    for r in matrix.n_rows..v {
        row_ptr[r + 1] = k as i32;
    }
    Ok(vec![
        Tensor::i32(row_ptr, &[v + 1]),
        Tensor::i32(col, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Padded COO `(src, dst, val)` with zero padding edges.
fn pack_coo(matrix: &Csr, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let e = bucket.edges;
    let mut src = vec![0i32; e];
    let mut dst = vec![0i32; e];
    let mut val = vec![0f32; e];
    for (i, (d, s, w)) in matrix.to_triplets().into_iter().enumerate() {
        src[i] = s as i32;
        dst[i] = d as i32;
        val[i] = w;
    }
    Ok(vec![
        Tensor::i32(src, &[e]),
        Tensor::i32(dst, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Dense `[nB, C, C]` diagonal blocks.
fn pack_dense_blocks(matrix: &Csr, community: usize, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    if matrix.n_rows > bucket.vertices {
        bail!("graph exceeds bucket vertex capacity");
    }
    let nb = bucket.blocks;
    let c = community;
    let mut data = vec![0f32; nb * c * c];
    for (r, cc, w) in matrix.to_triplets() {
        let (r, cc) = (r as usize, cc as usize);
        if r / c != cc / c {
            bail!("entry ({r},{cc}) is not block-diagonal; split first");
        }
        let b = r / c;
        data[(b * c + r % c) * c + cc % c] += w;
    }
    Ok(vec![Tensor::f32(data, &[nb, c, c])])
}

/// Pad features `[n, f_data]` into the bucket's `[V, F]` (truncating or
/// zero-extending the feature dimension).
pub fn pack_features(x: &[f32], n: usize, f_data: usize, bucket: &BucketInfo) -> Result<Tensor> {
    if x.len() != n * f_data {
        bail!("feature length {} != n*f {}", x.len(), n * f_data);
    }
    if n > bucket.vertices {
        bail!("features exceed bucket vertex capacity");
    }
    let (v, f) = (bucket.vertices, bucket.features);
    let mut out = vec![0f32; v * f];
    let copy_f = f_data.min(f);
    for r in 0..n {
        out[r * f..r * f + copy_f].copy_from_slice(&x[r * f_data..r * f_data + copy_f]);
    }
    Ok(Tensor::f32(out, &[v, f]))
}

/// Pad labels to `[V]` (clamping into the bucket's class range) and build
/// the matching mask (1.0 for real vertices, 0.0 for padding).
pub fn pack_labels_mask(labels: &[i32], bucket: &BucketInfo) -> Result<(Tensor, Tensor)> {
    if labels.len() > bucket.vertices {
        bail!("labels exceed bucket vertex capacity");
    }
    let v = bucket.vertices;
    let mut lab = vec![0i32; v];
    let mut mask = vec![0f32; v];
    for (i, &l) in labels.iter().enumerate() {
        lab[i] = l.rem_euclid(bucket.classes as i32);
        mask[i] = 1.0;
    }
    Ok((Tensor::i32(lab, &[v]), Tensor::f32(mask, &[v])))
}

/// Pack both subgraphs of a decomposition for a kernel pair; full-graph
/// pairs (intra=None) pack the recombined whole matrix as "inter".
pub fn pack_pair(
    d: &Decomposition,
    intra: Option<KernelKind>,
    inter: KernelKind,
    bucket: &BucketInfo,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    match intra {
        Some(ik) => Ok((
            pack_kernel_operands(ik, &d.intra, d.community, bucket)?,
            pack_kernel_operands(inter, &d.inter, d.community, bucket)?,
        )),
        None => Ok((
            Vec::new(),
            pack_kernel_operands(inter, &d.whole(), d.community, bucket)?,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::partition::{Propagation, Reorder};
    use crate::util::rng::Rng;

    fn bucket() -> BucketInfo {
        BucketInfo { name: "t".into(), vertices: 64, edges: 512, features: 8, hidden: 8, classes: 4, blocks: 4 }
    }

    fn decomp() -> Decomposition {
        let mut rng = Rng::new(1);
        let g = planted_partition(48, 16, 0.4, 0.03, &mut rng);
        Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0)
    }

    #[test]
    fn csr_global_padding_shape() {
        let d = decomp();
        let b = bucket();
        let ops = pack_csr_global(&d.inter, &b).unwrap();
        assert_eq!(ops[0].shape(), &[65]);
        assert_eq!(ops[1].shape(), &[512]);
        // row_ptr monotone, final rows flat
        let rp = ops[0].as_i32().unwrap();
        assert!(rp.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(rp[48], rp[64]);
    }

    #[test]
    fn csr_local_columns_in_range() {
        let d = decomp();
        let ops = pack_csr_local(&d.intra, 16, &bucket()).unwrap();
        let col = ops[1].as_i32().unwrap();
        assert!(col.iter().all(|&c| (0..16).contains(&c)));
    }

    #[test]
    fn coo_padding_is_zero_weight() {
        let d = decomp();
        let ops = pack_coo(&d.inter, &bucket()).unwrap();
        let val = ops[2].as_f32().unwrap();
        let nnz = d.inter.nnz();
        assert!(val[nnz..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dense_blocks_shape() {
        let d = decomp();
        let ops = pack_dense_blocks(&d.intra, 16, &bucket()).unwrap();
        assert_eq!(ops[0].shape(), &[4, 16, 16]);
    }

    #[test]
    fn rejects_oversize() {
        let mut rng = Rng::new(2);
        let g = planted_partition(128, 16, 0.5, 0.05, &mut rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0);
        assert!(pack_csr_global(&d.inter, &bucket()).is_err());
    }

    #[test]
    fn features_pad_and_truncate() {
        let b = bucket();
        let x: Vec<f32> = (0..10 * 12).map(|i| i as f32).collect();
        let t = pack_features(&x, 10, 12, &b).unwrap(); // truncate 12 -> 8
        assert_eq!(t.shape(), &[64, 8]);
        assert_eq!(t.as_f32().unwrap()[0..8], x[0..8]);
        let t2 = pack_features(&x[..10 * 4], 10, 4, &b).unwrap(); // extend 4 -> 8
        assert_eq!(t2.as_f32().unwrap()[4..8], [0.0; 4]);
    }

    #[test]
    fn labels_clamped_and_masked() {
        let b = bucket();
        let (lab, mask) = pack_labels_mask(&[0, 5, -1], &b).unwrap();
        let l = lab.as_i32().unwrap();
        assert_eq!(&l[..3], &[0, 1, 3]); // 5 % 4 = 1, -1 -> 3
        let m = mask.as_f32().unwrap();
        assert_eq!(&m[..4], &[1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn pack_pair_full_graph_mode() {
        let d = decomp();
        let (iops, jops) = pack_pair(&d, None, KernelKind::CsrInter, &bucket()).unwrap();
        assert!(iops.is_empty());
        // whole matrix nnz = intra + inter
        let rp = jops[0].as_i32().unwrap();
        assert_eq!(rp[64] as usize, d.intra.nnz() + d.inter.nnz());
    }
}
