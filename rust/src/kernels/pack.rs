//! Bucket packing: translate a [`Decomposition`] into the padded operand
//! tensors an AOT artifact expects (the contract documented in
//! `python/compile/kernels/ref.py`).
//!
//! Zero padding is exact for aggregate-sum: padded CSR rows are empty
//! (row_ptr is exact), padded COO edges carry weight 0, padded vertices
//! are masked out of the loss.

use anyhow::{bail, Context, Result};

use crate::graph::Csr;
use crate::partition::{Decomposition, DensityClass};
use crate::plan::{GearAssignment, SubgraphClass};
use crate::runtime::{BucketInfo, Tensor};

use super::spec::KernelKind;

/// Pack the intra/inter subgraph for `kind` into operand tensors, padded
/// to `bucket`. The CSR must fit the bucket's vertex and edge capacity.
pub fn pack_kernel_operands(
    kind: KernelKind,
    matrix: &Csr,
    community: usize,
    bucket: &BucketInfo,
) -> Result<Vec<Tensor>> {
    match kind {
        KernelKind::CsrInter => pack_csr_global(matrix, bucket),
        KernelKind::CsrIntra => pack_csr_local(matrix, community, bucket),
        KernelKind::Coo => pack_coo(matrix, bucket),
        KernelKind::DenseBlock => pack_dense_blocks(matrix, community, bucket),
        KernelKind::TileSparse => pack_tile_class(matrix, community, bucket),
        KernelKind::DenseFull => bail!("dense_full has no AOT operand packing (Fig. 2b only)"),
    }
}

fn check_capacity(matrix: &Csr, bucket: &BucketInfo) -> Result<()> {
    if matrix.n_rows > bucket.vertices {
        bail!("graph has {} vertices, bucket {} holds {}", matrix.n_rows, bucket.name, bucket.vertices);
    }
    if matrix.nnz() > bucket.edges {
        bail!("subgraph has {} nnz, bucket {} holds {}", matrix.nnz(), bucket.name, bucket.edges);
    }
    Ok(())
}

/// Padded global CSR: row_ptr [V+1] exact, col/val tails zero.
fn pack_csr_global(matrix: &Csr, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let v = bucket.vertices;
    let e = bucket.edges;
    let mut row_ptr = vec![0i32; v + 1];
    for r in 0..matrix.n_rows {
        row_ptr[r + 1] = matrix.row_ptr[r + 1] as i32;
    }
    let last = matrix.row_ptr[matrix.n_rows] as i32;
    for r in matrix.n_rows..v {
        row_ptr[r + 1] = last;
    }
    let mut col = vec![0i32; e];
    let mut val = vec![0f32; e];
    for (i, (&c, &w)) in matrix.col_idx.iter().zip(&matrix.vals).enumerate() {
        col[i] = c as i32;
        val[i] = w;
    }
    Ok(vec![
        Tensor::i32(row_ptr, &[v + 1]),
        Tensor::i32(col, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Padded local CSR for a block-diagonal matrix: columns are local to the
/// community (0..C).
fn pack_csr_local(matrix: &Csr, community: usize, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let v = bucket.vertices;
    let e = bucket.edges;
    let mut row_ptr = vec![0i32; v + 1];
    let mut col = vec![0i32; e];
    let mut val = vec![0f32; e];
    let mut k = 0usize;
    for r in 0..matrix.n_rows {
        let base = (r / community) * community;
        let (cols, vals) = matrix.row(r);
        for (&c, &w) in cols.iter().zip(vals) {
            let c = c as usize;
            if c / community != r / community {
                bail!("entry ({r},{c}) is not block-diagonal; split first");
            }
            col[k] = (c - base) as i32;
            val[k] = w;
            k += 1;
        }
        row_ptr[r + 1] = k as i32;
    }
    for r in matrix.n_rows..v {
        row_ptr[r + 1] = k as i32;
    }
    Ok(vec![
        Tensor::i32(row_ptr, &[v + 1]),
        Tensor::i32(col, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Padded COO `(src, dst, val)` with zero padding edges.
fn pack_coo(matrix: &Csr, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    check_capacity(matrix, bucket)?;
    let e = bucket.edges;
    let mut src = vec![0i32; e];
    let mut dst = vec![0i32; e];
    let mut val = vec![0f32; e];
    for (i, (d, s, w)) in matrix.to_triplets().into_iter().enumerate() {
        src[i] = s as i32;
        dst[i] = d as i32;
        val[i] = w;
    }
    Ok(vec![
        Tensor::i32(src, &[e]),
        Tensor::i32(dst, &[e]),
        Tensor::f32(val, &[e]),
    ])
}

/// Dense `[nB, C, C]` diagonal blocks.
fn pack_dense_blocks(matrix: &Csr, community: usize, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    if matrix.n_rows > bucket.vertices {
        bail!("graph exceeds bucket vertex capacity");
    }
    let nb = bucket.blocks;
    let c = community;
    let mut data = vec![0f32; nb * c * c];
    for (r, cc, w) in matrix.to_triplets() {
        let (r, cc) = (r as usize, cc as usize);
        if r / c != cc / c {
            bail!("entry ({r},{cc}) is not block-diagonal; split first");
        }
        let b = r / c;
        data[(b * c + r % c) * c + cc % c] += w;
    }
    Ok(vec![Tensor::f32(data, &[nb, c, c])])
}

/// Non-empty `16x16` MMA tiles (`kernels::tile` extraction), padded to
/// the bucket's geometric tile-grid capacity: `strip_row` `[T]`, compacted
/// column ids `[T*16]` (`-1` pad), dense payload `[T, 16, 16]`. Padding
/// tiles carry zero payload — exact for aggregate-sum, like every other
/// format here.
pub fn pack_tile_class(matrix: &Csr, community: usize, bucket: &BucketInfo) -> Result<Vec<Tensor>> {
    use crate::kernels::tile::{tile_capacity, TileSparse, MMA_TILE};
    if matrix.n_rows > bucket.vertices {
        bail!("graph exceeds bucket vertex capacity");
    }
    let tiles = TileSparse::from_block_diagonal_csr(matrix, community);
    let cap = tile_capacity(bucket.blocks, community);
    if tiles.n_tiles() > cap {
        bail!(
            "class occupies {} tiles, bucket {} reserves {cap} tile slots",
            tiles.n_tiles(),
            bucket.name
        );
    }
    let mut strip_row = vec![0i32; cap];
    let mut cols = vec![-1i32; cap * MMA_TILE];
    let mut data = vec![0f32; cap * MMA_TILE * MMA_TILE];
    for (i, &r) in tiles.strip_row.iter().enumerate() {
        strip_row[i] = r as i32;
    }
    for (i, &c) in tiles.cols.iter().enumerate() {
        cols[i] = if c == u32::MAX { -1 } else { c as i32 };
    }
    data[..tiles.data.len()].copy_from_slice(&tiles.data);
    Ok(vec![
        Tensor::i32(strip_row, &[cap]),
        Tensor::i32(cols, &[cap * MMA_TILE]),
        Tensor::f32(data, &[cap, MMA_TILE, MMA_TILE]),
    ])
}

/// Pad features `[n, f_data]` into the bucket's `[V, F]` (truncating or
/// zero-extending the feature dimension).
pub fn pack_features(x: &[f32], n: usize, f_data: usize, bucket: &BucketInfo) -> Result<Tensor> {
    if x.len() != n * f_data {
        bail!("feature length {} != n*f {}", x.len(), n * f_data);
    }
    if n > bucket.vertices {
        bail!("features exceed bucket vertex capacity");
    }
    let (v, f) = (bucket.vertices, bucket.features);
    let mut out = vec![0f32; v * f];
    let copy_f = f_data.min(f);
    for r in 0..n {
        out[r * f..r * f + copy_f].copy_from_slice(&x[r * f_data..r * f_data + copy_f]);
    }
    Ok(Tensor::f32(out, &[v, f]))
}

/// Pad labels to `[V]` (clamping into the bucket's class range) and build
/// the matching mask (1.0 for real vertices, 0.0 for padding).
pub fn pack_labels_mask(labels: &[i32], bucket: &BucketInfo) -> Result<(Tensor, Tensor)> {
    let ones = vec![1.0f32; labels.len()];
    pack_labels_masked(labels, &ones, bucket)
}

/// [`pack_labels_mask`] with a caller-supplied per-row mask — sampled
/// batches mask their support rows out of the loss (only target rows
/// carry 1.0). One implementation owns the label clamp/padding contract
/// for both the full-graph and sampled paths.
pub fn pack_labels_masked(
    labels: &[i32],
    mask: &[f32],
    bucket: &BucketInfo,
) -> Result<(Tensor, Tensor)> {
    if labels.len() != mask.len() {
        bail!("labels ({}) and mask ({}) lengths differ", labels.len(), mask.len());
    }
    if labels.len() > bucket.vertices {
        bail!("labels exceed bucket vertex capacity");
    }
    let v = bucket.vertices;
    let mut lab = vec![0i32; v];
    let mut m = vec![0f32; v];
    for (i, &l) in labels.iter().enumerate() {
        lab[i] = l.rem_euclid(bucket.classes as i32);
        m[i] = mask[i];
    }
    Ok((Tensor::i32(lab, &[v]), Tensor::f32(m, &[v])))
}

/// Pack only the listed diagonal `blocks` of a block-diagonal matrix for
/// `kind`, zeroing every other block — the class-subset packing hybrid
/// execution rests on (zero padding is exact for aggregate-sum, so the
/// classes' outputs sum back to the whole intra aggregate).
///
/// The block-membership rule (`row / community`) is the same one
/// `Decomposition::split_intra` classifies by; [`pack_assignment`] goes
/// through the split's pre-materialized class matrices instead so it can
/// cross-check them against the plan, while this standalone primitive
/// serves ad-hoc class packing (candidate timing, tests).
pub fn pack_block_class(
    kind: KernelKind,
    matrix: &Csr,
    blocks: &[u32],
    community: usize,
    bucket: &BucketInfo,
) -> Result<Vec<Tensor>> {
    let c = community.max(1);
    let n_blocks = matrix.n_rows.div_ceil(c);
    let mut member = vec![false; n_blocks];
    for &b in blocks {
        if (b as usize) < n_blocks {
            member[b as usize] = true;
        }
    }
    let filtered = Csr::from_triplets(
        matrix.n_rows,
        matrix.n_cols,
        matrix
            .to_triplets()
            .into_iter()
            .filter(|&(r, _, _)| member[r as usize / c]),
    );
    pack_kernel_operands(kind, &filtered, community, bucket)
}

/// Lower a plan's class assignment onto the two AOT operand slots.
///
/// Uniform assignments pack exactly like [`pack_pair`]. Hybrid
/// assignments re-split the intra part at the recorded threshold, pack
/// the dense class into the intra slot, and MERGE the sparse class into
/// the inter operand — the inter kernels are global sparse formats that
/// take arbitrary coordinates, so the merge is exact and a 2-slot
/// artifact executes the N-part plan.
pub fn pack_assignment(
    d: &Decomposition,
    assignment: &GearAssignment,
    bucket: &BucketInfo,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let inter_kernel = assignment.inter_class()?.kernel;
    if !assignment.is_hybrid() {
        let pair = assignment.executed_pair()?;
        return pack_pair(d, pair.intra, inter_kernel, bucket);
    }
    let split = d.split_intra(assignment.threshold);
    let dense = split
        .class(DensityClass::Dense)
        .context("hybrid plan but the threshold split produced no dense class — replan")?;
    let sparse = split
        .class(DensityClass::Sparse)
        .context("hybrid plan but the threshold split produced no sparse class — replan")?;
    for (class, got) in [
        (SubgraphClass::DenseIntra, dense),
        (SubgraphClass::SparseIntra, sparse),
    ] {
        let want = assignment
            .classes
            .iter()
            .find(|c| c.class == class)
            .with_context(|| format!("assignment missing {} class", class.as_str()))?;
        if want.blocks != got.blocks.len() || want.nnz != got.matrix.nnz() {
            bail!(
                "plan's {} class ({} blocks, {} nnz) does not match the decomposition's split ({} blocks, {} nnz) — replan",
                class.as_str(),
                want.blocks,
                want.nnz,
                got.blocks.len(),
                got.matrix.nnz()
            );
        }
    }
    let dense_kernel = assignment
        .kernel_for(SubgraphClass::DenseIntra)
        .expect("hybrid assignment has a dense class");
    let intra_ops = pack_kernel_operands(dense_kernel, &dense.matrix, d.community, bucket)?;
    let mut merged = sparse.matrix.to_triplets();
    merged.extend(d.inter.to_triplets());
    let merged = Csr::from_triplets(d.inter.n_rows, d.inter.n_cols, merged);
    let inter_ops = pack_kernel_operands(inter_kernel, &merged, d.community, bucket)
        .context("packing the merged sparse-class + inter operand")?;
    Ok((intra_ops, inter_ops))
}

/// Pack both subgraphs of a decomposition for a kernel pair; full-graph
/// pairs (intra=None) pack the recombined whole matrix as "inter".
pub fn pack_pair(
    d: &Decomposition,
    intra: Option<KernelKind>,
    inter: KernelKind,
    bucket: &BucketInfo,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    match intra {
        Some(ik) => Ok((
            pack_kernel_operands(ik, &d.intra, d.community, bucket)?,
            pack_kernel_operands(inter, &d.inter, d.community, bucket)?,
        )),
        None => Ok((
            Vec::new(),
            pack_kernel_operands(inter, &d.whole(), d.community, bucket)?,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::partition::{Propagation, Reorder};
    use crate::util::rng::Rng;

    fn bucket() -> BucketInfo {
        BucketInfo { name: "t".into(), vertices: 64, edges: 512, features: 8, hidden: 8, classes: 4, blocks: 4 }
    }

    fn decomp() -> Decomposition {
        let mut rng = Rng::new(1);
        let g = planted_partition(48, 16, 0.4, 0.03, &mut rng);
        Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0)
    }

    #[test]
    fn csr_global_padding_shape() {
        let d = decomp();
        let b = bucket();
        let ops = pack_csr_global(&d.inter, &b).unwrap();
        assert_eq!(ops[0].shape(), &[65]);
        assert_eq!(ops[1].shape(), &[512]);
        // row_ptr monotone, final rows flat
        let rp = ops[0].as_i32().unwrap();
        assert!(rp.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(rp[48], rp[64]);
    }

    #[test]
    fn csr_local_columns_in_range() {
        let d = decomp();
        let ops = pack_csr_local(&d.intra, 16, &bucket()).unwrap();
        let col = ops[1].as_i32().unwrap();
        assert!(col.iter().all(|&c| (0..16).contains(&c)));
    }

    #[test]
    fn coo_padding_is_zero_weight() {
        let d = decomp();
        let ops = pack_coo(&d.inter, &bucket()).unwrap();
        let val = ops[2].as_f32().unwrap();
        let nnz = d.inter.nnz();
        assert!(val[nnz..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dense_blocks_shape() {
        let d = decomp();
        let ops = pack_dense_blocks(&d.intra, 16, &bucket()).unwrap();
        assert_eq!(ops[0].shape(), &[4, 16, 16]);
    }

    #[test]
    fn tile_class_packs_to_grid_capacity_and_roundtrips() {
        use crate::kernels::tile::TileSparse;
        let d = decomp();
        let b = bucket();
        let ops = pack_tile_class(&d.intra, 16, &b).unwrap();
        // community 16 -> one tile slot per block
        assert_eq!(ops[0].shape(), &[4]);
        assert_eq!(ops[1].shape(), &[64]);
        assert_eq!(ops[2].shape(), &[4, 16, 16]);
        // the packed operands execute to the same aggregate
        let back = TileSparse::from_packed(
            d.intra.n_rows,
            16,
            ops[0].as_i32().unwrap(),
            ops[1].as_i32().unwrap(),
            ops[2].as_f32().unwrap(),
        );
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..48 * 2).map(|_| rng.normal_f32()).collect();
        let direct = TileSparse::from_block_diagonal_csr(&d.intra, 16).spmm(&x, 2);
        assert_eq!(back.spmm(&x, 2), direct);
        // a bucket with no tile slots rejects the class
        let mut tiny = bucket();
        tiny.blocks = 0;
        assert!(pack_tile_class(&d.intra, 16, &tiny).is_err());
    }

    #[test]
    fn rejects_oversize() {
        let mut rng = Rng::new(2);
        let g = planted_partition(128, 16, 0.5, 0.05, &mut rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 16, 0);
        assert!(pack_csr_global(&d.inter, &bucket()).is_err());
    }

    #[test]
    fn features_pad_and_truncate() {
        let b = bucket();
        let x: Vec<f32> = (0..10 * 12).map(|i| i as f32).collect();
        let t = pack_features(&x, 10, 12, &b).unwrap(); // truncate 12 -> 8
        assert_eq!(t.shape(), &[64, 8]);
        assert_eq!(t.as_f32().unwrap()[0..8], x[0..8]);
        let t2 = pack_features(&x[..10 * 4], 10, 4, &b).unwrap(); // extend 4 -> 8
        assert_eq!(t2.as_f32().unwrap()[4..8], [0.0; 4]);
    }

    #[test]
    fn labels_clamped_and_masked() {
        let b = bucket();
        let (lab, mask) = pack_labels_mask(&[0, 5, -1], &b).unwrap();
        let l = lab.as_i32().unwrap();
        assert_eq!(&l[..3], &[0, 1, 3]); // 5 % 4 = 1, -1 -> 3
        let m = mask.as_f32().unwrap();
        assert_eq!(&m[..4], &[1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn custom_mask_packs_verbatim() {
        // the sampled path: support rows masked out of the loss
        let b = bucket();
        let (lab, mask) = pack_labels_masked(&[1, 2, 3], &[1.0, 0.0, 1.0], &b).unwrap();
        assert_eq!(&lab.as_i32().unwrap()[..3], &[1, 2, 3]);
        assert_eq!(&mask.as_f32().unwrap()[..4], &[1.0, 0.0, 1.0, 0.0]);
        assert!(pack_labels_masked(&[1], &[1.0, 1.0], &b).is_err());
    }

    #[test]
    fn block_class_subset_zeroes_other_blocks() {
        let d = decomp();
        let b = bucket();
        // pack only block 0 of the intra part as dense tiles
        let ops = pack_block_class(KernelKind::DenseBlock, &d.intra, &[0], 16, &b).unwrap();
        let data = ops[0].as_f32().unwrap();
        let tile = 16 * 16;
        assert!(data[..tile].iter().any(|&v| v != 0.0), "member block packed");
        assert!(data[tile..].iter().all(|&v| v == 0.0), "non-members zeroed");
    }

    #[test]
    fn hybrid_assignment_packs_dense_slot_plus_merged_inter() {
        use crate::plan::{ClassAssignment, GearAssignment, SubgraphClass};
        let d = decomp();
        let b = bucket();
        let profile = d.intra_block_profile();
        // pick a threshold that genuinely splits the blocks
        let mut dens: Vec<f64> = (0..profile.len()).map(|i| profile.density(i)).collect();
        dens.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let threshold = (dens[0] + dens[dens.len() - 1]) / 2.0;
        let split = d.split_intra(threshold);
        if split.classes.len() < 2 {
            return; // degenerate sample: nothing to pack hybrid
        }
        let class_stat = |label| {
            let c = split.class(label).unwrap();
            (c.blocks.len(), c.rows, c.matrix.nnz())
        };
        let (db, dr, dn) = class_stat(crate::partition::DensityClass::Dense);
        let (sb, sr, sn) = class_stat(crate::partition::DensityClass::Sparse);
        let assignment = GearAssignment {
            threshold,
            classes: vec![
                ClassAssignment {
                    class: SubgraphClass::DenseIntra,
                    kernel: KernelKind::DenseBlock,
                    blocks: db,
                    rows: dr,
                    nnz: dn,
                    time_us: 1.0,
                },
                ClassAssignment {
                    class: SubgraphClass::SparseIntra,
                    kernel: KernelKind::CsrIntra,
                    blocks: sb,
                    rows: sr,
                    nnz: sn,
                    time_us: 1.0,
                },
                ClassAssignment {
                    class: SubgraphClass::Inter,
                    kernel: KernelKind::CsrInter,
                    blocks: 0,
                    rows: d.inter.n_rows,
                    nnz: d.inter.nnz(),
                    time_us: 1.0,
                },
            ],
            provenance: None,
        };
        let (iops, jops) = pack_assignment(&d, &assignment, &b).unwrap();
        // intra slot: dense tiles holding ONLY the dense class's entries
        assert_eq!(iops[0].shape(), &[4, 16, 16]);
        let dense_sum: f32 = iops[0].as_f32().unwrap().iter().sum();
        let expect_dense: f32 = split
            .class(crate::partition::DensityClass::Dense)
            .unwrap()
            .matrix
            .vals
            .iter()
            .sum();
        assert!((dense_sum - expect_dense).abs() < 1e-4);
        // inter slot: row_ptr tail counts sparse-class + inter entries
        let rp = jops[0].as_i32().unwrap();
        assert_eq!(rp[64] as usize, sn + d.inter.nnz());
    }

    #[test]
    fn uniform_assignment_packs_like_pack_pair() {
        use crate::plan::GearAssignment;
        use crate::kernels::KernelPair;
        let d = decomp();
        let b = bucket();
        let pair = KernelPair::new(KernelKind::CsrIntra, KernelKind::Coo);
        let profile = d.intra_block_profile();
        let rows: usize = profile.blocks.iter().map(|&(r, _)| r).sum();
        let assignment = GearAssignment::uniform(
            pair,
            (profile.len(), rows, d.intra.nnz(), 1.0),
            (d.inter.n_rows, d.inter.nnz(), 1.0),
        );
        let (a_i, a_j) = pack_assignment(&d, &assignment, &b).unwrap();
        let (p_i, p_j) = pack_pair(&d, pair.intra, pair.inter, &b).unwrap();
        assert_eq!(a_i.len(), p_i.len());
        assert_eq!(a_j.len(), p_j.len());
        for (x, y) in a_i.iter().zip(&p_i).chain(a_j.iter().zip(&p_j)) {
            assert_eq!(x.shape(), y.shape());
        }
    }

    #[test]
    fn pack_pair_full_graph_mode() {
        let d = decomp();
        let (iops, jops) = pack_pair(&d, None, KernelKind::CsrInter, &bucket()).unwrap();
        assert!(iops.is_empty());
        // whole matrix nnz = intra + inter
        let rp = jops[0].as_i32().unwrap();
        assert_eq!(rp[64] as usize, d.intra.nnz() + d.inter.nnz());
    }
}
