//! `16x16` MMA tile extraction for the tile-sparse kernel class.
//!
//! TC-GNN-style sparse-graph translation: per 16-row strip of a
//! block-diagonal class matrix, the distinct occupied columns are
//! condensed (column compaction) into 16-wide dense `16x16` tiles that
//! tensor-core fragments execute at full rate. Unlike the geometric
//! `DenseBlocks` format, only NON-EMPTY tiles are materialized — a
//! mid-density block pays for its occupied tiles, not its padded `c x c`
//! square. The payload stays f32 natively; the cost model
//! (`gpusim::kernel_cost::tile_sparse_cost_dims`) prices it at the
//! half-precision rate the MMA path stages it in.
//!
//! Layout per tile `t`:
//! * `strip_row[t]` — global row base of the tile's 16-row strip.
//! * `cols[t*16 .. t*16+16]` — the compacted global column ids, padded
//!   with `u32::MAX`.
//! * `data[t*256 .. (t+1)*256]` — row-major `16x16` dense payload;
//!   `data[t][r][p]` is the weight of `(strip_row[t]+r, cols[t*16+p])`.

use crate::graph::Csr;

/// MMA fragment edge length: tiles are `MMA_TILE x MMA_TILE`.
pub const MMA_TILE: usize = 16;

/// Geometric tile-grid capacity of `blocks` diagonal `community x
/// community` blocks: the occupied-tile count can never exceed it, and
/// the AOT bucket reserves exactly this many tile slots
/// (`pack::pack_tile_class`).
pub fn tile_capacity(blocks: usize, community: usize) -> usize {
    let g = community.max(1).div_ceil(MMA_TILE).max(1);
    blocks * g * g
}

/// A block-diagonal class matrix compacted into non-empty MMA tiles.
#[derive(Debug, Clone)]
pub struct TileSparse {
    /// Global row count of the source matrix (output height).
    pub rows: usize,
    /// Community (block) size of the source block diagonal.
    pub community: usize,
    /// Global row base per tile.
    pub strip_row: Vec<u32>,
    /// Compacted global column ids, `MMA_TILE` per tile, `u32::MAX` pad.
    pub cols: Vec<u32>,
    /// Dense `[n_tiles, MMA_TILE, MMA_TILE]` payload, row-major.
    pub data: Vec<f32>,
}

impl TileSparse {
    /// Extract the non-empty tiles of a block-diagonal matrix (a density
    /// class from `split_intra`, global row/column ids). Panics on an
    /// entry escaping its diagonal block: contract violation, same as
    /// [`DenseBlocks`](crate::graph::DenseBlocks).
    pub fn from_block_diagonal_csr(a: &Csr, community: usize) -> TileSparse {
        let c = community.max(1);
        let mut out = TileSparse {
            rows: a.n_rows,
            community: c,
            strip_row: Vec::new(),
            cols: Vec::new(),
            data: Vec::new(),
        };
        let mut strip: Vec<(usize, u32, f32)> = Vec::new(); // (local row, col, w)
        for base in (0..a.n_rows).step_by(MMA_TILE) {
            strip.clear();
            for r in base..(base + MMA_TILE).min(a.n_rows) {
                let (cols, vals) = a.row(r);
                for (&cc, &w) in cols.iter().zip(vals) {
                    assert_eq!(
                        cc as usize / c,
                        r / c,
                        "entry ({r},{cc}) escapes its diagonal block; split first"
                    );
                    strip.push((r - base, cc, w));
                }
            }
            if strip.is_empty() {
                continue;
            }
            // column compaction: distinct columns, condensed 16 per tile
            let mut distinct: Vec<u32> = strip.iter().map(|&(_, cc, _)| cc).collect();
            distinct.sort_unstable();
            distinct.dedup();
            let first_tile = out.strip_row.len();
            for chunk in distinct.chunks(MMA_TILE) {
                out.strip_row.push(base as u32);
                let mut padded = [u32::MAX; MMA_TILE];
                padded[..chunk.len()].copy_from_slice(chunk);
                out.cols.extend_from_slice(&padded);
                out.data.extend(std::iter::repeat(0.0).take(MMA_TILE * MMA_TILE));
            }
            for &(lr, cc, w) in &strip {
                let pos = distinct.binary_search(&cc).unwrap();
                let t = first_tile + pos / MMA_TILE;
                out.data[(t * MMA_TILE + lr) * MMA_TILE + pos % MMA_TILE] += w;
            }
        }
        out
    }

    /// Rebuild from packed AOT operands (`pack::pack_tile_class` layout):
    /// `cols` uses `-1` padding, zero-payload padding tiles are kept (they
    /// contribute exact zeros to the aggregate).
    pub fn from_packed(
        rows: usize,
        community: usize,
        strip_row: &[i32],
        cols: &[i32],
        data: &[f32],
    ) -> TileSparse {
        TileSparse {
            rows,
            community: community.max(1),
            strip_row: strip_row.iter().map(|&r| r as u32).collect(),
            cols: cols
                .iter()
                .map(|&cc| if cc < 0 { u32::MAX } else { cc as u32 })
                .collect(),
            data: data.to_vec(),
        }
    }

    pub fn n_tiles(&self) -> usize {
        self.strip_row.len()
    }

    /// Occupied fraction of the geometric tile grid — the exact
    /// counterpart of the sweep's `est_occupied_tiles` estimate, reported
    /// as `tile/occupied_frac` by the kernels bench.
    pub fn occupied_frac(&self) -> f64 {
        let cap = tile_capacity(self.rows.div_ceil(self.community), self.community);
        self.n_tiles() as f64 / cap.max(1) as f64
    }

    /// `y = A @ x` on the tile schedule: per tile one dense
    /// `16x16 @ 16xF` fragment product, accumulated into the strip's
    /// output rows — the CPU twin of the MMA kernel (zeros inside a tile
    /// are computed, like the dense schedule; absent tiles cost nothing).
    pub fn spmm(&self, x: &[f32], f: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; self.rows * f];
        for t in 0..self.n_tiles() {
            let base = self.strip_row[t] as usize;
            let height = MMA_TILE.min(self.rows - base);
            for lr in 0..height {
                let row = &self.data[(t * MMA_TILE + lr) * MMA_TILE..][..MMA_TILE];
                let out = &mut y[(base + lr) * f..(base + lr + 1) * f];
                for (pos, &w) in row.iter().enumerate() {
                    let cc = self.cols[t * MMA_TILE + pos];
                    if cc == u32::MAX {
                        continue; // column pad: no operand row
                    }
                    let src = &x[cc as usize * f..(cc as usize + 1) * f];
                    for (o, s) in out.iter_mut().zip(src) {
                        *o += w * s;
                    }
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::graph::Graph;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn tile_spmm_matches_csr_reference() {
        prop::check("TileSparse::spmm == Csr::spmm", 15, |rng| {
            let n = (rng.usize_below(6) + 2) * 16;
            let g = planted_partition(n, 16, 0.1 + rng.f64() * 0.8, 0.0, rng);
            let (intra, _) = Csr::gcn_normalized(&g).split_block_diagonal(16);
            let f = rng.usize_below(6) + 2;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let tiles = TileSparse::from_block_diagonal_csr(&intra, 16);
            let got = tiles.spmm(&x, f);
            for (a, b) in got.iter().zip(&intra.spmm(&x, f)) {
                prop::require_close(*a as f64, *b as f64, 1e-4, "tile spmm elem")?;
            }
            Ok(())
        });
    }

    #[test]
    fn handles_ragged_tail_and_wide_communities() {
        prop::check("ragged TileSparse == Csr::spmm", 15, |rng| {
            let c = [8, 16, 32, 64][rng.usize_below(4)];
            let n = rng.usize_below(150) + 3; // usually NOT a multiple of c
            let m = rng.usize_below(4 * n);
            let g = Graph::from_edges(
                n,
                (0..m).map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32)),
            );
            let (intra, _) = Csr::gcn_normalized(&g).split_block_diagonal(c);
            let f = 3;
            let x: Vec<f32> = (0..n * f).map(|_| rng.normal_f32()).collect();
            let got = TileSparse::from_block_diagonal_csr(&intra, c).spmm(&x, f);
            for (a, b) in got.iter().zip(&intra.spmm(&x, f)) {
                prop::require_close(*a as f64, *b as f64, 1e-4, "ragged tile elem")?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic]
    fn rejects_escaping_edges() {
        let a = Csr::from_triplets(32, 32, vec![(0, 20, 1.0)]);
        TileSparse::from_block_diagonal_csr(&a, 16);
    }

    #[test]
    fn occupancy_tracks_density() {
        let mut rng = Rng::new(7);
        let sparse = planted_partition(64 * 64, 64, 0.02, 0.0, &mut rng);
        let dense = planted_partition(64 * 64, 64, 0.9, 0.0, &mut rng);
        let frac = |g| {
            let (intra, _) = Csr::gcn_normalized(g).split_block_diagonal(64);
            TileSparse::from_block_diagonal_csr(&intra, 64).occupied_frac()
        };
        let (fs, fd) = (frac(&sparse), frac(&dense));
        assert!(fs < fd, "sparse {fs} vs dense {fd}");
        assert!(fd <= 1.0 && fs > 0.0);
    }

    #[test]
    fn column_compaction_beats_geometric_grid_on_few_columns() {
        // 64-wide block whose entries all hit 3 columns: the geometric
        // grid would hold 4 tiles per strip, compaction needs 1
        let t = Csr::from_triplets(
            64,
            64,
            vec![(0, 0, 1.0), (5, 21, 1.0), (9, 63, 1.0), (40, 0, 1.0)],
        );
        let tiles = TileSparse::from_block_diagonal_csr(&t, 64);
        assert_eq!(tiles.n_tiles(), 2, "one tile per non-empty strip");
        assert_eq!(tile_capacity(1, 64), 16);
        assert!((tiles.occupied_frac() - 2.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix_has_no_tiles() {
        let a = Csr::from_triplets(32, 32, vec![]);
        let tiles = TileSparse::from_block_diagonal_csr(&a, 16);
        assert_eq!(tiles.n_tiles(), 0);
        assert!(tiles.spmm(&vec![1.0; 32 * 2], 2).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn packed_roundtrip_preserves_spmm() {
        let mut rng = Rng::new(3);
        let g = planted_partition(64, 16, 0.4, 0.0, &mut rng);
        let (intra, _) = Csr::gcn_normalized(&g).split_block_diagonal(16);
        let t = TileSparse::from_block_diagonal_csr(&intra, 16);
        let strip: Vec<i32> = t.strip_row.iter().map(|&r| r as i32).collect();
        let cols: Vec<i32> = t
            .cols
            .iter()
            .map(|&c| if c == u32::MAX { -1 } else { c as i32 })
            .collect();
        let back = TileSparse::from_packed(64, 16, &strip, &cols, &t.data);
        let x: Vec<f32> = (0..64 * 2).map(|_| rng.normal_f32()).collect();
        assert_eq!(t.spmm(&x, 2), back.spmm(&x, 2));
    }
}
