//! Layer-wise neighbor samplers over a propagation matrix.
//!
//! The sampler walks the propagation CSR (row = destination, columns =
//! in-neighbors, weights = normalized propagation coefficients), so a
//! "neighbor" here is an *entry of the propagation row* — for GCN that
//! includes the self-loop the normalization added. Sampling happens
//! layer by layer: layer `l` draws up to `fanout[l]` entries from the
//! row of every node reached so far, so after `L` layers the batch holds
//! everything an `L`-layer aggregation of the targets can touch (under
//! [`Fanout::Full`], *exactly* everything — which is what makes sampled
//! and full-graph forwards agree on the targets; see
//! `rust/tests/sample_prop.rs`).
//!
//! Determinism: the same seed, targets, and fanouts reproduce the same
//! [`BatchSubgraph`] bit for bit — batches are identified by profile in
//! the plan cache, and the fixed-seed bench workload depends on it.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::graph::Csr;
use crate::util::rng::Rng;

use super::batch::BatchSubgraph;

/// Per-layer neighbor budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fanout {
    /// Keep every propagation entry of the row (full-neighbor fallback —
    /// sampled execution becomes exact for the batch targets).
    Full,
    /// Uniformly sample up to `k` distinct entries per row.
    Uniform(usize),
}

impl Fanout {
    pub fn as_string(&self) -> String {
        match self {
            Fanout::Full => "full".to_string(),
            Fanout::Uniform(k) => k.to_string(),
        }
    }
}

impl fmt::Display for Fanout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.as_string())
    }
}

impl FromStr for Fanout {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Fanout, Self::Err> {
        match s.trim() {
            "full" | "0" => Ok(Fanout::Full),
            other => {
                let k: usize = other
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad fanout {other:?}: {e}"))?;
                Ok(Fanout::Uniform(k))
            }
        }
    }
}

/// Parse a `--fanout` CLI list: comma-separated per-layer budgets, e.g.
/// `"10,10"` (two layers of 10) or `"full,full"`; `0` also means full.
pub fn parse_fanouts(s: &str) -> Result<Vec<Fanout>> {
    let out: Vec<Fanout> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| p.parse())
        .collect::<Result<_>>()
        .with_context(|| format!("parsing fanout list {s:?}"))?;
    if out.is_empty() {
        bail!("fanout list {s:?} is empty (expected e.g. \"10,10\" or \"full\")");
    }
    Ok(out)
}

/// Layer-wise neighbor sampler bound to one propagation matrix.
pub struct NeighborSampler<'a> {
    prop: &'a Csr,
    fanouts: Vec<Fanout>,
}

impl<'a> NeighborSampler<'a> {
    /// `prop` is the full graph's (square) propagation matrix; `fanouts`
    /// holds one per-layer budget per model layer, outermost first.
    pub fn new(prop: &'a Csr, fanouts: Vec<Fanout>) -> Result<NeighborSampler<'a>> {
        if prop.n_rows != prop.n_cols {
            bail!(
                "sampler needs a square propagation matrix, got {}x{}",
                prop.n_rows,
                prop.n_cols
            );
        }
        if fanouts.is_empty() {
            bail!("sampler needs at least one layer fanout");
        }
        Ok(NeighborSampler { prop, fanouts })
    }

    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }

    /// Sample one batch subgraph for `targets` (global vertex ids;
    /// duplicates are dropped). Local ids are assigned in discovery
    /// order, targets first, so `BatchSubgraph::targets()` is the
    /// deduplicated input prefix.
    pub fn sample(&self, targets: &[u32], rng: &mut Rng) -> BatchSubgraph {
        let n_full = self.prop.n_rows;
        let mut nodes: Vec<u32> = Vec::with_capacity(targets.len());
        let mut local: HashMap<u32, u32> = HashMap::with_capacity(targets.len() * 2);
        for &t in targets {
            debug_assert!((t as usize) < n_full, "target {t} out of range (n={n_full})");
            if let std::collections::hash_map::Entry::Vacant(slot) = local.entry(t) {
                slot.insert(nodes.len() as u32);
                nodes.push(t);
            }
        }
        let n_targets = nodes.len();

        // (dst_local, src_local, w) with global dedup across layers: the
        // same propagation entry reached twice must appear once, not sum.
        let mut triplets: Vec<(u32, u32, f32)> = Vec::new();
        let mut seen: HashSet<(u32, u32)> = HashSet::new();
        for &fanout in &self.fanouts {
            // Layer l samples the rows of EVERY node reached so far, so
            // after the last layer the rows needed by an L-layer
            // aggregation over the targets are all present.
            let frontier_len = nodes.len();
            for idx in 0..frontier_len {
                let u = nodes[idx];
                let (cols, vals) = self.prop.row(u as usize);
                let deg = cols.len();
                if deg == 0 {
                    continue;
                }
                let pick_all = match fanout {
                    Fanout::Full => true,
                    Fanout::Uniform(k) => deg <= k,
                };
                let chosen: Vec<usize> = if pick_all {
                    (0..deg).collect()
                } else {
                    let Fanout::Uniform(k) = fanout else { unreachable!() };
                    rng.sample_indices(deg, k)
                };
                let lu = idx as u32;
                for i in chosen {
                    let (v, w) = (cols[i], vals[i]);
                    if !seen.insert((u, v)) {
                        continue;
                    }
                    let lv = match local.entry(v) {
                        std::collections::hash_map::Entry::Occupied(e) => *e.get(),
                        std::collections::hash_map::Entry::Vacant(slot) => {
                            let id = nodes.len() as u32;
                            slot.insert(id);
                            nodes.push(v);
                            id
                        }
                    };
                    triplets.push((lu, lv, w));
                }
            }
        }

        let n = nodes.len();
        let csr = Csr::from_triplets(n, n, triplets);
        crate::obs::counter("sample.batches").inc();
        crate::obs::counter("sample.nodes").add(n as u64);
        crate::obs::counter("sample.edges").add(csr.nnz() as u64);
        BatchSubgraph { nodes, n_targets, csr }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::graph::Graph;

    fn prop_matrix(seed: u64, n: usize) -> Csr {
        let mut rng = Rng::new(seed);
        let g = planted_partition(n, 16, 0.4, 0.02, &mut rng);
        Csr::gcn_normalized(&g)
    }

    #[test]
    fn fanout_parsing_roundtrips() {
        assert_eq!(
            parse_fanouts("10,10").unwrap(),
            vec![Fanout::Uniform(10), Fanout::Uniform(10)]
        );
        assert_eq!(parse_fanouts("full").unwrap(), vec![Fanout::Full]);
        assert_eq!(parse_fanouts("0,5").unwrap(), vec![Fanout::Full, Fanout::Uniform(5)]);
        assert!(parse_fanouts("").is_err());
        assert!(parse_fanouts("ten").is_err());
        assert_eq!(Fanout::Uniform(7).to_string(), "7");
        assert_eq!(Fanout::Full.to_string(), "full");
    }

    #[test]
    fn fixed_seed_reproduces_identical_batches() {
        let a = prop_matrix(3, 128);
        let sampler =
            NeighborSampler::new(&a, vec![Fanout::Uniform(4), Fanout::Uniform(4)]).unwrap();
        let targets: Vec<u32> = (0..32).collect();
        let b1 = sampler.sample(&targets, &mut Rng::new(42));
        let b2 = sampler.sample(&targets, &mut Rng::new(42));
        assert_eq!(b1.nodes, b2.nodes);
        assert_eq!(b1.csr, b2.csr);
        let b3 = sampler.sample(&targets, &mut Rng::new(43));
        // a different seed almost surely samples a different subgraph
        assert!(b1.csr != b3.csr || b1.nodes != b3.nodes);
    }

    #[test]
    fn sampling_bumps_fanout_counters() {
        // Counters are process-global; assert on deltas.
        let batches = crate::obs::counter("sample.batches");
        let nodes = crate::obs::counter("sample.nodes");
        let edges = crate::obs::counter("sample.edges");
        let (b0, n0, e0) = (batches.get(), nodes.get(), edges.get());
        let a = prop_matrix(8, 64);
        let sampler = NeighborSampler::new(&a, vec![Fanout::Uniform(4)]).unwrap();
        let batch = sampler.sample(&(0..16).collect::<Vec<_>>(), &mut Rng::new(2));
        assert!(batches.get() > b0);
        assert!(nodes.get() - n0 >= batch.n() as u64);
        assert!(edges.get() - e0 >= batch.csr.nnz() as u64);
    }

    #[test]
    fn duplicate_targets_are_deduplicated() {
        let a = prop_matrix(4, 64);
        let sampler = NeighborSampler::new(&a, vec![Fanout::Uniform(3)]).unwrap();
        let batch = sampler.sample(&[5, 5, 9, 5], &mut Rng::new(1));
        assert_eq!(batch.targets(), &[5, 9]);
        assert_eq!(batch.n_targets, 2);
    }

    #[test]
    fn fanout_bounds_row_degree() {
        let a = prop_matrix(5, 128);
        let sampler = NeighborSampler::new(&a, vec![Fanout::Uniform(3)]).unwrap();
        let batch = sampler.sample(&(0..64).collect::<Vec<_>>(), &mut Rng::new(7));
        // every sampled row holds at most `fanout` entries
        for r in 0..batch.n_targets {
            let (cols, _) = batch.csr.row(r);
            assert!(cols.len() <= 3, "row {r} has {} entries", cols.len());
        }
    }

    #[test]
    fn full_fanout_keeps_every_target_row_entry() {
        let a = prop_matrix(6, 96);
        let sampler = NeighborSampler::new(&a, vec![Fanout::Full]).unwrap();
        let targets: Vec<u32> = vec![0, 17, 33];
        let batch = sampler.sample(&targets, &mut Rng::new(0));
        for (i, &t) in targets.iter().enumerate() {
            let (gcols, gvals) = a.row(t as usize);
            let (bcols, bvals) = batch.csr.row(i);
            assert_eq!(bcols.len(), gcols.len(), "target {t} row incomplete");
            // same multiset of (global col, weight)
            let mut got: Vec<(u32, f32)> = bcols
                .iter()
                .map(|&lc| batch.nodes[lc as usize])
                .zip(bvals.iter().copied())
                .collect();
            got.sort_by_key(|&(c, _)| c);
            let mut want: Vec<(u32, f32)> =
                gcols.iter().copied().zip(gvals.iter().copied()).collect();
            want.sort_by_key(|&(c, _)| c);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn isolated_targets_sample_empty_rows() {
        let g = Graph::empty(32);
        let a = Csr::adjacency(&g); // no entries at all
        let sampler = NeighborSampler::new(&a, vec![Fanout::Uniform(5)]).unwrap();
        let batch = sampler.sample(&[1, 2, 3], &mut Rng::new(0));
        assert_eq!(batch.n(), 3);
        assert_eq!(batch.csr.nnz(), 0);
    }

    #[test]
    fn rejects_bad_construction() {
        let a = prop_matrix(7, 32);
        assert!(NeighborSampler::new(&a, vec![]).is_err());
        let rect = Csr::from_triplets(2, 3, vec![(0, 1, 1.0)]);
        assert!(NeighborSampler::new(&rect, vec![Fanout::Full]).is_err());
    }
}
