//! The per-batch induced subgraph and its bridges back to the full graph.
//!
//! A [`BatchSubgraph`] is what one mini-batch executes: a local-id CSR
//! slice of the full propagation matrix holding exactly the sampled
//! entries (weights included — normalization stays global), the
//! local→global node map, and the target prefix. [`BatchSubgraph::decompose`]
//! turns it into a regular [`Decomposition`] via
//! [`Decomposition::from_propagation`], after which the whole existing
//! stack applies unchanged: block profiles, hybrid splits, plan
//! fingerprints, operand packing, and the native kernel mirrors.

use crate::coordinator::apply_perm;
use crate::graph::Csr;
use crate::partition::{Decomposition, Reorder};

/// One sampled batch: local-id subgraph + mapping back to global ids.
#[derive(Debug, Clone)]
pub struct BatchSubgraph {
    /// Local→global vertex ids, in discovery order; the first
    /// [`BatchSubgraph::n_targets`] entries are the batch's targets.
    pub nodes: Vec<u32>,
    /// How many leading `nodes` are targets (loss/classification rows).
    pub n_targets: usize,
    /// Sampled propagation slice in local ids. Weights are copied from
    /// the full matrix, so aggregation semantics match full-graph
    /// execution restricted to the sampled entries.
    pub csr: Csr,
}

impl BatchSubgraph {
    /// Vertices in the batch (targets + sampled support nodes).
    pub fn n(&self) -> usize {
        self.nodes.len()
    }

    /// Sampled propagation entries.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// The deduplicated target ids (global), in input order.
    pub fn targets(&self) -> &[u32] {
        &self.nodes[..self.n_targets]
    }

    /// Gather the batch's rows out of a full `[n_full, f]` feature
    /// buffer, producing `[n_batch, f]` in local order.
    pub fn gather_features(&self, x_full: &[f32], f: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n() * f];
        for (i, &g) in self.nodes.iter().enumerate() {
            let g = g as usize;
            out[i * f..(i + 1) * f].copy_from_slice(&x_full[g * f..(g + 1) * f]);
        }
        out
    }

    /// Gather the batch's labels out of a full label buffer.
    pub fn gather_labels(&self, labels_full: &[i32]) -> Vec<i32> {
        self.nodes.iter().map(|&g| labels_full[g as usize]).collect()
    }

    /// Loss mask in LOCAL order: 1.0 for target rows, 0.0 for support
    /// nodes (they exist only to feed aggregation, not the loss).
    pub fn target_mask(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.n()];
        for v in m.iter_mut().take(self.n_targets) {
            *v = 1.0;
        }
        m
    }

    /// Decompose the batch for kernel execution: reorder to concentrate
    /// density, split block-diagonal — weights preserved. The returned
    /// decomposition's `perm` maps LOCAL old→new ids; see
    /// [`BatchSubgraph::permute_for`] and [`BatchSubgraph::target_rows`].
    pub fn decompose(&self, reorder: Reorder, community: usize, seed: u64) -> Decomposition {
        Decomposition::from_propagation(&self.csr, reorder, community, seed)
    }

    /// Gather + permute features, labels, and the target mask into `d`'s
    /// reordered id space, ready for packing/execution. `d` must come
    /// from [`BatchSubgraph::decompose`] on this batch.
    pub fn permute_for(
        &self,
        d: &Decomposition,
        x_full: &[f32],
        f: usize,
        labels_full: &[i32],
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        debug_assert_eq!(d.perm.len(), self.n());
        let (x, labels) = apply_perm(
            &d.perm,
            &self.gather_features(x_full, f),
            &self.gather_labels(labels_full),
            f,
        );
        let mut mask = vec![0.0f32; self.n()];
        for i in 0..self.n_targets {
            mask[d.perm[i] as usize] = 1.0;
        }
        (x, labels, mask)
    }

    /// Row index of each target in `d`'s reordered space (for reading
    /// logits back out), in [`BatchSubgraph::targets`] order.
    pub fn target_rows(&self, d: &Decomposition) -> Vec<usize> {
        (0..self.n_targets).map(|i| d.perm[i] as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::sample::{Fanout, NeighborSampler};
    use crate::util::rng::Rng;

    fn batch(seed: u64) -> BatchSubgraph {
        let mut rng = Rng::new(seed);
        let g = planted_partition(96, 16, 0.4, 0.03, &mut rng);
        let a = Csr::gcn_normalized(&g);
        let sampler =
            NeighborSampler::new(&a, vec![Fanout::Uniform(5), Fanout::Uniform(5)]).unwrap();
        sampler.sample(&[3, 10, 40, 77], &mut rng)
    }

    #[test]
    fn gather_and_mask_follow_local_order() {
        let b = batch(1);
        let n_full = 96;
        let f = 3;
        let x: Vec<f32> = (0..n_full * f).map(|i| i as f32).collect();
        let gx = b.gather_features(&x, f);
        assert_eq!(gx.len(), b.n() * f);
        for (i, &g) in b.nodes.iter().enumerate() {
            assert_eq!(gx[i * f], (g as usize * f) as f32);
        }
        let labels: Vec<i32> = (0..n_full as i32).collect();
        let gl = b.gather_labels(&labels);
        assert_eq!(gl.len(), b.n());
        assert_eq!(gl[0], b.nodes[0] as i32);
        let m = b.target_mask();
        assert_eq!(m.iter().filter(|&&v| v == 1.0).count(), b.n_targets);
        assert!(m[..b.n_targets].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn decompose_preserves_batch_entries_and_targets() {
        let b = batch(2);
        let d = b.decompose(Reorder::Metis, 16, 7);
        assert_eq!(d.graph.n, b.n());
        assert_eq!(d.intra.nnz() + d.inter.nnz(), b.nnz());
        // target rows address the same global vertices after reordering
        let rows = b.target_rows(&d);
        assert_eq!(rows.len(), b.n_targets);
        for (i, &r) in rows.iter().enumerate() {
            assert_eq!(d.perm[i] as usize, r);
        }
        // permuted mask marks exactly the target rows
        let labels = vec![0i32; 96];
        let xf = vec![0.0f32; 96 * 2];
        let (_, _, mask) = b.permute_for(&d, &xf, 2, &labels);
        assert_eq!(mask.iter().filter(|&&v| v == 1.0).count(), b.n_targets);
        for &r in &rows {
            assert_eq!(mask[r], 1.0);
        }
    }
}
