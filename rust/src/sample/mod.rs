//! Mini-batch neighbor sampling — the workload that stresses
//! subgraph-level kernel adaptivity hardest.
//!
//! Full-graph training decides kernels once; neighbor-sampled training
//! (GraphSAGE-style) materializes a fresh induced subgraph per batch,
//! each with its own density profile. This module provides the sampling
//! substrate:
//!
//! * [`sampler`] — layer-wise neighbor samplers over a propagation
//!   matrix: uniform fanout ([`Fanout::Uniform`]) and the full-neighbor
//!   fallback ([`Fanout::Full`]), deterministic under a seed.
//! * [`batch`] — [`BatchSubgraph`], the per-batch induced subgraph: a
//!   local-id CSR whose weights are copied from the FULL graph's
//!   propagation matrix (so full-fanout batches reproduce full-graph
//!   results exactly), plus the local→global node mapping.
//!
//! Downstream, `plan::BatchPlanner` amortizes kernel planning across
//! batches with similar density *profiles*,
//! `coordinator::sampled::train_sampled` runs the mini-batch training
//! loop, and `serve::SampledInference` serves target-node inference on
//! graphs too large to pack whole. See `rust/DESIGN.md` Sec. 10.

pub mod batch;
pub mod sampler;

pub use batch::BatchSubgraph;
pub use sampler::{parse_fanouts, Fanout, NeighborSampler};
