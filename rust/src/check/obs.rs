//! Obs analyzer: exported Chrome traces and the metrics snapshot they
//! carry (AG040–AG042).
//!
//! Traces must reparse and pass the same `validate_pairing` the writer
//! ran (AG040) with per-thread monotone timestamps (AG041), and every
//! counter in the embedded metrics snapshot must follow the
//! `subsystem.noun.verb` naming rule from `obs::metrics` (AG042 —
//! Warn, because two legacy `sample.*` counters are asserted by name
//! in tests and renaming them is a separate, deliberate break).

use std::collections::BTreeMap;
use std::path::Path;

use crate::check::{CheckContext, Diagnostics, LintCode};
use crate::obs::Trace;
use crate::util::json::{self, Json};

pub const CODES: &[LintCode] = &[
    LintCode::AuditSkipped,
    LintCode::TraceMalformed,
    LintCode::TraceNonMonotonic,
    LintCode::CounterNaming,
];

/// `subsystem.noun.verb`: exactly three non-empty dot segments of
/// `[a-z0-9_]`.
pub fn counter_name_ok(name: &str) -> bool {
    let segs: Vec<&str> = name.split('.').collect();
    segs.len() == 3
        && segs.iter().all(|s| {
            !s.is_empty()
                && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        })
}

/// Audit one exported trace document. `obs::write_trace` runs this as
/// its debug-build self-check.
pub fn lint_trace_doc(doc: &Json, loc: &str, diags: &mut Diagnostics) {
    let trace = match Trace::from_chrome_json(doc) {
        Ok(t) => t,
        Err(e) => {
            diags.emit(LintCode::TraceMalformed, loc, format!("{e:#}"));
            return;
        }
    };
    if let Err(e) = trace.validate_pairing() {
        diags.emit(LintCode::TraceMalformed, loc, format!("{e:#}"));
    }
    let mut last: BTreeMap<u64, f64> = BTreeMap::new();
    for ev in &trace.events {
        if let Some(&prev) = last.get(&ev.tid) {
            if ev.ts_us < prev {
                diags.emit(
                    LintCode::TraceNonMonotonic,
                    loc,
                    format!("tid {}: ts {} after {} ({})", ev.tid, ev.ts_us, prev, ev.name),
                );
                break;
            }
        }
        last.insert(ev.tid, ev.ts_us);
    }
    if let Some(counters) = doc.get("metrics").get("counters").as_obj() {
        for name in counters.keys() {
            if !counter_name_ok(name) {
                diags.emit(
                    LintCode::CounterNaming,
                    loc,
                    format!("counter {name:?} is not subsystem.noun.verb"),
                );
            }
        }
    }
}

/// Audit one trace file on disk.
pub fn lint_trace_file(path: &Path, diags: &mut Diagnostics) {
    let loc = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            diags.emit(LintCode::TraceMalformed, &loc, format!("read failed: {e}"));
            return;
        }
    };
    match json::parse(&text) {
        Ok(doc) => lint_trace_doc(&doc, &loc, diags),
        Err(e) => diags.emit(LintCode::TraceMalformed, &loc, format!("parse failed: {e}")),
    }
}

/// Analyzer entry point: audit every trace file handed to the run.
pub fn run(ctx: &CheckContext, diags: &mut Diagnostics) {
    if ctx.traces.is_empty() {
        diags.emit(LintCode::AuditSkipped, "obs", "no traces to audit");
        return;
    }
    for p in &ctx.traces {
        lint_trace_file(p, diags);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(text: &str) -> Vec<&'static str> {
        let mut d = Diagnostics::new("obs");
        lint_trace_doc(&json::parse(text).unwrap(), "trace", &mut d);
        d.as_slice().iter().map(|x| x.code.code()).collect()
    }

    fn event(name: &str, ph: &str, ts: f64) -> String {
        format!(
            r#"{{"cat":"adaptgear","name":"{name}","ph":"{ph}","pid":1,"tid":1,"ts":{ts}}}"#
        )
    }

    #[test]
    fn naming_rule() {
        assert!(counter_name_ok("plan.cache.hit"));
        assert!(counter_name_ok("stream.delta.applied"));
        assert!(!counter_name_ok("sample.batches"));
        assert!(!counter_name_ok("a.b.c.d"));
        assert!(!counter_name_ok("Plan.Cache.Hit"));
        assert!(!counter_name_ok("plan..hit"));
    }

    #[test]
    fn paired_trace_is_clean() {
        let doc = format!(
            r#"{{"traceEvents":[{},{}],"metrics":{{"counters":{{"plan.cache.hit":1}}}}}}"#,
            event("plan.sweep", "B", 1.0),
            event("plan.sweep", "E", 2.0)
        );
        assert!(lint(&doc).is_empty());
    }

    #[test]
    fn crossed_spans_are_ag040() {
        let doc = format!(
            r#"{{"traceEvents":[{},{},{},{}]}}"#,
            event("a", "B", 1.0),
            event("b", "B", 2.0),
            event("a", "E", 3.0),
            event("b", "E", 4.0)
        );
        assert!(lint(&doc).contains(&"AG040"));
    }

    #[test]
    fn backwards_clock_is_ag041() {
        let doc = format!(
            r#"{{"traceEvents":[{},{}]}}"#,
            event("a", "B", 5.0),
            event("a", "E", 1.0)
        );
        assert!(lint(&doc).contains(&"AG041"));
    }

    #[test]
    fn bad_counter_name_is_ag042_warn() {
        let doc = r#"{"traceEvents":[],"metrics":{"counters":{"bad":1}}}"#;
        let mut d = Diagnostics::new("obs");
        lint_trace_doc(&json::parse(doc).unwrap(), "trace", &mut d);
        let only = &d.as_slice()[0];
        assert_eq!(only.code.code(), "AG042");
        assert_eq!(only.severity, crate::check::Severity::Warn);
    }
}
