//! Plan analyzer: audits every plan in the `PlanStore` (AG020–AG029,
//! AG035/AG036, AG003).
//!
//! Three audit tiers, each gated on what can actually be re-derived:
//!
//! 1. **Structural** ([`lint_plan_json`], fs-free): the document parses
//!    as a v3 `GearPlan`, its threshold and class layout are legal, all
//!    numerics are finite, the sweep provenance is self-consistent, and
//!    every chosen kernel is the argmin of its persisted candidate
//!    costs. `PlanStore::save` runs exactly this tier as its
//!    debug-build self-check.
//! 2. **Re-derivation**: for plans labeled with a known synthetic
//!    dataset, rebuild the graph from `(dataset, scale, seed)`,
//!    redecompose, and re-check the v3 fingerprint (AG024) plus
//!    `GearAssignment::covers()` (AG025). Skipped with AG000 when the
//!    topology is unrecoverable (anonymous graphs, streamed versions).
//! 3. **Bucket**: against the artifacts manifest, re-check edge-cap
//!    admissibility of the lowered operands (AG026) and recompute
//!    hybrid per-class analytic costs via `class_kernel_cost` to catch
//!    cost-model drift (AG028, Warn).
//!
//! Argmin severity (AG027) honors the plan's clock: analytic/sim plans
//! persisted the exact numbers the decision was made from, so a
//! mismatch is an Error; wall-clock plans recorded measurements whose
//! re-ranking is expected jitter, so it degrades to Warn.

use std::path::Path;

use crate::check::{CheckContext, Diagnostics, LintCode, Severity};
use crate::coordinator::pipeline::propagation_for;
use crate::gpusim::kernel_cost::CostCtx;
use crate::gpusim::{class_kernel_cost, ClassDims, GpuModel};
use crate::graph::datasets;
use crate::kernels::{candidates, KernelKind, Role};
use crate::partition::Decomposition;
use crate::plan::{Fingerprint, GearPlan, SubgraphClass};
use crate::runtime::{BucketInfo, Manifest};
use crate::util::json::{self, Json};

pub const CODES: &[LintCode] = &[
    LintCode::AuditSkipped,
    LintCode::NonFinite,
    LintCode::PlanUnreadable,
    LintCode::PlanFilenameMismatch,
    LintCode::PlanStructure,
    LintCode::PlanFingerprintMismatch,
    LintCode::PlanCoverage,
    LintCode::PlanEdgeCap,
    LintCode::PlanNotArgmin,
    LintCode::PlanCostDrift,
    LintCode::PlanProvenance,
    LintCode::PlanFeatDensity,
    LintCode::PlanFeatDensityDrift,
];

/// Candidate outcome labels `SweepProvenance` is allowed to record.
const OUTCOMES: [&str; 5] =
    ["chosen", "uniform_dense", "uniform_sparse", "considered", "rejected_edge_cap"];

/// Tier-1 structural audit of one plan document. Returns the decoded
/// plan when it parsed, so callers can continue to deeper tiers; emits
/// and returns `None` when it did not.
pub fn lint_plan_json(doc: &Json, loc: &str, diags: &mut Diagnostics) -> Option<GearPlan> {
    let plan = match GearPlan::from_json(doc) {
        Ok(p) => p,
        Err(e) => {
            diags.emit(LintCode::PlanUnreadable, loc, format!("{e:#}"));
            return None;
        }
    };
    lint_structure(&plan, loc, diags);
    lint_finite(&plan, loc, diags);
    lint_provenance(&plan, loc, diags);
    lint_argmin(&plan, loc, diags);
    lint_feat_density(doc, loc, diags);
    Some(plan)
}

/// AG035: a versioned (v4+) plan document must carry a `feat_density`
/// in [0, 1]. The decoder is deliberately tolerant — an absent field
/// reads as dense so density-blind (v3 and older) files keep loading —
/// which is exactly why this must be a raw-document check: a v4 writer
/// that dropped or corrupted the field persisted a plan whose cache key
/// and pricing cannot be re-derived.
fn lint_feat_density(doc: &Json, loc: &str, diags: &mut Diagnostics) {
    let version = doc.get("version").as_f64().unwrap_or(0.0);
    if version < 4.0 {
        return; // pre-density generations legitimately lack the field
    }
    match doc.get("feat_density").as_f64() {
        None => diags.emit(
            LintCode::PlanFeatDensity,
            loc,
            format!("version {version} plan carries no feat_density field"),
        ),
        Some(rho) if !(0.0..=1.0).contains(&rho) => diags.emit(
            LintCode::PlanFeatDensity,
            loc,
            format!("feat_density {rho} outside [0, 1]"),
        ),
        Some(_) => {}
    }
}

/// AG022: threshold range, class layout, dense-class kernel registry
/// membership.
fn lint_structure(plan: &GearPlan, loc: &str, diags: &mut Diagnostics) {
    let a = &plan.assignment;
    if !(0.0..=2.0).contains(&a.threshold) {
        diags.emit(
            LintCode::PlanStructure,
            loc,
            format!("threshold {} outside [0, 2]", a.threshold),
        );
    }
    let inter_count = a.classes.iter().filter(|c| c.class == SubgraphClass::Inter).count();
    let last_is_inter = a.classes.last().map(|c| c.class) == Some(SubgraphClass::Inter);
    if inter_count != 1 || !last_is_inter {
        diags.emit(
            LintCode::PlanStructure,
            loc,
            format!("want exactly one trailing inter class, got {inter_count} in {:?} order", {
                a.classes.iter().map(|c| c.class.as_str()).collect::<Vec<_>>()
            }),
        );
    }
    for pair in a.classes.windows(2) {
        if pair[0].class == pair[1].class {
            diags.emit(
                LintCode::PlanStructure,
                loc,
                format!("duplicate class {}", pair[0].class.as_str()),
            );
        }
    }
    for c in &a.classes {
        if c.class == SubgraphClass::DenseIntra
            && !candidates(Role::DenseClass).contains(&c.kernel)
        {
            diags.emit(
                LintCode::PlanStructure,
                loc,
                format!(
                    "dense_intra class runs {} (not a dense-class kernel)",
                    c.kernel.as_str()
                ),
            );
        }
    }
}

/// AG003: every numeric field a plan persists must be finite. The JSON
/// writer rejects non-finite floats outright, but plans can also arrive
/// from other writers (`1e999` parses as +inf), so the analyzer checks
/// semantically.
fn lint_finite(plan: &GearPlan, loc: &str, diags: &mut Diagnostics) {
    let mut bad = |field: &str, v: f64, diags: &mut Diagnostics| {
        if !v.is_finite() {
            diags.emit(LintCode::NonFinite, loc, format!("{field} = {v}"));
        }
    };
    bad("scale", plan.scale, diags);
    bad("assignment.threshold", plan.assignment.threshold, diags);
    bad("monitor_overhead_us", plan.monitor_overhead_us, diags);
    for c in &plan.assignment.classes {
        bad(&format!("class {} time_us", c.class.as_str()), c.time_us, diags);
    }
    for (name, map) in [("intra_times", &plan.intra_times), ("inter_times", &plan.inter_times)] {
        for (k, &v) in map {
            bad(&format!("{name}[{k}]"), v, diags);
        }
    }
    for (field, v) in [
        ("projected.aggregate_us", plan.projected.aggregate_us),
        ("projected.update_us", plan.projected.update_us),
        ("projected.overhead_us", plan.projected.overhead_us),
    ] {
        bad(field, v, diags);
    }
    if let Some(p) = &plan.assignment.provenance {
        bad("provenance.threshold", p.threshold, diags);
        for cc in &p.class_costs {
            for (k, &v) in &cc.costs {
                bad(&format!("provenance.class_costs[{}][{k}]", cc.class.as_str()), v, diags);
            }
        }
        for cand in &p.candidates {
            bad("provenance.candidate.threshold", cand.threshold, diags);
            if let Some(t) = cand.total_us {
                bad("provenance.candidate.total_us", t, diags);
            }
        }
    }
}

/// AG029: the sweep provenance must describe the assignment it rides
/// on — same threshold, only known outcome labels.
fn lint_provenance(plan: &GearPlan, loc: &str, diags: &mut Diagnostics) {
    let Some(p) = &plan.assignment.provenance else { return };
    if p.threshold != plan.assignment.threshold {
        diags.emit(
            LintCode::PlanProvenance,
            loc,
            format!(
                "provenance threshold {} != assignment threshold {}",
                p.threshold, plan.assignment.threshold
            ),
        );
    }
    for cand in &p.candidates {
        if !OUTCOMES.contains(&cand.outcome.as_str()) {
            diags.emit(
                LintCode::PlanProvenance,
                loc,
                format!("unknown candidate outcome {:?}", cand.outcome),
            );
        }
    }
}

/// AG027: each class's chosen kernel must be the argmin of the
/// candidate costs the sweep persisted for it, enumerated via the
/// `kernels::spec::candidates` registry for the class's role. Uniform
/// extremes are exempt: a lone class is pinned to its slot-compatible
/// kernel by the two-slot lowering even when an alternative prices
/// lower. Candidates without a recorded cost (a vetoed tile class, or a
/// plan persisted before the kernel existed) simply don't participate.
fn lint_argmin(plan: &GearPlan, loc: &str, diags: &mut Diagnostics) {
    let Some(prov) = &plan.assignment.provenance else { return };
    let analytic = matches!(plan.provenance.clock.as_str(), "analytic" | "sim");
    let severity = if analytic { Severity::Error } else { Severity::Warn };
    for c in &plan.assignment.classes {
        // Uniform plans keep the sweep's provenance but rebuild the
        // assignment from the planner's own winner, so a class without
        // a matching candidate row is expected — skip, don't guess.
        let Some(cand) = prov.class_costs.iter().find(|cc| cc.class == c.class) else {
            continue;
        };
        let audited: &[KernelKind] = match c.class {
            SubgraphClass::DenseIntra if !plan.assignment.is_hybrid() => continue,
            SubgraphClass::DenseIntra => candidates(Role::DenseClass),
            SubgraphClass::SparseIntra if !plan.assignment.is_hybrid() => continue,
            SubgraphClass::SparseIntra => candidates(Role::SparseClass),
            SubgraphClass::Inter => candidates(Role::Inter),
        };
        let Some(&chosen_cost) = cand.costs.get(c.kernel.as_str()) else {
            diags.emit_with(
                LintCode::PlanNotArgmin,
                severity,
                loc,
                format!(
                    "class {} chose {} but no candidate cost was recorded for it",
                    c.class.as_str(),
                    c.kernel.as_str()
                ),
            );
            continue;
        };
        let min = audited
            .iter()
            .filter_map(|k| cand.costs.get(k.as_str()))
            .fold(f64::INFINITY, |m, &v| m.min(v));
        if min.is_finite() && chosen_cost > min * (1.0 + 1e-6) + 1e-9 {
            diags.emit_with(
                LintCode::PlanNotArgmin,
                severity,
                loc,
                format!(
                    "class {} chose {} at {:.3}us but a candidate costs {:.3}us",
                    c.class.as_str(),
                    c.kernel.as_str(),
                    chosen_cost,
                    min
                ),
            );
        }
    }
}

/// Tier-2: rebuild the selection problem from the plan's own labels and
/// re-check fingerprint + coverage. Emits AG000 when the topology is
/// not re-derivable from what the plan recorded.
fn lint_rederive(plan: &GearPlan, loc: &str, diags: &mut Diagnostics) {
    if plan.dataset.is_empty() {
        diags.emit(LintCode::AuditSkipped, loc, "anonymous graph: fingerprint not re-derivable");
        return;
    }
    let Some(spec) = datasets::find(&plan.dataset) else {
        diags.emit(
            LintCode::AuditSkipped,
            loc,
            format!("dataset {:?} unknown: fingerprint not re-derivable", plan.dataset),
        );
        return;
    };
    if !(plan.scale > 0.0 && plan.scale <= 1.0) {
        diags.emit(LintCode::AuditSkipped, loc, format!("scale {} not stageable", plan.scale));
        return;
    }
    if plan.graph_version > 0 {
        diags.emit(
            LintCode::AuditSkipped,
            loc,
            format!("graph_version {}: mutated topology not re-derivable", plan.graph_version),
        );
        return;
    }
    let data = spec.build_scaled(plan.scale, plan.seed);
    let d = Decomposition::build(
        &data.graph,
        plan.reorder,
        propagation_for(plan.model),
        plan.community,
        plan.seed,
    );
    // AG036 — the plan's assumed feature density vs the density measured
    // on the re-derived synthetic features (nonzero fraction). The wide
    // 0.75 absolute tolerance only catches plans priced for a sparsity
    // the workload clearly does not have (rho ~ 0 against dense data);
    // top-k plans keyed off the hidden width legitimately sit below the
    // raw-input density. Runs before the fingerprint gate: drift is
    // observable even when the fingerprint no longer recomputes.
    let x = data.features(16);
    let measured = if x.is_empty() {
        1.0
    } else {
        x.iter().filter(|&&v| v != 0.0).count() as f64 / x.len() as f64
    };
    if (plan.feat_density - measured).abs() > 0.75 {
        diags.emit(
            LintCode::PlanFeatDensityDrift,
            loc,
            format!(
                "plan assumes feature density {:.4} but re-derived features measure {measured:.4}",
                plan.feat_density
            ),
        );
    }
    let fp = Fingerprint::of_full(&d, plan.model, plan.graph_version, plan.feat_density);
    if fp != plan.fingerprint {
        diags.emit(
            LintCode::PlanFingerprintMismatch,
            loc,
            format!("stored {} but topology re-derives {fp}", plan.fingerprint),
        );
        return;
    }
    if let Err(e) = plan.assignment.covers(&d) {
        diags.emit(LintCode::PlanCoverage, loc, format!("{e:#}"));
    }
}

/// Tier-3: audits that need the bucket geometry from the manifest.
fn lint_against_bucket(plan: &GearPlan, bucket: &BucketInfo, loc: &str, diags: &mut Diagnostics) {
    // AG026 — the two-slot lowering packs the first intra class into
    // the intra operand and merges every later (sparse) class into the
    // inter operand; that merged operand must fit the bucket edge cap,
    // exactly as the sweep's admissibility veto priced it.
    let merged: usize = plan
        .assignment
        .classes
        .iter()
        .filter(|c| c.class.is_intra())
        .skip(1)
        .map(|c| c.nnz)
        .sum();
    let inter_nnz: usize = plan
        .assignment
        .classes
        .iter()
        .filter(|c| c.class == SubgraphClass::Inter)
        .map(|c| c.nnz)
        .sum();
    if merged + inter_nnz > bucket.edges {
        diags.emit(
            LintCode::PlanEdgeCap,
            loc,
            format!(
                "inter operand holds {} edges (inter {inter_nnz} + merged {merged}) but bucket {} caps at {}",
                merged + inter_nnz,
                bucket.name,
                bucket.edges
            ),
        );
    }
    // AG028 — hybrid intra classes persist the analytic sweep's
    // mean-width class costs verbatim (whatever the plan's clock), so
    // they must recompute from `class_kernel_cost` on today's model.
    if !plan.assignment.is_hybrid() {
        return;
    }
    let Some(gpu) = GpuModel::by_name(&plan.provenance.gpu) else {
        diags.emit(
            LintCode::AuditSkipped,
            loc,
            format!("gpu {:?} unknown: cost drift not recomputable", plan.provenance.gpu),
        );
        return;
    };
    let widths = [bucket.features, bucket.hidden];
    for c in plan.assignment.classes.iter().filter(|c| c.class.is_intra()) {
        if !matches!(
            c.kernel,
            KernelKind::CsrIntra
                | KernelKind::DenseBlock
                | KernelKind::Coo
                | KernelKind::TileSparse
        ) {
            continue;
        }
        let dims = ClassDims { kind: c.kernel, blocks: c.blocks, rows: c.rows, nnz: c.nnz };
        let mean: f64 = widths
            .iter()
            .map(|&w| {
                // reprice at the density the sweep assumed, or the drift
                // check would flag every sparse-feature plan
                let ctx = CostCtx::new(dims, w, plan.community, gpu)
                    .with_feat_density(plan.feat_density);
                class_kernel_cost(&ctx).time_us
            })
            .sum::<f64>()
            / widths.len() as f64;
        let rel = (mean - c.time_us).abs() / mean.abs().max(1e-12);
        if rel > 1e-3 {
            diags.emit(
                LintCode::PlanCostDrift,
                loc,
                format!(
                    "class {}: recorded {:.3}us, cost model now says {:.3}us (rel {:.2e})",
                    c.class.as_str(),
                    c.time_us,
                    mean,
                    rel
                ),
            );
        }
    }
}

/// Full three-tier audit of one plan file on disk.
pub fn lint_plan_file(path: &Path, manifest: Option<&Manifest>, diags: &mut Diagnostics) {
    let loc = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            diags.emit(LintCode::PlanUnreadable, &loc, format!("read failed: {e}"));
            return;
        }
    };
    let doc = match json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            diags.emit(LintCode::PlanUnreadable, &loc, format!("parse failed: {e}"));
            return;
        }
    };
    let Some(plan) = lint_plan_json(&doc, &loc, diags) else { return };
    // AG021 — the store keys files by fingerprint; a renamed or
    // hand-edited file would serve the wrong selection problem.
    let want = format!("plan_{}.json", plan.fingerprint);
    if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
        if name != want {
            diags.emit(
                LintCode::PlanFilenameMismatch,
                &loc,
                format!("file {name} holds fingerprint {}", plan.fingerprint),
            );
        }
    }
    lint_rederive(&plan, &loc, diags);
    match manifest.and_then(|m| m.buckets.get(&plan.bucket)) {
        Some(bucket) => lint_against_bucket(&plan, bucket, &loc, diags),
        None => diags.emit(
            LintCode::AuditSkipped,
            &loc,
            format!("bucket {:?} not in manifest: edge-cap/cost-drift audit skipped", plan.bucket),
        ),
    }
}

/// Analyzer entry point: audit every `plans/plan_*.json` under the
/// artifacts dir.
pub fn run(ctx: &CheckContext, diags: &mut Diagnostics) {
    if !ctx.plans {
        diags.emit(LintCode::AuditSkipped, "plans", "no plan store to audit");
        return;
    }
    let dir = ctx.artifacts.join("plans");
    let entries = match std::fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) => {
            diags.emit(
                LintCode::AuditSkipped,
                dir.display().to_string(),
                format!("plan store unreadable: {e}"),
            );
            return;
        }
    };
    let manifest = Manifest::load(&ctx.artifacts).ok();
    let mut paths: Vec<_> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("plan_") && n.ends_with(".json"))
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        diags.emit(LintCode::AuditSkipped, dir.display().to_string(), "plan store is empty");
        return;
    }
    for p in &paths {
        lint_plan_file(p, manifest.as_ref(), diags);
    }
}
