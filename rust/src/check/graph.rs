//! Graph analyzer: CSR well-formedness and decomposition invariants
//! (AG001–AG006).
//!
//! The helpers here are the shared substrate the other analyzers build
//! on — `stream` lints its replayed overlay through [`lint_csr`] and
//! [`lint_symmetric`] too. The analyzer's own `run` is an always-on
//! self-audit: it builds a small planted-mixed decomposition from
//! scratch and lints it, so a regression in reorder / normalization /
//! block-splitting is caught even on a checkout with no artifacts at
//! all.

use crate::check::{CheckContext, Diagnostics, LintCode};
use crate::graph::datasets;
use crate::graph::Csr;
use crate::partition::{Decomposition, Propagation, Reorder};

pub const CODES: &[LintCode] = &[
    LintCode::AuditSkipped,
    LintCode::CsrIndptr,
    LintCode::CsrCols,
    LintCode::NonFinite,
    LintCode::AsymmetricMatrix,
    LintCode::BlockDiagonal,
    LintCode::BadPermutation,
];

/// Structural CSR audit: row_ptr shape (AG001), column order/range
/// (AG002), finite values (AG003). Returns whether the matrix is
/// well-formed enough for the deeper audits (symmetry, block coverage)
/// to run without slicing out of bounds.
pub fn lint_csr(csr: &Csr, what: &str, diags: &mut Diagnostics) -> bool {
    if csr.row_ptr.len() != csr.n_rows + 1 {
        diags.emit(
            LintCode::CsrIndptr,
            what,
            format!("row_ptr has {} entries for {} rows (want rows + 1)", csr.row_ptr.len(), csr.n_rows),
        );
        return false;
    }
    let mut ok = true;
    if csr.row_ptr.first() != Some(&0) {
        diags.emit(LintCode::CsrIndptr, what, "row_ptr does not start at 0");
        ok = false;
    }
    if let Some(w) = csr.row_ptr.windows(2).find(|w| w[1] < w[0]) {
        diags.emit(
            LintCode::CsrIndptr,
            what,
            format!("row_ptr not monotone: {} then {}", w[0], w[1]),
        );
        ok = false;
    }
    let last = *csr.row_ptr.last().unwrap() as usize;
    if last != csr.col_idx.len() {
        diags.emit(
            LintCode::CsrIndptr,
            what,
            format!("row_ptr ends at {last} but col_idx holds {} entries", csr.col_idx.len()),
        );
        ok = false;
    }
    if csr.vals.len() != csr.col_idx.len() {
        diags.emit(
            LintCode::CsrIndptr,
            what,
            format!("{} vals for {} col_idx entries", csr.vals.len(), csr.col_idx.len()),
        );
        ok = false;
    }
    // Non-finite values are detectable even when the structure is off.
    if let Some((i, v)) = csr.vals.iter().enumerate().find(|(_, v)| !v.is_finite()) {
        diags.emit(LintCode::NonFinite, what, format!("vals[{i}] = {v}"));
        ok = false;
    }
    if !ok {
        return false;
    }
    // Per-row column audit, first violation only (one bad permutation
    // would otherwise flood the report with one finding per row).
    'rows: for r in 0..csr.n_rows {
        let lo = csr.row_ptr[r] as usize;
        let hi = csr.row_ptr[r + 1] as usize;
        let cols = &csr.col_idx[lo..hi];
        for (k, &c) in cols.iter().enumerate() {
            if c as usize >= csr.n_cols {
                diags.emit(
                    LintCode::CsrCols,
                    what,
                    format!("row {r}: col {c} out of range (n_cols = {})", csr.n_cols),
                );
                ok = false;
                break 'rows;
            }
            if k > 0 && cols[k - 1] >= c {
                let msg = if cols[k - 1] == c {
                    format!("row {r}: duplicate col {c}")
                } else {
                    format!("row {r}: cols unsorted ({} before {c})", cols[k - 1])
                };
                diags.emit(LintCode::CsrCols, what, msg);
                ok = false;
                break 'rows;
            }
        }
    }
    ok
}

/// AG004: audit a matrix that claims symmetry. Call only on
/// well-formed square matrices ([`lint_csr`] gates it).
pub fn lint_symmetric(csr: &Csr, what: &str, diags: &mut Diagnostics) {
    if csr.n_rows != csr.n_cols {
        diags.emit(
            LintCode::AsymmetricMatrix,
            what,
            format!("claims symmetry but is {}x{}", csr.n_rows, csr.n_cols),
        );
        return;
    }
    if !csr.is_symmetric(1e-6) {
        diags.emit(LintCode::AsymmetricMatrix, what, "matrix is not symmetric (tol 1e-6)");
    }
}

/// Full decomposition audit: perm is a permutation (AG006), intra and
/// inter are well-formed symmetric n×n matrices (AG001–AG004), and the
/// block-diagonal split is honest — every intra entry on its diagonal
/// block, every inter entry off it (AG005).
pub fn lint_decomposition(d: &Decomposition, diags: &mut Diagnostics) {
    let n = d.graph.n;
    if d.perm.len() != n {
        diags.emit(
            LintCode::BadPermutation,
            "perm",
            format!("perm has {} entries for {} vertices", d.perm.len(), n),
        );
    } else {
        let mut seen = vec![false; n];
        for &p in &d.perm {
            if p as usize >= n || seen[p as usize] {
                diags.emit(
                    LintCode::BadPermutation,
                    "perm",
                    format!("vertex {p} out of range or repeated"),
                );
                break;
            }
            seen[p as usize] = true;
        }
    }
    let community = d.community.max(1);
    for (part, csr, want_intra) in [("intra", &d.intra, true), ("inter", &d.inter, false)] {
        if !lint_csr(csr, part, diags) {
            continue;
        }
        if csr.n_rows != n || csr.n_cols != n {
            diags.emit(
                LintCode::BlockDiagonal,
                part,
                format!("{}x{} matrix for an n = {n} decomposition", csr.n_rows, csr.n_cols),
            );
            continue;
        }
        lint_symmetric(csr, part, diags);
        'rows: for r in 0..csr.n_rows {
            let (cols, _) = csr.row(r);
            for &c in cols {
                let on_block = r / community == c as usize / community;
                if on_block != want_intra {
                    diags.emit(
                        LintCode::BlockDiagonal,
                        part,
                        format!(
                            "entry ({r}, {c}) is {} its diagonal block (community = {community})",
                            if on_block { "on" } else { "off" }
                        ),
                    );
                    break 'rows;
                }
            }
        }
    }
}

/// Analyzer entry point: always-on self-audit over a freshly built
/// planted-mixed decomposition (~1k vertices — milliseconds). No
/// artifacts are needed, so a bare checkout still audits the whole
/// reorder → normalize → split pipeline.
pub fn run(_ctx: &CheckContext, diags: &mut Diagnostics) {
    let Some(spec) = datasets::find("planted-mixed") else {
        diags.emit(LintCode::AuditSkipped, "self-audit", "planted-mixed spec missing");
        return;
    };
    let scale = (1024.0 / spec.vertices as f64).min(1.0);
    let data = spec.build_scaled(scale, 0);
    let d = Decomposition::build(
        &data.graph,
        Reorder::Metis,
        Propagation::GcnNormalized,
        datasets::COMMUNITY,
        0,
    );
    lint_decomposition(&d, diags);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::Severity;

    fn diags() -> Diagnostics {
        Diagnostics::new("graph")
    }

    fn codes(d: &Diagnostics) -> Vec<&'static str> {
        d.as_slice().iter().map(|x| x.code.code()).collect()
    }

    fn well_formed() -> Csr {
        Csr::from_triplets(4, 4, vec![(0, 1, 1.0), (1, 0, 1.0), (2, 3, 0.5), (3, 2, 0.5)])
    }

    #[test]
    fn clean_csr_passes() {
        let mut d = diags();
        assert!(lint_csr(&well_formed(), "m", &mut d));
        lint_symmetric(&well_formed(), "m", &mut d);
        assert!(d.as_slice().is_empty(), "{:?}", d.as_slice());
    }

    #[test]
    fn truncated_row_ptr_is_ag001() {
        let mut m = well_formed();
        m.row_ptr.pop();
        let mut d = diags();
        assert!(!lint_csr(&m, "m", &mut d));
        assert_eq!(codes(&d), vec!["AG001"]);
    }

    #[test]
    fn unsorted_cols_are_ag002() {
        let m = Csr {
            n_rows: 2,
            n_cols: 4,
            row_ptr: vec![0, 2, 2],
            col_idx: vec![3, 1],
            vals: vec![1.0, 1.0],
        };
        let mut d = diags();
        assert!(!lint_csr(&m, "m", &mut d));
        assert_eq!(codes(&d), vec!["AG002"]);
    }

    #[test]
    fn nan_value_is_ag003() {
        let mut m = well_formed();
        m.vals[1] = f32::NAN;
        let mut d = diags();
        assert!(!lint_csr(&m, "m", &mut d));
        assert!(codes(&d).contains(&"AG003"));
    }

    #[test]
    fn asymmetry_is_ag004() {
        let m = Csr::from_triplets(2, 2, vec![(0, 1, 1.0)]);
        let mut d = diags();
        assert!(lint_csr(&m, "m", &mut d));
        lint_symmetric(&m, "m", &mut d);
        assert_eq!(codes(&d), vec!["AG004"]);
    }

    #[test]
    fn self_audit_is_clean() {
        let ctx = CheckContext {
            artifacts: std::env::temp_dir(),
            plans: false,
            traces: vec![],
            deltas: vec![],
            bench_dir: None,
            baseline: None,
        };
        let mut d = diags();
        run(&ctx, &mut d);
        assert_eq!(
            d.as_slice().iter().filter(|x| x.severity == Severity::Error).count(),
            0,
            "{:?}",
            d.as_slice()
        );
    }

    #[test]
    fn off_block_intra_entry_is_ag005() {
        let spec = datasets::find("planted-mixed").unwrap();
        let data = spec.build_scaled(256.0 / spec.vertices as f64, 0);
        let mut dec = Decomposition::build(
            &data.graph,
            Reorder::Metis,
            Propagation::GcnNormalized,
            datasets::COMMUNITY,
            0,
        );
        // Swap the parts: every "intra" entry is now off-diagonal.
        std::mem::swap(&mut dec.intra, &mut dec.inter);
        let mut d = diags();
        lint_decomposition(&dec, &mut d);
        assert!(codes(&d).contains(&"AG005"), "{:?}", d.as_slice());
    }
}
