//! Diagnostics engine for `adaptgear check` (DESIGN.md Sec. 13).
//!
//! Every finding is a [`Diagnostic`] carrying a stable [`LintCode`]
//! (`AG001`, `AG024`, ...), a severity, the analyzer that emitted it, a
//! location string (file path, plan fingerprint, delta version, ...),
//! and a human message. Codes are append-only: a code never changes
//! meaning and is never reused, so scripts grepping `CHECK_report.json`
//! stay valid across releases.
//!
//! The same machinery backs the debug-build writer assertions
//! ([`debug_self_check`]): an artifact writer runs its own analyzer on
//! the document it is about to persist, so writers and checkers cannot
//! drift apart silently.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::json::Json;

/// Report schema version for `CHECK_report.json`.
pub const REPORT_SCHEMA_VERSION: u64 = 1;

/// How bad a finding is. `Error` fails the check (non-zero exit);
/// `Warn` is advisory unless promoted by `--deny warn`; `Info` records
/// skipped audits so "clean" is distinguishable from "not looked at".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The stable lint-code table. Blocks of ten-ish per analyzer leave
/// room to grow without renumbering: AG00x graph, AG02x plan, AG03x
/// stream, AG04x obs, AG06x bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LintCode {
    /// AG000 — an audit was skipped (missing input, unresolvable
    /// context); Info so reports show coverage, not just findings.
    AuditSkipped,
    /// AG001 — CSR row_ptr is malformed (wrong length, non-monotone,
    /// first != 0, last != nnz, vals/col_idx length mismatch).
    CsrIndptr,
    /// AG002 — CSR column indices out of range, unsorted, or duplicated
    /// within a row.
    CsrCols,
    /// AG003 — non-finite (NaN/Inf) numeric value in a persisted
    /// artifact or matrix.
    NonFinite,
    /// AG004 — a matrix that claims symmetry is asymmetric.
    AsymmetricMatrix,
    /// AG005 — block-diagonal violation: intra entry off its diagonal
    /// block, or inter entry on one.
    BlockDiagonal,
    /// AG006 — decomposition perm is not a permutation of 0..n.
    BadPermutation,
    /// AG020 — plan file unreadable or unparseable as a v3 GearPlan.
    PlanUnreadable,
    /// AG021 — plan filename fingerprint disagrees with the embedded
    /// fingerprint.
    PlanFilenameMismatch,
    /// AG022 — plan structural invariant violated (threshold range,
    /// class ordering/duplication, dense class not on dense_block).
    PlanStructure,
    /// AG024 — recomputed v3 fingerprint disagrees with the stored one.
    PlanFingerprintMismatch,
    /// AG025 — `GearAssignment::covers()` fails against the re-derived
    /// decomposition.
    PlanCoverage,
    /// AG026 — assignment inadmissible under the bucket edge cap at the
    /// recorded threshold.
    PlanEdgeCap,
    /// AG027 — chosen kernel is not the argmin of the persisted
    /// candidate costs.
    PlanNotArgmin,
    /// AG028 — recorded per-class time drifts from the recomputed
    /// `class_kernel_cost` beyond tolerance (cost-model drift).
    PlanCostDrift,
    /// AG029 — sweep provenance inconsistent with the assignment
    /// (threshold mismatch, unknown candidate outcome).
    PlanProvenance,
    /// AG030 — delta log versions are not 1-based contiguous.
    DeltaVersionGap,
    /// AG031 — delta log entry malformed (unknown op, missing field).
    DeltaMalformed,
    /// AG032 — static replay of the delta log fails to apply.
    DeltaReplayFailure,
    /// AG033 — replayed overlay state is asymmetric (edge pairing was
    /// lost somewhere between writer and log).
    DeltaAsymmetry,
    /// AG034 — overlay stages more rows than the ops address (no-op
    /// deletes or reweights staged copies).
    DeltaOverStaging,
    /// AG035 — plan `feat_density` missing on a versioned (v4+) plan
    /// file or outside [0, 1]. A density-blind entry in a v4 document
    /// cannot be priced or re-keyed correctly.
    PlanFeatDensity,
    /// AG036 — the plan's assumed feature density drifts from the
    /// density measured on the re-derived features beyond tolerance
    /// (the plan was priced for a sparsity the workload does not have).
    PlanFeatDensityDrift,
    /// AG040 — trace unparseable or B/E pairing violated.
    TraceMalformed,
    /// AG041 — per-thread trace timestamps are non-monotone.
    TraceNonMonotonic,
    /// AG042 — counter name does not match `subsystem.noun.verb`.
    CounterNaming,
    /// AG060 — bench report fails schema validation.
    BenchSchema,
    /// AG061 — metric names / units / direction tags unstable vs the
    /// baseline dir.
    BenchBaselineDrift,
    /// AG062 — quick-profile flag disagrees with the baseline report.
    BenchQuickMismatch,
}

impl LintCode {
    /// The stable wire code. Never renumber, never reuse.
    pub fn code(&self) -> &'static str {
        match self {
            LintCode::AuditSkipped => "AG000",
            LintCode::CsrIndptr => "AG001",
            LintCode::CsrCols => "AG002",
            LintCode::NonFinite => "AG003",
            LintCode::AsymmetricMatrix => "AG004",
            LintCode::BlockDiagonal => "AG005",
            LintCode::BadPermutation => "AG006",
            LintCode::PlanUnreadable => "AG020",
            LintCode::PlanFilenameMismatch => "AG021",
            LintCode::PlanStructure => "AG022",
            LintCode::PlanFingerprintMismatch => "AG024",
            LintCode::PlanCoverage => "AG025",
            LintCode::PlanEdgeCap => "AG026",
            LintCode::PlanNotArgmin => "AG027",
            LintCode::PlanCostDrift => "AG028",
            LintCode::PlanProvenance => "AG029",
            LintCode::DeltaVersionGap => "AG030",
            LintCode::DeltaMalformed => "AG031",
            LintCode::DeltaReplayFailure => "AG032",
            LintCode::DeltaAsymmetry => "AG033",
            LintCode::DeltaOverStaging => "AG034",
            LintCode::PlanFeatDensity => "AG035",
            LintCode::PlanFeatDensityDrift => "AG036",
            LintCode::TraceMalformed => "AG040",
            LintCode::TraceNonMonotonic => "AG041",
            LintCode::CounterNaming => "AG042",
            LintCode::BenchSchema => "AG060",
            LintCode::BenchBaselineDrift => "AG061",
            LintCode::BenchQuickMismatch => "AG062",
        }
    }

    /// Default severity; [`Diagnostics::emit_with`] can override per
    /// finding (e.g. AG027 degrades to Warn for wall-clock plans whose
    /// recorded costs are measurements, not the analytic model).
    pub fn severity(&self) -> Severity {
        match self {
            LintCode::AuditSkipped => Severity::Info,
            LintCode::PlanCostDrift
            | LintCode::PlanFeatDensityDrift
            | LintCode::CounterNaming
            | LintCode::BenchBaselineDrift
            | LintCode::BenchQuickMismatch => Severity::Warn,
            _ => Severity::Error,
        }
    }

    /// One-line title for the rendered table and the docs.
    pub fn title(&self) -> &'static str {
        match self {
            LintCode::AuditSkipped => "audit skipped",
            LintCode::CsrIndptr => "malformed CSR row_ptr",
            LintCode::CsrCols => "CSR cols out of range, unsorted, or duplicated",
            LintCode::NonFinite => "non-finite value in artifact",
            LintCode::AsymmetricMatrix => "claimed-symmetric matrix is asymmetric",
            LintCode::BlockDiagonal => "block-diagonal split violated",
            LintCode::BadPermutation => "perm is not a permutation",
            LintCode::PlanUnreadable => "plan unreadable or unparseable",
            LintCode::PlanFilenameMismatch => "plan filename/fingerprint mismatch",
            LintCode::PlanStructure => "plan structural invariant violated",
            LintCode::PlanFingerprintMismatch => "fingerprint does not recompute",
            LintCode::PlanCoverage => "assignment does not cover decomposition",
            LintCode::PlanEdgeCap => "assignment exceeds bucket edge cap",
            LintCode::PlanNotArgmin => "chosen kernel is not the candidate-cost argmin",
            LintCode::PlanCostDrift => "recorded class time drifts from cost model",
            LintCode::PlanProvenance => "sweep provenance inconsistent",
            LintCode::DeltaVersionGap => "delta versions not contiguous",
            LintCode::DeltaMalformed => "malformed delta entry",
            LintCode::DeltaReplayFailure => "delta replay failed",
            LintCode::DeltaAsymmetry => "replayed overlay is asymmetric",
            LintCode::DeltaOverStaging => "overlay staged more rows than ops address",
            LintCode::PlanFeatDensity => "plan feat_density missing or out of [0,1]",
            LintCode::PlanFeatDensityDrift => "assumed feature density drifts from measured",
            LintCode::TraceMalformed => "trace unparseable or B/E pairing violated",
            LintCode::TraceNonMonotonic => "trace timestamps non-monotone per thread",
            LintCode::CounterNaming => "counter name not subsystem.noun.verb",
            LintCode::BenchSchema => "bench report fails schema validation",
            LintCode::BenchBaselineDrift => "bench metric set unstable vs baseline",
            LintCode::BenchQuickMismatch => "bench quick profile differs from baseline",
        }
    }
}

impl fmt::Display for LintCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.code())
    }
}

/// One finding: what, how bad, who found it, where, and why.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: LintCode,
    pub severity: Severity,
    pub analyzer: &'static str,
    /// Where the finding anchors: a file path, `plan <fp>`, a delta
    /// version, a counter name, ...
    pub location: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} [{}] {}: {}",
            self.severity,
            self.code.code(),
            self.analyzer,
            self.location,
            self.message
        )
    }
}

/// Collector handed to analyzers. Scoped to one analyzer name so
/// findings attribute themselves; [`Diagnostics::emit`] uses the
/// code's default severity, [`Diagnostics::emit_with`] overrides it.
#[derive(Debug)]
pub struct Diagnostics {
    analyzer: &'static str,
    diags: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new(analyzer: &'static str) -> Self {
        Diagnostics { analyzer, diags: Vec::new() }
    }

    pub fn emit(
        &mut self,
        code: LintCode,
        location: impl Into<String>,
        message: impl Into<String>,
    ) {
        self.emit_with(code, code.severity(), location, message);
    }

    pub fn emit_with(
        &mut self,
        code: LintCode,
        severity: Severity,
        location: impl Into<String>,
        message: impl Into<String>,
    ) {
        self.diags.push(Diagnostic {
            code,
            severity,
            analyzer: self.analyzer,
            location: location.into(),
            message: message.into(),
        });
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn as_slice(&self) -> &[Diagnostic] {
        &self.diags
    }

    pub fn into_vec(self) -> Vec<Diagnostic> {
        self.diags
    }
}

/// The assembled result of a `check` run: every diagnostic from every
/// analyzer, with `--deny warn` promotion already applied.
#[derive(Debug)]
pub struct CheckReport {
    pub diagnostics: Vec<Diagnostic>,
    pub deny_warn: bool,
}

impl CheckReport {
    /// Promotes Warn to Error in place when `deny_warn` — the report
    /// that is written is the report that decided the exit code.
    pub fn new(mut diagnostics: Vec<Diagnostic>, deny_warn: bool) -> Self {
        if deny_warn {
            for d in &mut diagnostics {
                if d.severity == Severity::Warn {
                    d.severity = Severity::Error;
                }
            }
        }
        CheckReport { diagnostics, deny_warn }
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn infos(&self) -> usize {
        self.count(Severity::Info)
    }

    fn count(&self, s: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == s).count()
    }

    /// Machine-readable `CHECK_report.json` document.
    pub fn to_json(&self) -> Json {
        let mut per_analyzer: BTreeMap<String, u64> = BTreeMap::new();
        for d in &self.diagnostics {
            *per_analyzer.entry(d.analyzer.to_string()).or_insert(0) += 1;
        }
        Json::obj(vec![
            ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
            ("deny_warn", Json::Bool(self.deny_warn)),
            (
                "totals",
                Json::obj(vec![
                    ("errors", Json::num(self.errors() as f64)),
                    ("warnings", Json::num(self.warnings() as f64)),
                    ("infos", Json::num(self.infos() as f64)),
                ]),
            ),
            (
                "per_analyzer",
                Json::Obj(
                    per_analyzer
                        .into_iter()
                        .map(|(k, v)| (k, Json::num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "diagnostics",
                Json::Arr(
                    self.diagnostics
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("code", Json::str(d.code.code())),
                                ("severity", Json::str(d.severity.as_str())),
                                ("analyzer", Json::str(d.analyzer)),
                                ("location", Json::str(&d.location)),
                                ("message", Json::str(&d.message)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rendered table: errors first, then warns, then infos; stable
    /// within a severity by emission order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for want in [Severity::Error, Severity::Warn, Severity::Info] {
            for d in self.diagnostics.iter().filter(|d| d.severity == want) {
                out.push_str(&format!("{d}\n"));
            }
        }
        out.push_str(&format!(
            "check: {} errors, {} warnings, {} infos\n",
            self.errors(),
            self.warnings(),
            self.infos()
        ));
        out
    }
}

/// Debug-build writer assertion: run analyzer body `f` over the
/// document a writer is about to persist and panic if it produced any
/// Error diagnostic. Release builds skip it entirely. This is the
/// anti-drift rule from DESIGN.md Sec. 13 — an artifact writer cannot
/// emit something its own analyzer rejects.
pub fn debug_self_check(what: &str, f: impl FnOnce(&mut Diagnostics)) {
    if !cfg!(debug_assertions) {
        return;
    }
    let mut diags = Diagnostics::new("self-check");
    f(&mut diags);
    if diags.error_count() > 0 {
        let findings: Vec<String> = diags
            .as_slice()
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.to_string())
            .collect();
        panic!("{what} wrote an artifact that fails its own analyzer:\n{}", findings.join("\n"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_stable() {
        let all = [
            LintCode::AuditSkipped,
            LintCode::CsrIndptr,
            LintCode::CsrCols,
            LintCode::NonFinite,
            LintCode::AsymmetricMatrix,
            LintCode::BlockDiagonal,
            LintCode::BadPermutation,
            LintCode::PlanUnreadable,
            LintCode::PlanFilenameMismatch,
            LintCode::PlanStructure,
            LintCode::PlanFingerprintMismatch,
            LintCode::PlanCoverage,
            LintCode::PlanEdgeCap,
            LintCode::PlanNotArgmin,
            LintCode::PlanCostDrift,
            LintCode::PlanProvenance,
            LintCode::DeltaVersionGap,
            LintCode::DeltaMalformed,
            LintCode::DeltaReplayFailure,
            LintCode::DeltaAsymmetry,
            LintCode::DeltaOverStaging,
            LintCode::PlanFeatDensity,
            LintCode::PlanFeatDensityDrift,
            LintCode::TraceMalformed,
            LintCode::TraceNonMonotonic,
            LintCode::CounterNaming,
            LintCode::BenchSchema,
            LintCode::BenchBaselineDrift,
            LintCode::BenchQuickMismatch,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for c in all {
            assert!(c.code().starts_with("AG"), "{c:?}");
            assert!(seen.insert(c.code()), "duplicate code {}", c.code());
            assert!(!c.title().is_empty());
        }
    }

    #[test]
    fn deny_warn_promotes() {
        let mut d = Diagnostics::new("t");
        d.emit(LintCode::CounterNaming, "x", "bad name");
        d.emit(LintCode::AuditSkipped, "y", "skipped");
        let plain = CheckReport::new(d.as_slice().to_vec(), false);
        assert_eq!((plain.errors(), plain.warnings(), plain.infos()), (0, 1, 1));
        let denied = CheckReport::new(plain.diagnostics, true);
        assert_eq!((denied.errors(), denied.warnings(), denied.infos()), (1, 0, 1));
    }

    #[test]
    fn report_json_shape() {
        let mut d = Diagnostics::new("graph");
        d.emit(LintCode::CsrIndptr, "intra", "row_ptr truncated");
        let rep = CheckReport::new(d.into_vec(), false);
        let doc = rep.to_json();
        assert_eq!(doc.get("schema_version").as_usize(), Some(REPORT_SCHEMA_VERSION as usize));
        assert_eq!(doc.get("totals").get("errors").as_usize(), Some(1));
        assert_eq!(doc.get("diagnostics").idx(0).get("code").as_str(), Some("AG001"));
        assert!(rep.render().contains("AG001"));
        assert!(rep.render().contains("1 errors"));
    }

    #[test]
    #[should_panic(expected = "fails its own analyzer")]
    fn self_check_panics_on_error() {
        debug_self_check("test writer", |d| {
            d.emit(LintCode::NonFinite, "field", "NaN");
        });
    }
}
