//! Stream analyzer: serialized delta logs and their static replay
//! (AG030–AG034, AG003).
//!
//! The audit is two-phase. A raw scan over the JSON distinguishes the
//! failure modes `DeltaLog::from_json` folds into one error — version
//! gaps (AG030) vs malformed entries (AG031) vs non-finite weights
//! (AG003) — and keeps scanning past the first finding. When the scan
//! is clean, the log is replayed through a fresh [`CsrOverlay`] over an
//! empty base: the ops must apply (AG032), the merged result must be
//! symmetric — the overlay mirrors every edge op, so an asymmetric
//! replay means the pairing contract broke (AG033) — and the overlay
//! must not stage more rows than the edge ops addressed, i.e. no-op
//! deletes and reweights never cost a copy-on-write (AG034).

use std::collections::BTreeSet;
use std::path::Path;

use crate::check::{CheckContext, Diagnostics, LintCode};
use crate::graph::Csr;
use crate::stream::{CsrOverlay, DeltaLog, DeltaOp};
use crate::util::json::{self, Json};

pub const CODES: &[LintCode] = &[
    LintCode::AuditSkipped,
    LintCode::NonFinite,
    LintCode::DeltaVersionGap,
    LintCode::DeltaMalformed,
    LintCode::DeltaReplayFailure,
    LintCode::DeltaAsymmetry,
    LintCode::DeltaOverStaging,
];

const OPS: [&str; 4] = ["insert_edge", "delete_edge", "reweight", "add_vertices"];

/// Audit one serialized delta-log document. `DeltaLog::to_json` runs
/// this as its debug-build self-check.
pub fn lint_delta_log_json(doc: &Json, loc: &str, diags: &mut Diagnostics) {
    let Some(raw) = doc.get("deltas").as_arr() else {
        diags.emit(LintCode::DeltaMalformed, loc, "missing 'deltas' array");
        return;
    };
    let mut clean = true;
    for (i, e) in raw.iter().enumerate() {
        let at = format!("{loc} delta {i}");
        match e.get("version").as_str().and_then(|s| s.parse::<u64>().ok()) {
            Some(v) if v == i as u64 + 1 => {}
            Some(v) => {
                diags.emit(
                    LintCode::DeltaVersionGap,
                    &at,
                    format!("version {v}, expected {} (1-based, contiguous)", i + 1),
                );
                clean = false;
            }
            None => {
                diags.emit(LintCode::DeltaMalformed, &at, "missing or non-numeric version string");
                clean = false;
            }
        }
        let Some(kind) = e.get("op").as_str() else {
            diags.emit(LintCode::DeltaMalformed, &at, "missing op");
            clean = false;
            continue;
        };
        if !OPS.contains(&kind) {
            diags.emit(LintCode::DeltaMalformed, &at, format!("unknown op {kind:?}"));
            clean = false;
            continue;
        }
        let need: &[&str] = match kind {
            "insert_edge" | "reweight" => &["u", "v", "w"],
            "delete_edge" => &["u", "v"],
            _ => &["count"],
        };
        for field in need {
            if e.get(field).as_f64().is_none() {
                diags.emit(LintCode::DeltaMalformed, &at, format!("missing field {field:?}"));
                clean = false;
            } else if *field == "w" && !e.get(field).as_f64().unwrap().is_finite() {
                // The writer refuses non-finite floats, but `1e999`
                // parses as +inf, so a foreign log can still carry one.
                diags.emit(
                    LintCode::NonFinite,
                    &at,
                    format!("weight = {}", e.get(field).as_f64().unwrap()),
                );
                clean = false;
            }
        }
    }
    if !clean {
        return;
    }
    let log = match DeltaLog::from_json(doc) {
        Ok(l) => l,
        Err(e) => {
            diags.emit(LintCode::DeltaMalformed, loc, format!("{e:#}"));
            return;
        }
    };
    replay(&log, loc, diags);
}

/// Static replay over an empty base sized to cover every addressed
/// vertex: the log must apply cleanly and land in a symmetric,
/// minimally-staged overlay.
fn replay(log: &DeltaLog, loc: &str, diags: &mut Diagnostics) {
    let mut n = 1usize;
    let mut touched: BTreeSet<u32> = BTreeSet::new();
    for d in log.entries() {
        match d.op {
            DeltaOp::InsertEdge { u, v, .. } | DeltaOp::DeleteEdge { u, v } => {
                n = n.max(u as usize + 1).max(v as usize + 1);
                touched.insert(u);
                touched.insert(v);
            }
            DeltaOp::Reweight { u, v, .. } => {
                n = n.max(u as usize + 1).max(v as usize + 1);
            }
            DeltaOp::AddVertices { .. } => {}
        }
    }
    let mut overlay = CsrOverlay::new(Csr::from_triplets(n, n, vec![]));
    for d in log.entries() {
        if let Err(e) = overlay.apply(d) {
            diags.emit(
                LintCode::DeltaReplayFailure,
                loc,
                format!("version {} ({}): {e:#}", d.version, d.op.kind()),
            );
            return;
        }
    }
    if !overlay.to_csr().is_symmetric(1e-6) {
        diags.emit(
            LintCode::DeltaAsymmetry,
            loc,
            "replayed overlay is asymmetric: edge mirroring was lost",
        );
    }
    if overlay.staged_rows() > touched.len() {
        diags.emit(
            LintCode::DeltaOverStaging,
            loc,
            format!(
                "{} rows staged but only {} rows addressed by edge ops",
                overlay.staged_rows(),
                touched.len()
            ),
        );
    }
}

/// Audit one delta-log file on disk.
pub fn lint_delta_file(path: &Path, diags: &mut Diagnostics) {
    let loc = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            diags.emit(LintCode::DeltaMalformed, &loc, format!("read failed: {e}"));
            return;
        }
    };
    match json::parse(&text) {
        Ok(doc) => lint_delta_log_json(&doc, &loc, diags),
        Err(e) => diags.emit(LintCode::DeltaMalformed, &loc, format!("parse failed: {e}")),
    }
}

/// Analyzer entry point: audit every delta-log file handed to the run.
pub fn run(ctx: &CheckContext, diags: &mut Diagnostics) {
    if ctx.deltas.is_empty() {
        diags.emit(LintCode::AuditSkipped, "stream", "no delta logs to audit");
        return;
    }
    for p in &ctx.deltas {
        lint_delta_file(p, diags);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(doc: &Json) -> Vec<&'static str> {
        let mut d = Diagnostics::new("stream");
        lint_delta_log_json(doc, "log", &mut d);
        d.as_slice().iter().map(|x| x.code.code()).collect()
    }

    fn sample_log() -> DeltaLog {
        let mut log = DeltaLog::new();
        log.append(DeltaOp::InsertEdge { u: 0, v: 5, w: 1.0 });
        log.append(DeltaOp::Reweight { u: 0, v: 5, w: 0.5 });
        log.append(DeltaOp::DeleteEdge { u: 2, v: 3 }); // no-op delete
        log.append(DeltaOp::AddVertices { count: 2 });
        log
    }

    #[test]
    fn serialized_log_is_clean() {
        assert!(lint(&sample_log().to_json()).is_empty());
    }

    #[test]
    fn version_gap_is_ag030() {
        let doc = json::parse(
            r#"{"version":1,"deltas":[
                {"version":"1","op":"insert_edge","u":0,"v":1,"w":1},
                {"version":"3","op":"delete_edge","u":0,"v":1}]}"#,
        )
        .unwrap();
        assert!(lint(&doc).contains(&"AG030"));
    }

    #[test]
    fn unknown_op_is_ag031() {
        let doc = json::parse(
            r#"{"version":1,"deltas":[{"version":"1","op":"merge_edge","u":0,"v":1}]}"#,
        )
        .unwrap();
        assert!(lint(&doc).contains(&"AG031"));
    }

    #[test]
    fn infinite_weight_is_ag003() {
        let doc = json::parse(
            r#"{"version":1,"deltas":[{"version":"1","op":"insert_edge","u":0,"v":1,"w":1e999}]}"#,
        )
        .unwrap();
        assert!(lint(&doc).contains(&"AG003"));
    }

    #[test]
    fn replay_stays_minimal() {
        // The no-op delete and the reweight must not stage extra rows;
        // symmetry must survive the round trip.
        let mut d = Diagnostics::new("stream");
        lint_delta_log_json(&sample_log().to_json(), "log", &mut d);
        assert!(d.as_slice().is_empty(), "{:?}", d.as_slice());
    }
}
