//! `adaptgear check` — static invariant auditing over everything the
//! system persists (DESIGN.md Sec. 13).
//!
//! The runtime already validates artifacts piecemeal at load time
//! (`GearPlan::validate`, `DeltaLog::from_json`, ...), but those checks
//! only fire on the artifacts a particular run happens to touch, and
//! they stop at the first failure. This subsystem is the opposite
//! shape: a registry of [`Analyzer`]s that each audit one artifact
//! family exhaustively — every plan in the store, every delta log,
//! every trace and bench report handed to it — and *keep going*,
//! accumulating [`Diagnostic`]s with stable lint codes instead of
//! bailing. Nothing here executes a training step or needs an engine;
//! `adaptgear check` runs to completion on a bare checkout.
//!
//! Analyzer ownership:
//!
//! | analyzer | artifact family | codes |
//! |---|---|---|
//! | `graph`  | CSR / [`Decomposition`] well-formedness | AG001–AG006 |
//! | `plan`   | plan store files, provenance, cost drift, feature density | AG020–AG029, AG035–AG036 |
//! | `stream` | delta logs + static replay | AG030–AG034 |
//! | `obs`    | Chrome traces + counter naming | AG040–AG042 |
//! | `bench`  | `BENCH_*.json` + baseline stability | AG060–AG062 |
//!
//! The writer/checker anti-drift rule: every artifact writer
//! (`PlanStore::save`, `DeltaLog::to_json`, `BenchReport::write_at`,
//! `obs::write_trace`) runs its own analyzer on the document it emits
//! under `debug_assertions` via [`diag::debug_self_check`]. A writer
//! change that the checker rejects fails every debug test run, not a
//! later audit.
//!
//! [`Decomposition`]: crate::partition::Decomposition

pub mod bench;
pub mod diag;
pub mod graph;
pub mod obs;
pub mod plan;
pub mod stream;

use std::path::PathBuf;

pub use diag::{debug_self_check, CheckReport, Diagnostic, Diagnostics, LintCode, Severity};

/// What a `check` run should look at. Built by the CLI from flags plus
/// filesystem discovery (plans dir, `TRACE_*.json`, `BENCH_*.json`);
/// analyzers treat missing inputs as AG000 skips, never errors.
#[derive(Debug, Clone)]
pub struct CheckContext {
    /// Artifacts dir holding `manifest.json` and `plans/`.
    pub artifacts: PathBuf,
    /// Audit every `plans/plan_*.json` under `artifacts`.
    pub plans: bool,
    /// Chrome trace files to audit.
    pub traces: Vec<PathBuf>,
    /// Serialized delta-log files to audit.
    pub deltas: Vec<PathBuf>,
    /// Directory holding `BENCH_<suite>.json` reports.
    pub bench_dir: Option<PathBuf>,
    /// Baseline dir to diff bench metric sets against.
    pub baseline: Option<PathBuf>,
}

/// One registered analyzer: a name, the codes it may emit (the
/// documented contract — tests assert emitted codes stay inside it),
/// and an infallible entry point. Analyzers report IO failures as
/// diagnostics; `run` never aborts the sweep.
pub struct Analyzer {
    pub name: &'static str,
    pub codes: &'static [LintCode],
    pub run: fn(&CheckContext, &mut Diagnostics),
}

/// The registry, in audit order. Order is presentation-only; analyzers
/// are independent.
pub const ANALYZERS: &[Analyzer] = &[
    Analyzer { name: "graph", codes: graph::CODES, run: graph::run },
    Analyzer { name: "plan", codes: plan::CODES, run: plan::run },
    Analyzer { name: "stream", codes: stream::CODES, run: stream::run },
    Analyzer { name: "obs", codes: obs::CODES, run: obs::run },
    Analyzer { name: "bench", codes: bench::CODES, run: bench::run },
];

/// Run every registered analyzer and assemble the report (with
/// `--deny warn` promotion applied).
pub fn run_all(ctx: &CheckContext, deny_warn: bool) -> CheckReport {
    let mut all = Vec::new();
    for a in ANALYZERS {
        let mut diags = Diagnostics::new(a.name);
        (a.run)(ctx, &mut diags);
        all.extend(diags.into_vec());
    }
    CheckReport::new(all, deny_warn)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_ctx() -> CheckContext {
        CheckContext {
            artifacts: std::env::temp_dir().join("adaptgear-check-noexist"),
            plans: false,
            traces: vec![],
            deltas: vec![],
            bench_dir: None,
            baseline: None,
        }
    }

    #[test]
    fn registry_names_unique_and_codes_disjoint() {
        let mut names = std::collections::BTreeSet::new();
        let mut codes = std::collections::BTreeSet::new();
        for a in ANALYZERS {
            assert!(names.insert(a.name), "duplicate analyzer {}", a.name);
            for c in a.codes {
                // AG000/AG003 are shared vocabulary; everything else is
                // owned by exactly one analyzer.
                if matches!(c, LintCode::AuditSkipped | LintCode::NonFinite) {
                    continue;
                }
                assert!(codes.insert(c.code()), "code {} claimed twice", c.code());
            }
        }
    }

    #[test]
    fn bare_run_has_zero_errors() {
        // A bare checkout with nothing to audit: the graph self-audit
        // runs, everything else skips with Info. Zero errors.
        let report = run_all(&empty_ctx(), false);
        assert_eq!(report.errors(), 0, "{}", report.render());
        assert!(report.infos() > 0, "skips should be recorded");
    }
}
