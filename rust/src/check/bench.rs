//! Bench analyzer: `BENCH_<suite>.json` reports and their stability
//! against a committed baseline dir (AG060–AG062).
//!
//! Schema validation reuses `BenchReport::from_json` — it is already
//! the strict gate (schema version, non-empty suite, finite values,
//! direction tags, duplicate names), so the analyzer cannot drift from
//! the loader (AG060). With `--baseline DIR`, the *metric set* is also
//! audited: a metric that existed in the baseline but vanished, or
//! changed unit or direction, silently breaks the perf-gate comparator,
//! so it warns here before the gate goes blind (AG061); a quick-profile
//! mismatch means the two reports are not comparable at all (AG062).

use std::path::Path;

use crate::bench::{BenchReport, SUITES};
use crate::check::{CheckContext, Diagnostics, LintCode};
use crate::util::json::{self, Json};

pub const CODES: &[LintCode] = &[
    LintCode::AuditSkipped,
    LintCode::BenchSchema,
    LintCode::BenchBaselineDrift,
    LintCode::BenchQuickMismatch,
];

/// Audit one bench-report document. `BenchReport::write_at` runs this
/// as its debug-build self-check.
pub fn lint_report_json(doc: &Json, loc: &str, diags: &mut Diagnostics) -> Option<BenchReport> {
    match BenchReport::from_json(doc) {
        Ok(r) => Some(r),
        Err(e) => {
            diags.emit(LintCode::BenchSchema, loc, format!("{e:#}"));
            None
        }
    }
}

fn load_report(path: &Path, diags: &mut Diagnostics) -> Option<BenchReport> {
    let loc = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            diags.emit(LintCode::BenchSchema, &loc, format!("read failed: {e}"));
            return None;
        }
    };
    match json::parse(&text) {
        Ok(doc) => lint_report_json(&doc, &loc, diags),
        Err(e) => {
            diags.emit(LintCode::BenchSchema, &loc, format!("parse failed: {e}"));
            None
        }
    }
}

/// AG061/AG062: the current report must remain comparable to the
/// baseline the perf gates diff against.
pub fn lint_against_baseline(
    current: &BenchReport,
    baseline: &BenchReport,
    loc: &str,
    diags: &mut Diagnostics,
) {
    if current.quick != baseline.quick {
        diags.emit(
            LintCode::BenchQuickMismatch,
            loc,
            format!("quick = {} here but {} in the baseline", current.quick, baseline.quick),
        );
    }
    for base in &baseline.metrics {
        match current.get(&base.name) {
            None => diags.emit(
                LintCode::BenchBaselineDrift,
                loc,
                format!("baseline metric {:?} is gone", base.name),
            ),
            Some(now) => {
                if now.unit != base.unit || now.better != base.better {
                    diags.emit(
                        LintCode::BenchBaselineDrift,
                        loc,
                        format!(
                            "metric {:?} changed shape: {} ({}) -> {} ({})",
                            base.name,
                            base.unit,
                            base.better.as_str(),
                            now.unit,
                            now.better.as_str()
                        ),
                    );
                }
            }
        }
    }
}

/// Analyzer entry point: audit every suite report present in the bench
/// dir, and diff each against the baseline dir when one is given.
pub fn run(ctx: &CheckContext, diags: &mut Diagnostics) {
    let Some(dir) = &ctx.bench_dir else {
        diags.emit(LintCode::AuditSkipped, "bench", "no bench reports to audit");
        return;
    };
    let mut found = 0usize;
    for suite in SUITES {
        let path = BenchReport::path_in(dir, suite);
        if !path.exists() {
            continue;
        }
        found += 1;
        let Some(report) = load_report(&path, diags) else { continue };
        if let Some(base_dir) = &ctx.baseline {
            let base_path = BenchReport::path_in(base_dir, suite);
            if base_path.exists() {
                if let Some(base) = load_report(&base_path, diags) {
                    lint_against_baseline(&report, &base, &path.display().to_string(), diags);
                }
            }
        }
    }
    if found == 0 {
        diags.emit(
            LintCode::AuditSkipped,
            dir.display().to_string(),
            "no BENCH_*.json present",
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::Direction;

    fn codes(d: &Diagnostics) -> Vec<&'static str> {
        d.as_slice().iter().map(|x| x.code.code()).collect()
    }

    fn report() -> BenchReport {
        let mut r = BenchReport::new("kernels", true);
        r.push("spmm_us", 12.5, "us", Direction::Lower);
        r
    }

    #[test]
    fn fresh_report_is_clean() {
        let mut d = Diagnostics::new("bench");
        assert!(lint_report_json(&report().to_json(), "r", &mut d).is_some());
        assert!(d.as_slice().is_empty(), "{:?}", d.as_slice());
    }

    #[test]
    fn wrong_schema_version_is_ag060() {
        let mut doc = report().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::num(99.0));
        }
        let mut d = Diagnostics::new("bench");
        assert!(lint_report_json(&doc, "r", &mut d).is_none());
        assert_eq!(codes(&d), vec!["AG060"]);
    }

    #[test]
    fn vanished_metric_is_ag061() {
        let mut base = report();
        base.push("launches", 3.0, "count", Direction::Lower);
        let mut d = Diagnostics::new("bench");
        lint_against_baseline(&report(), &base, "r", &mut d);
        assert_eq!(codes(&d), vec!["AG061"]);
    }

    #[test]
    fn quick_flip_is_ag062() {
        let full = BenchReport::new("kernels", false);
        let mut d = Diagnostics::new("bench");
        lint_against_baseline(&full, &report(), "r", &mut d);
        assert_eq!(codes(&d), vec!["AG062"]);
    }
}
