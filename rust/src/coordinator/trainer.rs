//! The PJRT training loop: execute a [`GearPlan`]'s kernel decision as
//! locked steady-state training, entirely in Rust over AOT artifacts.
//!
//! Kernel *selection* no longer happens here — it is the planner's job
//! (`crate::plan`): `train` takes a computed [`GearPlan`], validates it
//! against the decomposition, and runs the winning train-step artifact.
//!
//! Hot-loop discipline: graph operands and feature/label literals are
//! packed once (and only for the plan's chosen kernels); each step feeds
//! the previous step's decomposed output literals straight back as
//! parameters, so steady state performs no host-side tensor packing at
//! all.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::kernels::pack::{
    pack_assignment, pack_features, pack_kernel_operands, pack_labels_mask,
};
use crate::kernels::KernelPair;
use crate::obs;
use crate::partition::Decomposition;
use crate::plan::GearPlan;
use crate::runtime::{literal_scalar_f32, BucketInfo, Engine, Manifest, Tensor};
use crate::util::rng::Rng;

use super::modeldims::ModelKind;

/// Training configuration — the training *budget*. Kernel-selection knobs
/// (clock, monitor repeats, GPU model) live with the planner instead.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { model: ModelKind::Gcn, steps: 100, lr: 0.05, seed: 0 }
    }
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub bucket: String,
    /// The plan this run executed (decision + provenance + monitor cost).
    pub plan: GearPlan,
    pub losses: Vec<f32>,
    pub step_secs: Vec<f64>,
    pub compile_secs: f64,
    pub pack_secs: f64,
    /// Trained parameters (host copies) for reuse with [`forward`].
    pub params: Vec<Tensor>,
}

impl TrainReport {
    /// The kernel pair the run executed.
    pub fn chosen(&self) -> KernelPair {
        self.plan.chosen
    }

    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn mean_step_secs(&self) -> f64 {
        crate::util::stats::mean(&self.step_secs)
    }
}

/// Train a decomposed graph end to end under `plan`'s kernel decision.
/// `x` is `[n, f_data]` row-major in the decomposition's vertex order.
pub fn train(
    engine: &Engine,
    d: &Decomposition,
    x: &[f32],
    f_data: usize,
    labels: &[i32],
    cfg: &TrainConfig,
    plan: &GearPlan,
) -> Result<TrainReport> {
    let n = d.graph.n;
    let needed_edges = d.intra.nnz().max(d.inter.nnz());
    let bucket = engine
        .manifest
        .fit_bucket(n, needed_edges)
        .with_context(|| {
            format!("no AOT bucket fits n={n}, edges={needed_edges}; scale the dataset down")
        })?
        .clone();
    if engine.manifest.community != d.community {
        bail!(
            "decomposition community {} != AOT community {}",
            d.community,
            engine.manifest.community
        );
    }
    plan.validate(d, cfg.model)
        .context("train: the provided plan does not cover this graph")?;
    if plan.bucket != bucket.name {
        bail!(
            "plan targets bucket {} but the graph fits bucket {}; replan",
            plan.bucket,
            bucket.name
        );
    }
    let chosen = plan.chosen;

    // ---- pack static operands once — only the plan's classes. Hybrid
    // plans lower their N parts onto the two artifact slots: dense class
    // in the intra slot, sparse class merged into the inter operand.
    let t_pack = Instant::now();
    let mut static_ops: Vec<Tensor> = Vec::new();
    if chosen.intra.is_some() {
        let (intra_ops, inter_ops) = pack_assignment(d, &plan.assignment, &bucket)
            .context("packing the plan's class assignment")?;
        static_ops.extend(intra_ops);
        static_ops.extend(inter_ops);
    } else {
        // full-graph variant: the whole propagation matrix through inter
        static_ops.extend(pack_kernel_operands(chosen.inter, &d.whole(), d.community, &bucket)?);
    }
    let x_packed = pack_features(x, n, f_data, &bucket)?;
    let (labels_t, mask_t) = pack_labels_mask(labels, &bucket)?;
    let pack_secs = t_pack.elapsed().as_secs_f64();

    // ---- load the planned train-step artifact
    let name = Manifest::train_name(
        cfg.model.as_str(),
        chosen.intra_str(),
        &chosen.inter.to_string(),
        &bucket.name,
    );
    let meta = engine.manifest.get(&name)?.clone();
    let t_compile = Instant::now();
    let loaded = engine.load(&name)?;
    let compile_secs = t_compile.elapsed().as_secs_f64();

    // ---- initialize parameters from the manifest's operand specs
    let graph_arg_start = graph_arg_start(&meta);
    let mut rng = Rng::new(cfg.seed ^ 0x9a9a);
    let mut params: Vec<xla::Literal> = Vec::new();
    for spec in &meta.inputs[..graph_arg_start] {
        params.push(init_param(&spec.shape, &mut rng)?.to_literal()?);
    }
    let n_params = params.len();

    // ---- pack static (non-parameter) literals once
    let mut static_lits: Vec<xla::Literal> = Vec::new();
    for t in &static_ops {
        static_lits.push(t.to_literal()?);
    }
    static_lits.push(x_packed.to_literal()?);
    static_lits.push(labels_t.to_literal()?);
    static_lits.push(mask_t.to_literal()?);
    static_lits.push(Tensor::scalar_f32(cfg.lr).to_literal()?);
    if n_params + static_lits.len() != meta.inputs.len() {
        bail!(
            "operand mismatch for {name}: {} params + {} statics != {} inputs",
            n_params,
            static_lits.len(),
            meta.inputs.len()
        );
    }

    // ---- training hot loop: outputs feed back as parameters
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut step_secs = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let t0 = Instant::now();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(meta.inputs.len());
        args.extend(params.iter());
        args.extend(static_lits.iter());
        let mut outputs = engine.run_literals(&loaded, &args, meta.outputs.len())?;
        let loss = outputs.pop().expect("train_step returns params + loss");
        losses.push(literal_scalar_f32(&loss)?);
        params = outputs;
        step_secs.push(t0.elapsed().as_secs_f64());
    }

    let params = literals_to_tensors(&params, &meta.inputs[..n_params])?;
    Ok(TrainReport {
        bucket: bucket.name.clone(),
        plan: plan.clone(),
        losses,
        step_secs,
        compile_secs,
        pack_secs,
        params,
    })
}

/// Index of the first non-parameter operand in an artifact's input list
/// (graph operands, then features/labels/mask/lr); everything before it
/// is a trainable parameter.
pub(crate) fn graph_arg_start(meta: &crate::runtime::ArtifactMeta) -> usize {
    meta.inputs
        .iter()
        .position(|s| {
            s.name.starts_with("intra_") || s.name.starts_with("inter_") || s.name == "x"
        })
        .unwrap_or(meta.inputs.len())
}

/// Glorot-uniform for matrices, zeros for vectors/scalars — mirrors
/// `python/compile/model.py::init_params`.
pub(crate) fn init_param(shape: &[usize], rng: &mut Rng) -> Result<Tensor> {
    let count: usize = shape.iter().product();
    let data = if shape.len() == 2 {
        let scale = (6.0 / (shape[0] + shape[1]) as f64).sqrt() as f32;
        (0..count).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    } else {
        vec![0.0f32; count]
    };
    Ok(Tensor::f32(data, shape))
}

/// Resolve the forward artifact and pack a plan's STATIC graph operands
/// once: bucket fit + staleness guard, artifact name, and the class
/// assignment's operand tensors. The per-call remainder of a forward is
/// only feature packing + execution ([`forward_packed`]), so serving
/// deployments cache this result instead of re-splitting and re-packing
/// the topology on every micro-batch.
pub fn plan_forward_operands(
    manifest: &Manifest,
    d: &Decomposition,
    plan: &GearPlan,
    model: ModelKind,
) -> Result<(String, BucketInfo, Vec<Tensor>)> {
    let n = d.graph.n;
    let needed_edges = d.intra.nnz().max(d.inter.nnz());
    let bucket = manifest
        .fit_bucket(n, needed_edges)
        .context("no bucket fits")?
        .clone();
    // Same staleness guard as train(): the hybrid edge-cap admissibility
    // was checked against the plan's bucket, so a rebuilt manifest that
    // refits a different bucket must replan, not fail deep in packing.
    if plan.bucket != bucket.name {
        bail!(
            "plan targets bucket {} but the graph fits bucket {}; replan",
            plan.bucket,
            bucket.name
        );
    }
    let chosen = plan.chosen;
    let name = Manifest::fwd_name(
        model.as_str(),
        chosen.intra_str(),
        &chosen.inter.to_string(),
        &bucket.name,
    );
    let mut ops: Vec<Tensor> = Vec::new();
    if chosen.intra.is_some() {
        let (intra_ops, inter_ops) = pack_assignment(d, &plan.assignment, &bucket)?;
        ops.extend(intra_ops);
        ops.extend(inter_ops);
    } else {
        ops.extend(pack_kernel_operands(chosen.inter, &d.whole(), d.community, &bucket)?);
    }
    Ok((name, bucket, ops))
}

/// Wall-time split of one packed forward call: feature packing vs.
/// artifact execution. Serving feeds these into its per-stage latency
/// histograms ([`crate::serve::SloMetrics`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct ForwardTiming {
    pub pack_secs: f64,
    pub execute_secs: f64,
}

/// Execute a forward whose graph operands were packed up front by
/// [`plan_forward_operands`] — the serving hot path: per call it packs
/// only the (mutable) feature matrix and runs the artifact. `x` is the
/// full `[n, f_data]` row-major feature state (`n = x.len() / f_data`).
pub fn forward_packed(
    engine: &Engine,
    name: &str,
    bucket: &BucketInfo,
    params: &[Tensor],
    graph_ops: &[Tensor],
    x: &[f32],
    f_data: usize,
) -> Result<Vec<f32>> {
    Ok(forward_packed_timed(engine, name, bucket, params, graph_ops, x, f_data)?.0)
}

/// [`forward_packed`] with the pack/execute wall-time split exposed.
pub fn forward_packed_timed(
    engine: &Engine,
    name: &str,
    bucket: &BucketInfo,
    params: &[Tensor],
    graph_ops: &[Tensor],
    x: &[f32],
    f_data: usize,
) -> Result<(Vec<f32>, ForwardTiming)> {
    let n = x.len() / f_data.max(1);
    let t_pack = Instant::now();
    let args = {
        let _sp = obs::span("forward.pack");
        let mut args: Vec<Tensor> = params.to_vec();
        args.extend_from_slice(graph_ops);
        args.push(pack_features(x, n, f_data, bucket)?);
        args
    };
    let pack_secs = t_pack.elapsed().as_secs_f64();
    let t_exec = Instant::now();
    let out = {
        let _sp = obs::span("forward.execute");
        engine.run(name, &args)?
    };
    let execute_secs = t_exec.elapsed().as_secs_f64();
    Ok((out[0].to_vec::<f32>()?, ForwardTiming { pack_secs, execute_secs }))
}

/// Run a forward pass honoring a plan's full class assignment — the
/// hybrid-aware twin of [`forward`]: uniform plans pack identically,
/// hybrid plans pack the dense class + merged sparse/inter operands the
/// trainer executed. One-shot convenience over
/// [`plan_forward_operands`] + [`forward_packed`].
pub fn forward_planned(
    engine: &Engine,
    d: &Decomposition,
    plan: &GearPlan,
    model: ModelKind,
    params: &[Tensor],
    x: &[f32],
    f_data: usize,
) -> Result<Vec<f32>> {
    let (name, bucket, ops) = plan_forward_operands(&engine.manifest, d, plan, model)?;
    forward_packed(engine, &name, &bucket, params, &ops, x, f_data)
}

/// Run a forward (inference) pass with trained parameters.
pub fn forward(
    engine: &Engine,
    d: &Decomposition,
    chosen: KernelPair,
    model: ModelKind,
    params: &[Tensor],
    x: &[f32],
    f_data: usize,
) -> Result<Vec<f32>> {
    let n = d.graph.n;
    let needed_edges = d.intra.nnz().max(d.inter.nnz());
    let bucket = engine
        .manifest
        .fit_bucket(n, needed_edges)
        .context("no bucket fits")?
        .clone();
    let name = Manifest::fwd_name(
        model.as_str(),
        chosen.intra_str(),
        &chosen.inter.to_string(),
        &bucket.name,
    );
    let mut args: Vec<Tensor> = params.to_vec();
    if let Some(ik) = chosen.intra {
        args.extend(pack_kernel_operands(ik, &d.intra, d.community, &bucket)?);
        args.extend(pack_kernel_operands(chosen.inter, &d.inter, d.community, &bucket)?);
    } else {
        args.extend(pack_kernel_operands(chosen.inter, &d.whole(), d.community, &bucket)?);
    }
    args.push(pack_features(x, n, f_data, &bucket)?);
    let out = engine.run(&name, &args)?;
    Ok(out[0].to_vec::<f32>()?)
}

/// Extract trained parameters from a report-producing run for reuse in
/// `forward` (params come back as literals; convert to host tensors).
pub fn literals_to_tensors(
    lits: &[xla::Literal],
    specs: &[crate::runtime::TensorSpec],
) -> Result<Vec<Tensor>> {
    lits.iter()
        .zip(specs)
        .map(|(l, s)| Ok(Tensor::f32(l.to_vec::<f32>()?, &s.shape)))
        .collect()
}
