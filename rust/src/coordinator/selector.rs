//! The adaptive selector (Sec. 3.3): feedback-driven per-subgraph kernel
//! choice.
//!
//! GNN training runs hundreds of iterations over a *static* topology, so
//! AdaptGear spends the first few iterations monitoring each candidate
//! kernel's measured time and locks the per-subgraph winner for the rest.
//! The timing source is pluggable: the real PJRT wall clock (`--clock
//! wall`) or the gpusim surface (`--clock sim`, deterministic — used by
//! the figure benches).

use std::collections::BTreeMap;

use crate::kernels::{KernelKind, KernelPair, INTER_CANDIDATES, INTRA_CANDIDATES};

/// Which subgraph a kernel candidate serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Intra,
    Inter,
}

/// A pluggable kernel timer. Implementations: gpusim cost (deterministic)
/// and PJRT wall-clock (see `trainer.rs`).
pub trait KernelTimer {
    /// Measured time (microseconds) of one launch of `kind` on the `role`
    /// subgraph at aggregate width `width`.
    fn time_us(&mut self, role: Role, kind: KernelKind, width: usize) -> f64;
}

/// Outcome of the monitoring phase.
#[derive(Debug, Clone)]
pub struct SelectorReport {
    /// Mean measured time per candidate, per aggregate width.
    pub intra_times: BTreeMap<&'static str, f64>,
    pub inter_times: BTreeMap<&'static str, f64>,
    pub chosen: KernelPair,
    /// Monitoring iterations consumed (the Sec. 6.3 overhead).
    pub monitor_iters: usize,
    /// Total monitoring time (us) beyond what the winning kernels would
    /// have cost — the selector's runtime overhead.
    pub monitor_overhead_us: f64,
}

/// Run the feedback loop: `repeats` timed iterations per candidate (the
/// paper's "first few iterations"), averaged over every aggregate width
/// the model uses.
pub fn select(
    timer: &mut dyn KernelTimer,
    widths: &[usize],
    repeats: usize,
) -> SelectorReport {
    let repeats = repeats.max(1);
    let mut intra_times: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut inter_times: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut total_monitor_us = 0.0;

    let mut measure = |role: Role, kind: KernelKind, out: &mut BTreeMap<&'static str, f64>| {
        let mut acc = 0.0;
        for _ in 0..repeats {
            for &w in widths {
                acc += timer.time_us(role, kind, w);
            }
        }
        let mean = acc / (repeats * widths.len().max(1)) as f64;
        out.insert(kind.as_str(), mean);
        acc
    };

    for kind in INTRA_CANDIDATES {
        total_monitor_us += measure(Role::Intra, kind, &mut intra_times);
    }
    for kind in INTER_CANDIDATES {
        total_monitor_us += measure(Role::Inter, kind, &mut inter_times);
    }

    let pick = |times: &BTreeMap<&'static str, f64>, candidates: &[KernelKind]| {
        candidates
            .iter()
            .copied()
            .min_by(|a, b| times[a.as_str()].partial_cmp(&times[b.as_str()]).unwrap())
            .unwrap()
    };
    let intra = pick(&intra_times, &INTRA_CANDIDATES);
    let inter = pick(&inter_times, &INTER_CANDIDATES);

    // overhead = monitoring minus what the winners would have cost anyway
    let winner_us = (intra_times[intra.as_str()] + inter_times[inter.as_str()])
        * (repeats * widths.len().max(1)) as f64;
    SelectorReport {
        chosen: KernelPair::new(intra, inter),
        intra_times,
        inter_times,
        monitor_iters: repeats * (INTRA_CANDIDATES.len() + INTER_CANDIDATES.len()),
        monitor_overhead_us: (total_monitor_us - winner_us).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scripted timer for unit tests.
    struct Fake(BTreeMap<(&'static str, usize), f64>);

    impl KernelTimer for Fake {
        fn time_us(&mut self, _role: Role, kind: KernelKind, width: usize) -> f64 {
            *self.0.get(&(kind.as_str(), width)).unwrap_or(&1.0)
        }
    }

    #[test]
    fn picks_fastest_per_subgraph() {
        let mut m = BTreeMap::new();
        m.insert(("csr_intra", 32), 5.0);
        m.insert(("dense_block", 32), 2.0);
        m.insert(("csr_inter", 32), 3.0);
        m.insert(("coo", 32), 9.0);
        let mut t = Fake(m);
        let r = select(&mut t, &[32], 3);
        assert_eq!(r.chosen, KernelPair::new(KernelKind::DenseBlock, KernelKind::CsrInter));
        assert_eq!(r.monitor_iters, 12);
    }

    #[test]
    fn averages_across_widths() {
        // dense wins at width 8, csr_intra wins at width 64; averages decide
        let mut m = BTreeMap::new();
        m.insert(("dense_block", 8), 1.0);
        m.insert(("dense_block", 64), 10.0);
        m.insert(("csr_intra", 8), 4.0);
        m.insert(("csr_intra", 64), 4.0);
        m.insert(("csr_inter", 8), 1.0);
        m.insert(("csr_inter", 64), 1.0);
        m.insert(("coo", 8), 2.0);
        m.insert(("coo", 64), 2.0);
        let mut t = Fake(m);
        let r = select(&mut t, &[8, 64], 1);
        assert_eq!(r.chosen.intra, Some(KernelKind::CsrIntra));
    }

    #[test]
    fn overhead_is_nonnegative_and_reflects_losers() {
        let mut m = BTreeMap::new();
        m.insert(("csr_intra", 32), 1.0);
        m.insert(("dense_block", 32), 100.0);
        m.insert(("csr_inter", 32), 1.0);
        m.insert(("coo", 32), 100.0);
        let mut t = Fake(m);
        let r = select(&mut t, &[32], 2);
        // losers cost 200 us each over 2 repeats => overhead ~400
        assert!(r.monitor_overhead_us > 300.0);
    }
}
