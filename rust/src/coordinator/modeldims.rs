//! Model shape descriptions used by both the simulated strategies and the
//! real PJRT trainer.

/// Which GNN benchmark (Sec. 5: GCN and GIN with their default configs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Gcn,
    Gin,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Gin => "gin",
        }
    }

    /// Thin wrapper over the canonical [`FromStr`](std::str::FromStr) path.
    pub fn parse(s: &str) -> Option<ModelKind> {
        s.parse().ok()
    }
}

/// Canonical string dispatch — CLI parsing, manifest lookup, and plan
/// deserialization all come through here.
impl std::str::FromStr for ModelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ModelKind, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Ok(ModelKind::Gcn),
            "gin" => Ok(ModelKind::Gin),
            other => Err(anyhow::anyhow!("unknown model {other:?} (expected gcn|gin)")),
        }
    }
}

/// Layer dimensions of a 2-layer model instance.
#[derive(Debug, Clone, Copy)]
pub struct ModelDims {
    pub kind: ModelKind,
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl ModelDims {
    pub fn new(kind: ModelKind, features: usize, hidden: usize, classes: usize) -> ModelDims {
        ModelDims { kind, features, hidden, classes }
    }

    /// Feature widths at which neighborhood aggregation runs.
    ///
    /// GCN transforms-then-aggregates: `A_hat (X W1)` then `A_hat (H W2)`
    /// — widths `[hidden, classes]`. GIN aggregates raw features first:
    /// widths `[features, hidden]`. This is why GIN spends a larger share
    /// on graph operations (Sec. 6.1's explanation of its bigger speedup).
    pub fn aggregate_widths(&self) -> [usize; 2] {
        match self.kind {
            ModelKind::Gcn => [self.hidden, self.classes],
            ModelKind::Gin => [self.features, self.hidden],
        }
    }

    /// Dense (update-phase) GEMMs per forward pass as `(m_rows_factor,
    /// k, n)` — `m` is the vertex count, filled in by the caller.
    pub fn update_gemms(&self) -> Vec<(usize, usize)> {
        match self.kind {
            ModelKind::Gcn => vec![(self.features, self.hidden), (self.hidden, self.classes)],
            ModelKind::Gin => vec![
                (self.features, self.hidden),
                (self.hidden, self.hidden),
                (self.hidden, self.hidden),
                (self.hidden, self.hidden),
                (self.hidden, self.classes),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gin_aggregates_wider_than_gcn() {
        let gcn = ModelDims::new(ModelKind::Gcn, 128, 32, 8);
        let gin = ModelDims::new(ModelKind::Gin, 128, 32, 8);
        let gcn_w: usize = gcn.aggregate_widths().iter().sum();
        let gin_w: usize = gin.aggregate_widths().iter().sum();
        assert!(gin_w > gcn_w);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(ModelKind::parse("GCN"), Some(ModelKind::Gcn));
        assert_eq!(ModelKind::parse("gin"), Some(ModelKind::Gin));
        assert_eq!(ModelKind::parse("mlp"), None);
    }
}
