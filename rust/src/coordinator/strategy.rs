//! Execution strategies: AdaptGear's three optimization levels and every
//! baseline the paper compares against (Table 2 / Sec. 6), expressed as
//! iteration-cost assemblies over the gpusim surface.
//!
//! Baselines are reimplemented as *strategies over the same substrate*,
//! each keeping the property the paper contrasts: kernel-mapping
//! granularity × format policy × runtime overhead (DESIGN.md Sec. 2).

use std::collections::HashMap;

use crate::graph::{Csr, Graph};
use crate::gpusim::{elementwise_us, gemm_us, kernel_cost, GpuModel, IterationCost, KernelCost};
use crate::kernels::KernelKind;
use crate::partition::{Decomposition, Propagation, Reorder};

use super::modeldims::ModelDims;

/// Every comparable system in the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// DGL (framework baseline): full-graph CSR, per-op framework
    /// dispatch, unfused elementwise ops.
    Dgl,
    /// PyG (framework baseline): full-graph COO with edge-message
    /// materialization.
    Pyg,
    /// GNNAdvisor with rabbit-order preprocessing: tuned full-graph CSR.
    GnnAdvisorRabbit,
    /// GNNAdvisor with METIS preprocessing.
    GnnAdvisorMetis,
    /// PCGCN: block-level per-tile format choice with per-block launches
    /// and result merging. Tile size swept externally (Fig. 10).
    Pcgcn,
    /// AdaptGear O1: full-graph-level static CSR kernel (Fig. 11).
    AdaptGearO1,
    /// AdaptGear O2: static subgraph kernels (CSR intra + COO inter).
    AdaptGearO2,
    /// AdaptGear O3: subgraph-level adaptive kernels (the full system).
    AdaptGear,
}

pub const FIG8_BASELINES: [Strategy; 2] = [Strategy::Dgl, Strategy::Pyg];

/// Slowdown of generic framework aggregation kernels (cuSPARSE csrmm /
/// torch-scatter) relative to hand-tuned GNN kernels — the 2-4x gap the
/// GNNAdvisor and GE-SpMM papers measure.
const FRAMEWORK_KERNEL_QUALITY: f64 = 1.8;

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::Dgl => "DGL",
            Strategy::Pyg => "PyG",
            Strategy::GnnAdvisorRabbit => "GNNA-Rabbit",
            Strategy::GnnAdvisorMetis => "GNNA-Metis",
            Strategy::Pcgcn => "PCGCN",
            Strategy::AdaptGearO1 => "AdaptGear-O1",
            Strategy::AdaptGearO2 => "AdaptGear-O2",
            Strategy::AdaptGear => "AdaptGear",
        }
    }

    /// Preprocessing each system applies before training.
    pub fn reorder(&self) -> Reorder {
        match self {
            Strategy::Dgl | Strategy::Pyg => Reorder::Identity,
            Strategy::GnnAdvisorRabbit => Reorder::Rabbit,
            _ => Reorder::Metis,
        }
    }
}

/// Simulated cost of ONE forward pass's graph+update operators.
/// (Training iterations scale this uniformly; see IterationCost::scaled.)
pub fn forward_cost(
    strategy: Strategy,
    d: &Decomposition,
    model: &ModelDims,
    gpu: &GpuModel,
    pcgcn_tile: usize,
) -> IterationCost {
    let mut it = IterationCost::default();
    let n = d.graph.n;
    let community = d.community;

    // -- aggregation phase: one launch set per aggregate width
    for &w in &model.aggregate_widths() {
        match strategy {
            Strategy::Dgl => {
                // generic cuSPARSE-style SpMM: ~2.5x off hand-tuned
                // kernels (the gap GNNAdvisor/GE-SpMM report), plus per-op
                // framework dispatch around the SpMM
                let whole = d.whole();
                let mut c = kernel_cost(KernelKind::CsrInter, &whole, w, community, gpu);
                c.compute_us *= FRAMEWORK_KERNEL_QUALITY;
                c.memory_us *= FRAMEWORK_KERNEL_QUALITY;
                c.time_us = c.launch_us + c.compute_us.max(c.memory_us);
                it.add_kernel(&c);
                it.add_overhead(gpu.framework_op_us * 2.0);
            }
            Strategy::Pyg => {
                let whole = d.whole();
                let mut c = kernel_cost(KernelKind::Coo, &whole, w, community, gpu);
                // PyG materializes per-edge messages: an extra E*w*4-byte
                // round trip through HBM, on top of generic scatter kernels
                let msg_bytes = (whole.nnz() * w * 4) as f64;
                c.compute_us *= FRAMEWORK_KERNEL_QUALITY;
                c.memory_us = c.memory_us * FRAMEWORK_KERNEL_QUALITY + gpu.stream_us(msg_bytes);
                c.time_us = c.launch_us + c.compute_us.max(c.memory_us);
                it.add_kernel(&c);
                it.add_overhead(gpu.framework_op_us * 2.0);
            }
            Strategy::GnnAdvisorRabbit | Strategy::GnnAdvisorMetis => {
                // neighbor grouping + dimension workers bound the warp
                // imbalance GNNAdvisor exists to fix
                let whole = d.whole();
                it.add_kernel(&crate::gpusim::kernel_cost::csr_inter_cost_with_imb(
                    &whole, w, gpu, Some(1.15),
                ));
            }
            Strategy::Pcgcn => {
                pcgcn_cost(d, w, pcgcn_tile, gpu, &mut it);
            }
            Strategy::AdaptGearO1 => {
                // O1 = our tuned CSR kernel at full-graph granularity —
                // operationally the same point as GNNA-Metis (Table 2).
                let whole = d.whole();
                it.add_kernel(&crate::gpusim::kernel_cost::csr_inter_cost_with_imb(
                    &whole, w, gpu, Some(1.15),
                ));
            }
            Strategy::AdaptGearO2 => {
                let (ic, jc) = crate::gpusim::kernel_cost::subgraph_pair_cost(
                    KernelKind::CsrIntra,
                    KernelKind::Coo,
                    &d.intra,
                    &d.inter,
                    w,
                    community,
                    gpu,
                );
                it.add_kernel(&ic);
                it.add_kernel(&jc);
            }
            Strategy::AdaptGear => {
                let pair = best_adaptive_pair(d, w, gpu);
                let (ic, jc) = crate::gpusim::kernel_cost::subgraph_pair_cost(
                    pair.intra.unwrap(),
                    pair.inter,
                    &d.intra,
                    &d.inter,
                    w,
                    community,
                    gpu,
                );
                it.add_kernel(&ic);
                it.add_kernel(&jc);
            }
        }
    }

    // -- update phase (identical shape for all strategies)
    for (k, out) in model.update_gemms() {
        it.add_update(gemm_us(n, k, out, gpu));
        it.add_update(elementwise_us(n * out, gpu)); // bias + activation
        if matches!(strategy, Strategy::Dgl | Strategy::Pyg) {
            it.add_overhead(gpu.framework_op_us * 2.0);
        }
    }
    it
}

/// The simulated-fastest kernel per subgraph — absorbed by the plan
/// subsystem ([`SimCostPlanner`](crate::plan::SimCostPlanner) is its
/// planner form); re-exported here because the strategy assemblies and
/// the figure benches sit on the same decision.
pub use crate::plan::planners::best_adaptive_pair;

/// Aggregate-only cost of GNNAdvisor at a given width (the paper's Fig. 3b
/// profiles the first-layer aggregate at the dataset's raw feature width).
pub fn gnnadvisor_aggregate_cost(d: &Decomposition, width: usize, gpu: &GpuModel) -> IterationCost {
    let mut it = IterationCost::default();
    let whole = d.whole();
    it.add_kernel(&crate::gpusim::kernel_cost::csr_inter_cost_with_imb(
        &whole, width, gpu, Some(1.15),
    ));
    it
}

/// Aggregate-only cost of PCGCN at a given width (Fig. 3b twin).
pub fn pcgcn_aggregate_cost(
    d: &Decomposition,
    width: usize,
    tile: usize,
    gpu: &GpuModel,
) -> IterationCost {
    let mut it = IterationCost::default();
    pcgcn_cost(d, width, tile, gpu, &mut it);
    it
}

/// PCGCN's block-level mapping: the adjacency is tiled `tile x tile`; each
/// nonempty tile is launched as its own kernel (dense if locally dense,
/// sparse otherwise) and each tile-row's partials are merged — the extra
/// accumulation the paper blames for PCGCN's overhead (Sec. 2.2, Fig. 3b).
fn pcgcn_cost(d: &Decomposition, w: usize, tile: usize, gpu: &GpuModel, it: &mut IterationCost) {
    let whole = d.whole();
    let n = d.graph.n;
    let tile = tile.max(2);

    // occupancy map: edges per tile
    let mut tiles: HashMap<(u32, u32), u32> = HashMap::new();
    for (r, c, _) in whole.to_triplets() {
        *tiles.entry(((r as usize / tile) as u32, (c as usize / tile) as u32)).or_insert(0) += 1;
    }

    // PCGCN fuses each execution mode into ONE kernel (dense pass + sparse
    // pass) with per-tile CTAs; the overhead the paper measures is CTA
    // scheduling per tile plus the partial-result merges.
    const DENSE_THRESHOLD: f64 = 0.10;
    const TILE_SCHED_US: f64 = 0.02; // CTA setup per nonempty tile
    let mut dense_pass = KernelCost::noop(KernelKind::DenseBlock, gpu);
    let mut sparse_pass = KernelCost::noop(KernelKind::CsrInter, gpu);
    let mut row_tiles: HashMap<u32, u32> = HashMap::new();
    for (&(bi, _bj), &cnt) in &tiles {
        *row_tiles.entry(bi).or_insert(0) += 1;
        let density = cnt as f64 / (tile * tile) as f64;
        let rows = tile.min(n);
        if density >= DENSE_THRESHOLD {
            // dense tile GEMM: (tile x tile) @ (tile x w)
            let flops = (rows * rows * w * 2) as f64;
            let bytes = ((rows * rows + 2 * rows * w) * 4) as f64;
            dense_pass.compute_us += gpu.dense_us(flops) + TILE_SCHED_US;
            dense_pass.memory_us += gpu.stream_us(bytes);
            dense_pass.flops += flops;
            dense_pass.bytes += bytes;
            dense_pass.l2_hits += rows as u64; // tile-resident locality
            dense_pass.l2_accesses += rows as u64 + 1;
        } else {
            // sparse tile: CSR over its cnt edges; within-tile locality
            // decays as tiles grow past the L2-friendly range
            let locality = if tile <= 64 {
                0.92
            } else if tile <= 512 {
                0.85
            } else {
                0.6
            };
            let flops = (cnt as usize * w * 2) as f64;
            let bytes = (cnt as usize * (8 + w * 4)) as f64 + (rows * 4) as f64;
            sparse_pass.compute_us += gpu.fp32_us(flops) + TILE_SCHED_US;
            sparse_pass.memory_us +=
                gpu.gather_us(bytes * (1.0 - locality)) + gpu.stream_us(bytes * locality) / 2.0;
            sparse_pass.flops += flops;
            sparse_pass.bytes += bytes;
            sparse_pass.l2_hits += (cnt as f64 * locality) as u64;
            sparse_pass.l2_accesses += cnt as u64;
        }
    }
    for mut pass in [dense_pass, sparse_pass] {
        pass.time_us = gpu.launch_us + pass.compute_us.max(pass.memory_us);
        it.add_kernel(&pass);
    }
    // merge partial results: one accumulation kernel that reads every
    // extra per-tile partial and folds it into the output (read partial +
    // read acc + write acc = 12 B/element)
    let mut merge_bytes = 0f64;
    for (_bi, cnt) in row_tiles {
        if cnt > 1 {
            merge_bytes += (cnt - 1) as f64 * (tile.min(n) * w * 12) as f64;
        }
    }
    if merge_bytes > 0.0 {
        it.add_overhead(gpu.launch_us + gpu.stream_us(merge_bytes));
    }
}

/// Preprocess a graph the way `strategy` would (reorder + decompose) and
/// report wall time spent, mirroring the Sec. 6.3 overhead study.
pub fn preprocess(
    strategy: Strategy,
    g: &Graph,
    propagation: Propagation,
    community: usize,
    seed: u64,
) -> (Decomposition, PreprocessTimes) {
    let t0 = std::time::Instant::now();
    let reorder = strategy.reorder();
    let perm = reorder.order(g, community, seed);
    let reorder_secs = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let graph = g.relabel(&perm);
    let matrix = match propagation {
        Propagation::GcnNormalized => Csr::gcn_normalized(&graph),
        Propagation::PlainAdjacency => Csr::adjacency(&graph),
    };
    let (intra, inter) = matrix.split_block_diagonal(community);
    let decompose_secs = t1.elapsed().as_secs_f64();

    (
        Decomposition { graph, perm, intra, inter, community },
        PreprocessTimes { reorder_secs, decompose_secs },
    )
}

/// Wall time spent in the two preprocessing stages (Sec. 6.3).
#[derive(Debug, Clone, Copy)]
pub struct PreprocessTimes {
    pub reorder_secs: f64,
    pub decompose_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::gpusim::A100;
    use crate::coordinator::modeldims::ModelKind;
    use crate::util::rng::Rng;

    fn decomp(n: usize, seed: u64) -> Decomposition {
        let mut rng = Rng::new(seed);
        let g = planted_partition(n, 16, 0.5, 0.01, &mut rng);
        let mut sh: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut sh);
        let (d, _) = preprocess(Strategy::AdaptGear, &g.relabel(&sh), Propagation::GcnNormalized, 16, 1);
        d
    }

    fn dims() -> ModelDims {
        ModelDims::new(ModelKind::Gcn, 64, 32, 8)
    }

    #[test]
    fn adaptgear_beats_frameworks() {
        let d = decomp(2048, 1);
        let ours = forward_cost(Strategy::AdaptGear, &d, &dims(), &A100, 64).total_us();
        let dgl = forward_cost(Strategy::Dgl, &d, &dims(), &A100, 64).total_us();
        let pyg = forward_cost(Strategy::Pyg, &d, &dims(), &A100, 64).total_us();
        assert!(ours < dgl, "ours {ours} dgl {dgl}");
        assert!(ours < pyg, "ours {ours} pyg {pyg}");
    }

    #[test]
    fn ablation_o3_never_loses_to_o2() {
        // O3 picks the per-subgraph minimum over a candidate set that
        // includes O2's static choice, so it can never be slower.
        for seed in 1..5 {
            let d = decomp(2048, seed);
            let o2 = forward_cost(Strategy::AdaptGearO2, &d, &dims(), &A100, 64).total_us();
            let o3 = forward_cost(Strategy::AdaptGear, &d, &dims(), &A100, 64).total_us();
            assert!(o3 <= o2 * 1.001, "o3 {o3} vs o2 {o2} (seed {seed})");
        }
    }

    #[test]
    fn ablation_o3_beats_o1_beyond_l2() {
        // subgraph-level wins once the aggregate working set exceeds L2
        // (paper regime); V100's 6 MB L2 with wide GIN-style aggregates,
        // on a genuinely community-heavy graph (cf. Fig. 4's affinities —
        // the molecule collections are ~0.9 intra)
        let mut rng = Rng::new(2);
        let g = planted_partition(8192, 16, 0.6, 0.0002, &mut rng);
        let mut sh: Vec<u32> = (0..8192).collect();
        rng.shuffle(&mut sh);
        let (d, _) =
            preprocess(Strategy::AdaptGear, &g.relabel(&sh), Propagation::GcnNormalized, 16, 1);
        let dims = ModelDims::new(ModelKind::Gin, 512, 64, 8);
        let o1 = forward_cost(Strategy::AdaptGearO1, &d, &dims, &crate::gpusim::V100, 0).total_us();
        let o3 = forward_cost(Strategy::AdaptGear, &d, &dims, &crate::gpusim::V100, 0).total_us();
        assert!(o3 < o1, "o3 {o3} vs o1 {o1}");
    }

    #[test]
    fn pcgcn_higher_hit_rate_but_slower() {
        // Fig. 3b's tension, in its regime: first-layer aggregate at the
        // raw feature width, working set larger than L2
        let d = decomp(4096, 3);
        let width = 1024;
        let pcgcn = super::pcgcn_aggregate_cost(&d, width, 16, &crate::gpusim::V100);
        let gnna = super::gnnadvisor_aggregate_cost(&d, width, &crate::gpusim::V100);
        assert!(pcgcn.l2_hit_rate() > gnna.l2_hit_rate(),
            "pcgcn hit {} vs gnna {}", pcgcn.l2_hit_rate(), gnna.l2_hit_rate());
        assert!(pcgcn.kernel_launches > gnna.kernel_launches);
        assert!(pcgcn.total_us() > gnna.total_us(),
            "pcgcn {} vs gnna {}", pcgcn.total_us(), gnna.total_us());
    }

    #[test]
    fn adaptgear_beats_pcgcn_at_any_tile() {
        let d = decomp(8192, 4);
        let dims = ModelDims::new(ModelKind::Gin, 256, 64, 8);
        let gpu = &crate::gpusim::V100;
        let ours = forward_cost(Strategy::AdaptGear, &d, &dims, gpu, 0).total_us();
        let best_pcgcn = [16usize, 64, 256, 1024]
            .iter()
            .map(|&t| forward_cost(Strategy::Pcgcn, &d, &dims, gpu, t).total_us())
            .fold(f64::INFINITY, f64::min);
        assert!(ours < best_pcgcn, "ours {ours} vs pcgcn {best_pcgcn}");
    }

    #[test]
    fn preprocess_measures_both_stages() {
        let mut rng = Rng::new(5);
        let g = planted_partition(512, 16, 0.4, 0.01, &mut rng);
        let (d, t) = preprocess(Strategy::AdaptGear, &g, Propagation::GcnNormalized, 16, 1);
        assert!(t.reorder_secs >= 0.0 && t.decompose_secs > 0.0);
        assert_eq!(d.graph.n, 512);
    }

    #[test]
    fn strategy_reorders() {
        assert_eq!(Strategy::Dgl.reorder(), Reorder::Identity);
        assert_eq!(Strategy::GnnAdvisorRabbit.reorder(), Reorder::Rabbit);
        assert_eq!(Strategy::AdaptGear.reorder(), Reorder::Metis);
    }
}
