//! Training-memory accounting — the Fig. 12 "Topo. Tensor" overhead study
//! and the Sec. 6.3 runtime-overhead bookkeeping.

use crate::partition::Decomposition;

use super::modeldims::ModelDims;

/// Peak-memory breakdown of one training run (bytes).
#[derive(Debug, Clone, Copy)]
pub struct MemoryReport {
    /// Input vertex features `[n, f]`.
    pub feature_bytes: usize,
    /// Forward activations kept for backward (per-layer outputs).
    pub activation_bytes: usize,
    /// Parameters + their gradients + optimizer state (SGD: grads only).
    pub param_bytes: usize,
    /// Topology storage for BOTH subgraphs (decomposed form).
    pub topo_bytes: usize,
    /// Extra topology bytes versus a single full-graph CSR.
    pub topo_extra_bytes: usize,
}

impl MemoryReport {
    pub fn total(&self) -> usize {
        self.feature_bytes + self.activation_bytes + self.param_bytes + self.topo_bytes
    }

    /// Fig. 12's metric: share of peak memory spent on subgraph topology.
    pub fn topo_fraction(&self) -> f64 {
        self.topo_bytes as f64 / self.total().max(1) as f64
    }
}

/// Hybrid-aware twin of [`memory_breakdown`]: topology bytes are derived
/// from the ACTUAL parts a plan stores — each intra density class keeps
/// its own row_ptr, so a hybrid plan's Fig. 12 overhead is one extra
/// `(V+1)` row_ptr per extra class, not a hard-coded two-part constant.
pub fn memory_breakdown_planned(
    d: &Decomposition,
    dims: &ModelDims,
    assignment: &crate::plan::GearAssignment,
) -> MemoryReport {
    let mut report = memory_breakdown(d, dims);
    let split = d.split_intra(assignment.threshold);
    report.topo_bytes = split.topology_bytes(&d.inter);
    report.topo_extra_bytes = split.extra_topology_bytes(d.graph.n);
    report
}

/// Estimate the training-memory breakdown for a model over a decomposed
/// graph (f32 everywhere, SGD optimizer — matching the AOT train step).
pub fn memory_breakdown(d: &Decomposition, dims: &ModelDims) -> MemoryReport {
    let n = d.graph.n;
    let feature_bytes = n * dims.features * 4;

    // activations stashed for backward: aggregate outputs + post-MLP
    // activations per layer, both widths, fwd+bwd copies
    let act_elems: usize = dims
        .aggregate_widths()
        .iter()
        .map(|w| n * w * 2)
        .sum::<usize>()
        + dims.update_gemms().iter().map(|&(_, out)| n * out).sum::<usize>();
    let activation_bytes = act_elems * 4 * 2; // + gradient mirror

    let param_elems: usize = dims
        .update_gemms()
        .iter()
        .map(|&(k, out)| k * out + out)
        .sum();
    let param_bytes = param_elems * 4 * 2; // params + grads

    MemoryReport {
        feature_bytes,
        activation_bytes,
        param_bytes,
        topo_bytes: d.topology_bytes(),
        topo_extra_bytes: d.extra_topology_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modeldims::ModelKind;
    use crate::graph::generate::planted_partition;
    use crate::partition::{Propagation, Reorder};
    use crate::util::rng::Rng;

    fn decomp(n: usize) -> Decomposition {
        let mut rng = Rng::new(1);
        let g = planted_partition(n, 16, 0.4, 0.02, &mut rng);
        Decomposition::build(&g, Reorder::Metis, Propagation::GcnNormalized, 16, 0)
    }

    #[test]
    fn topology_is_small_fraction_with_real_features() {
        // the Fig. 12 claim: features/activations dominate
        let d = decomp(512);
        let dims = ModelDims::new(ModelKind::Gcn, 500, 32, 8); // pubmed-ish widths
        let m = memory_breakdown(&d, &dims);
        assert!(m.topo_fraction() < 0.15, "topo fraction {}", m.topo_fraction());
        assert!(m.total() > m.topo_bytes);
    }

    #[test]
    fn narrow_features_raise_topo_share() {
        let d = decomp(512);
        let wide = memory_breakdown(&d, &ModelDims::new(ModelKind::Gcn, 1433, 32, 8));
        let narrow = memory_breakdown(&d, &ModelDims::new(ModelKind::Gcn, 29, 32, 8));
        assert!(narrow.topo_fraction() > wide.topo_fraction());
    }

    #[test]
    fn hybrid_breakdown_charges_one_row_ptr_per_extra_class() {
        use crate::kernels::{KernelKind, KernelPair};
        use crate::plan::GearAssignment;
        let d = decomp(256);
        let dims = ModelDims::new(ModelKind::Gcn, 64, 32, 8);
        let uniform = memory_breakdown(&d, &dims);
        let profile = d.intra_block_profile();
        let rows: usize = profile.blocks.iter().map(|&(r, _)| r).sum();
        let a = GearAssignment::uniform(
            KernelPair::new(KernelKind::CsrIntra, KernelKind::CsrInter),
            (profile.len(), rows, d.intra.nnz(), 0.0),
            (d.inter.n_rows, d.inter.nnz(), 0.0),
        );
        let planned = memory_breakdown_planned(&d, &dims, &a);
        // uniform assignment: same two parts, same accounting
        assert_eq!(planned.topo_extra_bytes, uniform.topo_extra_bytes);
        assert_eq!(planned.topo_bytes, uniform.topo_bytes);
    }

    #[test]
    fn gin_activations_exceed_gcn() {
        let d = decomp(256);
        let gcn = memory_breakdown(&d, &ModelDims::new(ModelKind::Gcn, 64, 32, 8));
        let gin = memory_breakdown(&d, &ModelDims::new(ModelKind::Gin, 64, 32, 8));
        assert!(gin.activation_bytes > gcn.activation_bytes);
    }
}
