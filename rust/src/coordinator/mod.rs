//! Layer-3 coordinator — the paper's system contribution.
//!
//! * [`selector`]: feedback-driven adaptive kernel selection (Sec. 3.3).
//! * [`strategy`]: AdaptGear O1/O2/O3 and every baseline (Table 2) as
//!   iteration-cost assemblies over gpusim.
//! * [`trainer`]: the real PJRT training loop (monitor → locked steps).
//! * [`pipeline`]: dataset → preprocess → select → train, end to end.
//! * [`metrics`]: memory/overhead accounting (Fig. 12, Sec. 6.3).

pub mod metrics;
pub mod modeldims;
pub mod pipeline;
pub mod selector;
pub mod strategy;
pub mod trainer;

pub use modeldims::{ModelDims, ModelKind};
pub use selector::{select, KernelTimer, Role, SelectorReport};
pub use strategy::{best_adaptive_pair, forward_cost, preprocess, PreprocessTimes, Strategy};
pub use trainer::{train, Clock, TrainConfig, TrainReport};
