//! Layer-3 coordinator — the paper's system contribution.
//!
//! * [`selector`]: feedback-driven adaptive kernel selection (Sec. 3.3),
//!   driven by the planners in [`crate::plan`].
//! * [`strategy`]: AdaptGear O1/O2/O3 and every baseline (Table 2) as
//!   iteration-cost assemblies over gpusim.
//! * [`trainer`]: the real PJRT training loop executing a
//!   [`GearPlan`](crate::plan::GearPlan)'s kernel decision.
//! * [`sampled`]: mini-batch neighbor-sampled training — per-batch
//!   subgraphs planned through the amortized profile-keyed cache.
//! * [`pipeline`]: dataset → preprocess → plan → train, end to end, and
//!   [`pipeline::Run`] — the one builder entrypoint for train/serve/bench.
//! * [`metrics`]: memory/overhead accounting (Fig. 12, Sec. 6.3).

pub mod metrics;
pub mod modeldims;
pub mod pipeline;
pub mod sampled;
pub mod selector;
pub mod strategy;
pub mod trainer;

pub use crate::plan::Clock;
pub use modeldims::{ModelDims, ModelKind};
pub use pipeline::Run;
pub use sampled::{train_sampled, SampleConfig, SampledBackend, SampledTrainReport};
pub use selector::{select, KernelTimer, Role, SelectorReport};
pub use strategy::{best_adaptive_pair, forward_cost, preprocess, PreprocessTimes, Strategy};
pub use trainer::{train, TrainConfig, TrainReport};

/// Scatter features and labels from the original vertex order into a
/// decomposition's reordered id space (`perm[old] = new`).
///
/// `x0` is `[n, f_data]` row-major in the original order; the returned
/// pair is the same data in the reordered space, ready for the trainer,
/// the forward path, and the serve registry.
pub fn apply_perm(
    perm: &[u32],
    x0: &[f32],
    labels0: &[i32],
    f_data: usize,
) -> (Vec<f32>, Vec<i32>) {
    let n = perm.len();
    debug_assert_eq!(x0.len(), n * f_data);
    debug_assert_eq!(labels0.len(), n);
    let mut x = vec![0.0f32; n * f_data];
    let mut labels = vec![0i32; n];
    for old in 0..n {
        let new = perm[old] as usize;
        x[new * f_data..(new + 1) * f_data]
            .copy_from_slice(&x0[old * f_data..(old + 1) * f_data]);
        labels[new] = labels0[old];
    }
    (x, labels)
}

#[cfg(test)]
mod tests {
    use super::apply_perm;

    #[test]
    fn apply_perm_scatters_rows_and_labels() {
        // perm[old] = new: vertex 0 -> slot 2, 1 -> slot 0, 2 -> slot 1
        let perm = [2u32, 0, 1];
        let x0 = [0.0f32, 0.1, 1.0, 1.1, 2.0, 2.1]; // f_data = 2
        let labels0 = [10i32, 11, 12];
        let (x, labels) = apply_perm(&perm, &x0, &labels0, 2);
        assert_eq!(x, vec![1.0, 1.1, 2.0, 2.1, 0.0, 0.1]);
        assert_eq!(labels, vec![11, 12, 10]);
    }

    #[test]
    fn apply_perm_identity_is_noop() {
        let perm = [0u32, 1];
        let x0 = [5.0f32, 6.0];
        let (x, labels) = apply_perm(&perm, &x0, &[3, 4], 1);
        assert_eq!(x, x0.to_vec());
        assert_eq!(labels, vec![3, 4]);
    }
}
