//! Mini-batch neighbor-sampled training (DESIGN.md Sec. 10).
//!
//! [`train_sampled`] is the sampled twin of [`trainer::train`]: per
//! epoch it shuffles the vertex ids, chunks them into target batches,
//! samples each batch's subgraph from the full propagation matrix
//! ([`NeighborSampler`]), decomposes it, plans it through the amortized
//! [`BatchPlanner`] (profile hits skip the threshold sweep), and runs
//! ONE optimizer step per batch. Parameters persist across batches and
//! epochs.
//!
//! Two step backends ([`SampledBackend`]):
//!
//! * **PJRT** — packs the batch through `pack_assignment` and executes
//!   the AOT train-step artifact of the planned kernel pair, exactly
//!   like full-graph training. All batches must land in buckets with
//!   the same (features, hidden, classes) widths, because the trained
//!   parameters are shared.
//! * **Native** — the CPU fallback: a [`GcnModel`] whose aggregation
//!   runs the plan's class assignment on the native kernel schedules
//!   ([`AssignmentExec`]). This keeps `train --sampled` runnable on a
//!   bare checkout (no artifacts) and gives the equivalence tests an
//!   executable reference.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::gpusim::A100;
use crate::kernels::native_model::{FeatMode, GcnModel};
use crate::kernels::pack::{pack_assignment, pack_features, pack_labels_masked};
use crate::kernels::AssignmentExec;
use crate::obs;
use crate::partition::{Decomposition, Reorder};
use crate::plan::{BatchPlanner, GearPlan, PlanRequest, Planner, SimCostPlanner};
use crate::runtime::{literal_scalar_f32, BucketInfo, Engine, Manifest, Tensor, TensorSpec};
use crate::sample::{Fanout, NeighborSampler};
use crate::util::rng::Rng;

use super::modeldims::ModelKind;
use super::trainer::{self, TrainConfig};

/// Sampling-loop knobs, on top of the shared [`TrainConfig`] budget.
#[derive(Debug, Clone)]
pub struct SampleConfig {
    /// Per-layer neighbor budgets, outermost first (`--fanout 10,10`).
    pub fanouts: Vec<Fanout>,
    /// Target vertices per batch.
    pub batch_size: usize,
    /// Full passes over the vertex set.
    pub epochs: usize,
    /// Reordering applied to each batch subgraph before splitting.
    pub reorder: Reorder,
    /// Top-k activation sparsity (`--topk K`): keep only the K largest
    /// hidden lanes per row after ReLU, so the second aggregation runs at
    /// feature density `K / hidden` and the planner prices kernels at
    /// that density. `None` trains dense. Native backend only.
    pub topk: Option<usize>,
}

impl Default for SampleConfig {
    fn default() -> Self {
        SampleConfig {
            fanouts: vec![Fanout::Uniform(10), Fanout::Uniform(10)],
            batch_size: 256,
            epochs: 1,
            reorder: Reorder::Metis,
            topk: None,
        }
    }
}

/// Where sampled batch steps execute.
pub enum SampledBackend<'e> {
    /// AOT artifacts through PJRT (the production path).
    Pjrt(&'e Engine),
    /// Native CPU model at the given hidden/class widths (bare-checkout
    /// fallback; GCN only).
    Native { hidden: usize, classes: usize },
}

impl<'e> SampledBackend<'e> {
    pub fn name(&self) -> &'static str {
        match self {
            SampledBackend::Pjrt(_) => "pjrt",
            SampledBackend::Native { .. } => "native",
        }
    }
}

/// Wall-time split of one epoch (or a whole run) across the canonical
/// sampled-training stages: sample -> decompose -> plan -> pack -> step.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageSecs {
    pub sample: f64,
    pub decompose: f64,
    pub plan: f64,
    pub pack: f64,
    pub step: f64,
}

impl StageSecs {
    pub fn total(&self) -> f64 {
        self.sample + self.decompose + self.plan + self.pack + self.step
    }

    fn add(&mut self, other: &StageSecs) {
        self.sample += other.sample;
        self.decompose += other.decompose;
        self.plan += other.plan;
        self.pack += other.pack;
        self.step += other.step;
    }

    /// One-line rendering for the CLI's per-epoch report.
    pub fn render(&self) -> String {
        format!(
            "sample {:.2}s decompose {:.2}s plan {:.2}s pack {:.2}s step {:.2}s",
            self.sample, self.decompose, self.plan, self.pack, self.step
        )
    }
}

/// Outcome of one sampled training run.
#[derive(Debug)]
pub struct SampledTrainReport {
    /// Which backend executed the steps ("pjrt" | "native").
    pub backend: &'static str,
    pub epochs: usize,
    pub batches: usize,
    /// Per-batch training loss, in execution order.
    pub losses: Vec<f32>,
    /// Mean loss per epoch.
    pub epoch_mean_loss: Vec<f32>,
    /// Amortized-planner cache statistics across the whole run.
    pub plan_hits: usize,
    pub plan_misses: usize,
    /// Wall time split of the loop. `sample_secs` covers sampling +
    /// decomposition and `step_secs` covers pack + step (the historical
    /// three-way split); `stages` carries the full five-way accounting.
    pub sample_secs: f64,
    pub plan_secs: f64,
    pub step_secs: f64,
    /// Five-stage wall-time split over the whole run.
    pub stages: StageSecs,
    /// Per-epoch five-stage splits, in epoch order.
    pub epoch_stages: Vec<StageSecs>,
    /// Final parameters (host copies).
    pub params: Vec<Tensor>,
}

impl SampledTrainReport {
    /// Plan-cache hit rate over the whole run.
    pub fn plan_hit_rate(&self) -> f64 {
        let total = self.plan_hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_hits as f64 / total as f64
        }
    }

    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }
}

/// Per-run step state of the PJRT backend: parameters persist across
/// batches, so every batch must execute in a bucket with the widths the
/// parameters were initialized for.
struct PjrtState {
    params: Vec<xla::Literal>,
    param_specs: Vec<TensorSpec>,
    /// (features, hidden, classes) of the initializing bucket.
    widths: (usize, usize, usize),
}

/// Train `cfg.model` on `d_full`'s graph with layer-wise neighbor
/// sampling. `x`/`labels` are `[n, f_data]` / `[n]` in `d_full`'s vertex
/// order (the same contract as [`trainer::train`]).
pub fn train_sampled(
    backend: &mut SampledBackend,
    d_full: &Decomposition,
    x: &[f32],
    f_data: usize,
    labels: &[i32],
    cfg: &TrainConfig,
    scfg: &SampleConfig,
) -> Result<SampledTrainReport> {
    let n = d_full.graph.n;
    if n == 0 {
        bail!("cannot sample from an empty graph");
    }
    if scfg.batch_size == 0 || scfg.epochs == 0 {
        bail!("sampled training needs batch_size > 0 and epochs > 0");
    }
    if matches!(backend, SampledBackend::Native { .. }) && cfg.model != ModelKind::Gcn {
        bail!("the native sampled backend supports gcn only (build artifacts for gin)");
    }
    if let Some(k) = scfg.topk {
        if k == 0 {
            bail!("--topk needs k > 0 (omit it to train dense)");
        }
        if matches!(backend, SampledBackend::Pjrt(_)) {
            bail!(
                "--topk runs on the native backend only: the AOT train-step \
                 artifacts are compiled dense (drop --topk or drop the manifest)"
            );
        }
    }

    let prop = d_full.whole();
    let sampler = NeighborSampler::new(&prop, scfg.fanouts.clone())?;
    let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
    let mut rng = Rng::new(cfg.seed ^ 0x5a11);

    let mut pjrt: Option<PjrtState> = None;
    let mut native: Option<GcnModel> = None;

    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut losses = Vec::new();
    let mut epoch_mean_loss = Vec::new();
    let mut stages = StageSecs::default();
    let mut epoch_stages: Vec<StageSecs> = Vec::with_capacity(scfg.epochs);

    for epoch in 0..scfg.epochs {
        let mut epoch_sp = obs::span("train.epoch");
        epoch_sp.attr_num("epoch", epoch as f64);
        rng.shuffle(&mut order);
        let epoch_start = losses.len();
        let mut es = StageSecs::default();
        for chunk in order.chunks(scfg.batch_size) {
            let mut batch_sp = obs::span("train.batch");
            batch_sp.attr_num("targets", chunk.len() as f64);

            let t0 = Instant::now();
            let batch = {
                let _sp = obs::span("train.sample");
                sampler.sample(chunk, &mut rng)
            };
            es.sample += t0.elapsed().as_secs_f64();

            let td = Instant::now();
            let bd = {
                let _sp = obs::span("train.decompose");
                batch.decompose(scfg.reorder, d_full.community, cfg.seed)
            };
            es.decompose += td.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let bucket = bucket_for(backend, &bd, f_data)?;
            let plan = {
                let _sp = obs::span("train.plan");
                let mut req = PlanRequest::labeled(
                    &bd,
                    cfg.model,
                    &bucket,
                    "sampled-batch",
                    1.0,
                    scfg.reorder,
                    cfg.seed,
                );
                if let Some(k) = scfg.topk {
                    // price the second aggregation's operand: k live lanes
                    // out of `hidden` (also re-keys the plan cache, so a
                    // dense-feature plan is never served for this run)
                    req.feat_density = (k as f64 / bucket.hidden.max(1) as f64).min(1.0);
                }
                planner.plan(&req).context("planning a sampled batch")?
            };
            es.plan += t1.elapsed().as_secs_f64();

            let (bx, blabels, bmask) = batch.permute_for(&bd, x, f_data, labels);
            let t2 = Instant::now();
            let (loss, pack) = match backend {
                SampledBackend::Pjrt(engine) => pjrt_step(
                    *engine, &mut pjrt, &bd, &plan, &bucket, &bx, f_data, &blabels, &bmask, cfg,
                )?,
                SampledBackend::Native { hidden, classes } => {
                    let model = native.get_or_insert_with(|| {
                        let m = GcnModel::init(f_data, *hidden, *classes, cfg.seed);
                        match scfg.topk {
                            Some(k) => m.with_feat_mode(FeatMode::TopK(k)),
                            None => m,
                        }
                    });
                    native_step(model, &bd, &plan, &bx, &blabels, &bmask, cfg.lr)?
                }
            };
            es.pack += pack;
            es.step += (t2.elapsed().as_secs_f64() - pack).max(0.0);
            losses.push(loss);
        }
        let epoch_losses = &losses[epoch_start..];
        let mean = epoch_losses.iter().sum::<f32>() / epoch_losses.len().max(1) as f32;
        epoch_mean_loss.push(mean);
        stages.add(&es);
        epoch_stages.push(es);
    }

    let params = match backend {
        SampledBackend::Pjrt(_) => match pjrt {
            Some(state) => trainer::literals_to_tensors(&state.params, &state.param_specs)?,
            None => Vec::new(),
        },
        SampledBackend::Native { .. } => match native {
            Some(m) => vec![
                Tensor::f32(m.w1.clone(), &[m.f, m.h]),
                Tensor::f32(m.b1.clone(), &[m.h]),
                Tensor::f32(m.w2.clone(), &[m.h, m.c]),
                Tensor::f32(m.b2.clone(), &[m.c]),
            ],
            None => Vec::new(),
        },
    };

    Ok(SampledTrainReport {
        backend: backend.name(),
        epochs: scfg.epochs,
        batches: losses.len(),
        losses,
        epoch_mean_loss,
        plan_hits: planner.hits(),
        plan_misses: planner.misses(),
        sample_secs: stages.sample + stages.decompose,
        plan_secs: stages.plan,
        step_secs: stages.pack + stages.step,
        stages,
        epoch_stages,
        params,
    })
}

/// The AOT bucket a batch plans against. PJRT fits the manifest; the
/// native backend synthesizes a bucket from the batch itself (planning
/// needs widths and an edge capacity, not real artifacts).
fn bucket_for(
    backend: &SampledBackend,
    bd: &Decomposition,
    f_data: usize,
) -> Result<BucketInfo> {
    match backend {
        SampledBackend::Pjrt(engine) => {
            let needed = bd.intra.nnz().max(bd.inter.nnz());
            Ok(engine
                .manifest
                .fit_bucket(bd.graph.n, needed)
                .with_context(|| {
                    format!(
                        "no AOT bucket fits a sampled batch (n={}, edges={needed}); \
                         lower --batch-size or --fanout",
                        bd.graph.n
                    )
                })?
                .clone())
        }
        SampledBackend::Native { hidden, classes } => Ok(BucketInfo {
            name: format!("native-{}", bd.graph.n),
            vertices: bd.graph.n,
            // intra + inter so every admissible hybrid merge fits
            edges: bd.intra.nnz() + bd.inter.nnz(),
            features: f_data,
            hidden: *hidden,
            classes: *classes,
            blocks: bd.graph.n.div_ceil(bd.community.max(1)),
        }),
    }
}

/// One PJRT optimizer step over a batch: pack the plan's operands, run
/// the train-step artifact, feed the updated parameters forward.
/// Returns the step loss and the seconds spent packing operands.
#[allow(clippy::too_many_arguments)]
fn pjrt_step(
    engine: &Engine,
    state: &mut Option<PjrtState>,
    bd: &Decomposition,
    plan: &GearPlan,
    bucket: &BucketInfo,
    bx: &[f32],
    f_data: usize,
    blabels: &[i32],
    bmask: &[f32],
    cfg: &TrainConfig,
) -> Result<(f32, f64)> {
    let chosen = plan.chosen;
    let name = Manifest::train_name(
        cfg.model.as_str(),
        chosen.intra_str(),
        &chosen.inter.to_string(),
        &bucket.name,
    );
    let meta = engine.manifest.get(&name)?.clone();
    let loaded = engine.load(&name)?;

    // Initialize parameters on the first batch; afterwards only check
    // that this batch's bucket kept the widths they were shaped for.
    let n_params = trainer::graph_arg_start(&meta);
    let widths = (bucket.features, bucket.hidden, bucket.classes);
    let state = match state {
        Some(s) => {
            if s.widths != widths {
                bail!(
                    "sampled batch landed in bucket {} with widths {:?}, but parameters \
                     were initialized for {:?}; use a manifest with uniform widths",
                    bucket.name,
                    widths,
                    s.widths
                );
            }
            if s.params.len() != n_params {
                bail!(
                    "artifact {name} expects {n_params} parameters, run carries {}",
                    s.params.len()
                );
            }
            s
        }
        None => {
            let mut rng = Rng::new(cfg.seed ^ 0x9a9a);
            let mut params: Vec<xla::Literal> = Vec::with_capacity(n_params);
            for spec in &meta.inputs[..n_params] {
                params.push(trainer::init_param(&spec.shape, &mut rng)?.to_literal()?);
            }
            state.insert(PjrtState {
                params,
                param_specs: meta.inputs[..n_params].to_vec(),
                widths,
            })
        }
    };

    // ---- per-batch statics: graph operands + features + labels + mask + lr
    let t_pack = Instant::now();
    let static_lits: Vec<xla::Literal> = {
        let _sp = obs::span("train.pack");
        let (intra_ops, inter_ops) =
            pack_assignment(bd, &plan.assignment, bucket).context("packing a sampled batch")?;
        let bn = bd.graph.n;
        let mut lits: Vec<xla::Literal> = Vec::new();
        for t in intra_ops.iter().chain(inter_ops.iter()) {
            lits.push(t.to_literal()?);
        }
        lits.push(pack_features(bx, bn, f_data, bucket)?.to_literal()?);
        let (labels_t, mask_t) = pack_labels_masked(blabels, bmask, bucket)?;
        lits.push(labels_t.to_literal()?);
        lits.push(mask_t.to_literal()?);
        lits.push(Tensor::scalar_f32(cfg.lr).to_literal()?);
        lits
    };
    let pack_secs = t_pack.elapsed().as_secs_f64();
    if state.params.len() + static_lits.len() != meta.inputs.len() {
        bail!(
            "operand mismatch for {name}: {} params + {} statics != {} inputs",
            state.params.len(),
            static_lits.len(),
            meta.inputs.len()
        );
    }

    let mut args: Vec<&xla::Literal> = Vec::with_capacity(meta.inputs.len());
    args.extend(state.params.iter());
    args.extend(static_lits.iter());
    let mut outputs = {
        let _sp = obs::span("train.step");
        engine.run_literals(&loaded, &args, meta.outputs.len())?
    };
    let loss = outputs.pop().context("train_step returns params + loss")?;
    state.params = outputs;
    Ok((literal_scalar_f32(&loss)?, pack_secs))
}

/// One native CPU step: execute the plan's class assignment for `A·` and
/// the transposed whole batch matrix for `Aᵀ·`. Returns the step loss
/// and the seconds spent packing (building native schedules).
fn native_step(
    model: &mut GcnModel,
    bd: &Decomposition,
    plan: &GearPlan,
    bx: &[f32],
    blabels: &[i32],
    bmask: &[f32],
    lr: f32,
) -> Result<(f32, f64)> {
    if model.f * bd.graph.n != bx.len() {
        bail!(
            "feature width mismatch: model expects f={}, batch carries {}",
            model.f,
            bx.len() / bd.graph.n.max(1)
        );
    }
    let t_pack = Instant::now();
    let (exec, at) = {
        let _sp = obs::span("train.pack");
        let exec = AssignmentExec::build(bd, &plan.assignment)
            .context("compiling the batch plan to native schedules")?;
        (exec, bd.whole().transpose())
    };
    let pack_secs = t_pack.elapsed().as_secs_f64();
    let n = bd.graph.n;
    let _sp = obs::span("train.step");
    let loss = model.train_step(
        |t, w| exec.aggregate(t, w),
        |t, w| at.spmm(t, w),
        bx,
        n,
        blabels,
        bmask,
        lr,
    );
    Ok((loss, pack_secs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{apply_perm, preprocess, Strategy};
    use crate::graph::datasets;
    use crate::partition::Propagation;

    fn staged(scale: f64, seed: u64) -> (Decomposition, Vec<f32>, Vec<i32>, usize) {
        let spec = datasets::find("cora").unwrap();
        let data = spec.build_scaled(scale, seed);
        let (d, _) = preprocess(
            Strategy::AdaptGear,
            &data.graph,
            Propagation::GcnNormalized,
            16,
            seed,
        );
        let f = 16;
        let (x, labels) = apply_perm(&d.perm, &data.features(f), &data.labels(), f);
        (d, x, labels, f)
    }

    #[test]
    fn native_sampled_epoch_trains_and_amortizes_plans() {
        let (d, x, labels, f) = staged(0.25, 3);
        let cfg = TrainConfig { model: ModelKind::Gcn, steps: 0, lr: 0.1, seed: 7 };
        let scfg = SampleConfig {
            fanouts: vec![Fanout::Uniform(8), Fanout::Uniform(8)],
            batch_size: 64,
            epochs: 2,
            reorder: Reorder::Metis,
            topk: None,
        };
        let mut backend = SampledBackend::Native { hidden: 16, classes: 7 };
        let report = train_sampled(&mut backend, &d, &x, f, &labels, &cfg, &scfg).unwrap();
        assert_eq!(report.backend, "native");
        assert_eq!(report.epochs, 2);
        assert_eq!(report.batches, 2 * d.graph.n.div_ceil(64));
        assert_eq!(report.losses.len(), report.batches);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert_eq!(report.epoch_mean_loss.len(), 2);
        // training makes progress across epochs on the homophilous data
        assert!(
            report.epoch_mean_loss[1] < report.epoch_mean_loss[0],
            "epoch losses {:?} did not improve",
            report.epoch_mean_loss
        );
        // plan cache amortizes across same-workload batches
        assert_eq!(report.plan_hits + report.plan_misses, report.batches);
        assert!(
            report.plan_hit_rate() > 0.5,
            "hit rate {:.2} (hits {}, misses {})",
            report.plan_hit_rate(),
            report.plan_hits,
            report.plan_misses
        );
        // native GCN params round-trip as 4 tensors
        assert_eq!(report.params.len(), 4);
        // five-stage accounting: one row per epoch, rows sum to the run
        // totals, and the legacy three-way split stays derivable
        assert_eq!(report.epoch_stages.len(), 2);
        let summed: f64 = report.epoch_stages.iter().map(|s| s.total()).sum();
        assert!((summed - report.stages.total()).abs() < 1e-9);
        assert!(report.stages.total() > 0.0);
        let legacy = report.sample_secs + report.plan_secs + report.step_secs;
        assert!((legacy - report.stages.total()).abs() < 1e-9);
    }

    #[test]
    fn deterministic_under_seed() {
        let (d, x, labels, f) = staged(0.15, 5);
        let cfg = TrainConfig { model: ModelKind::Gcn, steps: 0, lr: 0.05, seed: 11 };
        let scfg = SampleConfig {
            fanouts: vec![Fanout::Uniform(5)],
            batch_size: 48,
            epochs: 1,
            reorder: Reorder::Metis,
            topk: None,
        };
        let run = |seed: u64| {
            let cfg = TrainConfig { seed, ..cfg.clone() };
            let mut backend = SampledBackend::Native { hidden: 8, classes: 7 };
            train_sampled(&mut backend, &d, &x, f, &labels, &cfg, &scfg)
                .unwrap()
                .losses
        };
        assert_eq!(run(11), run(11), "same seed must reproduce the epoch");
        assert_ne!(run(11), run(12), "different seeds must differ");
    }

    #[test]
    fn rejects_bad_configs() {
        let (d, x, labels, f) = staged(0.1, 1);
        let cfg = TrainConfig { model: ModelKind::Gcn, steps: 0, lr: 0.05, seed: 0 };
        let mut backend = SampledBackend::Native { hidden: 8, classes: 4 };
        let bad = SampleConfig { batch_size: 0, ..SampleConfig::default() };
        assert!(train_sampled(&mut backend, &d, &x, f, &labels, &cfg, &bad).is_err());
        let gin = TrainConfig { model: ModelKind::Gin, ..cfg };
        assert!(
            train_sampled(&mut backend, &d, &x, f, &labels, &gin, &SampleConfig::default())
                .is_err(),
            "native backend must reject gin"
        );
        let k0 = SampleConfig { topk: Some(0), ..SampleConfig::default() };
        assert!(
            train_sampled(&mut backend, &d, &x, f, &labels, &cfg, &k0).is_err(),
            "topk 0 must be rejected"
        );
    }

    #[test]
    fn topk_epoch_trains_and_full_width_matches_dense() {
        let (d, x, labels, f) = staged(0.2, 9);
        let cfg = TrainConfig { model: ModelKind::Gcn, steps: 0, lr: 0.1, seed: 5 };
        let hidden = 16;
        let run = |topk: Option<usize>| {
            let scfg = SampleConfig {
                fanouts: vec![Fanout::Uniform(6)],
                batch_size: 64,
                epochs: 1,
                reorder: Reorder::Metis,
                topk,
            };
            let mut backend = SampledBackend::Native { hidden, classes: 7 };
            train_sampled(&mut backend, &d, &x, f, &labels, &cfg, &scfg).unwrap()
        };
        // k = hidden keeps every lane: the whole run (same seed, same
        // sampler stream) must reproduce the dense losses bitwise
        let dense = run(None);
        let full = run(Some(hidden));
        assert_eq!(dense.losses, full.losses, "TopK(k = hidden) must equal dense");
        // a genuinely sparse run still trains to finite losses
        let sparse = run(Some(hidden / 4));
        assert_eq!(sparse.batches, dense.batches);
        assert!(sparse.losses.iter().all(|l| l.is_finite()));
    }
}
