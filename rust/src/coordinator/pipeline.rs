//! End-to-end pipeline (Fig. 7's user flow): load dataset → reorder +
//! decompose → **plan** (pluggable [`Planner`]) → train through PJRT —
//! plus [`Run`], the one builder entrypoint for train / serve / bench.
//!
//! ```no_run
//! # use adaptgear::coordinator::{pipeline::Run, ModelKind};
//! # use adaptgear::plan::{CachedPlanner, MonitorPlanner, PlanStore};
//! # use adaptgear::gpusim::A100;
//! # fn demo(engine: &adaptgear::runtime::Engine,
//! #         spec: &'static adaptgear::graph::datasets::DatasetSpec,
//! #         registry: &mut adaptgear::serve::ModelRegistry) -> anyhow::Result<()> {
//! let _report = Run::new(engine)
//!     .dataset(spec)
//!     .model(ModelKind::Gcn)
//!     .planner(CachedPlanner::new(
//!         PlanStore::in_artifacts(&engine.manifest.dir),
//!         MonitorPlanner::sim(&A100, 3),
//!     ))
//!     .train()?;
//! let _dep = Run::new(engine).dataset(spec).model(ModelKind::Gcn).deploy(registry)?;
//! # Ok(()) }
//! ```

use anyhow::{Context, Result};

use crate::graph::datasets::{Dataset, DatasetSpec};
use crate::gpusim::A100;
use crate::partition::{Decomposition, Propagation};
use crate::plan::{GearPlan, MonitorPlanner, PlanRequest, Planner};
use crate::runtime::{BucketInfo, Engine, Manifest};
use crate::serve::{Deployment, DeploymentSpec, ModelRegistry};

use super::modeldims::ModelKind;
use super::strategy::{preprocess, PreprocessTimes, Strategy};
use super::trainer::{train, TrainConfig, TrainReport};

/// End-to-end run summary.
#[derive(Debug)]
pub struct PipelineReport {
    pub dataset: &'static str,
    pub scale: f64,
    pub vertices: usize,
    pub edges: usize,
    pub preprocess: PreprocessTimes,
    pub train: TrainReport,
}

/// Choose a dataset scale that fits the largest AOT bucket: both vertex
/// count and the per-subgraph edge capacity must fit.
pub fn auto_scale(spec: &DatasetSpec, engine: &Engine) -> f64 {
    let max_v = engine.manifest.buckets.values().map(|b| b.vertices).max().unwrap_or(0);
    let max_e = engine.manifest.buckets.values().map(|b| b.edges).max().unwrap_or(0);
    auto_scale_for(spec, max_v, max_e)
}

/// [`auto_scale`] core, engine-free for testing: `max_v` / `max_e` are
/// the largest bucket's vertex and per-subgraph edge capacities.
pub fn auto_scale_for(spec: &DatasetSpec, max_v: usize, max_e: usize) -> f64 {
    if max_v == 0 {
        return 1.0;
    }
    // GCN-normalized nnz = directed edges + n; leave 15% headroom for the
    // randomness of the generator. With small buckets the vertex term can
    // swallow the whole edge budget and drive the headroom negative, so it
    // is floored at 10% of the bucket's edge capacity — a conservative but
    // sane scale instead of a silent collapse to the 1e-6 floor.
    let v_scale = max_v as f64 / spec.vertices as f64;
    let headroom = (max_e as f64 * 0.85 - max_v as f64 * 0.3).max(max_e as f64 * 0.10);
    let e_scale = headroom / spec.edges as f64;
    v_scale.min(e_scale).min(1.0).max(1e-6)
}

/// Propagation matrix per model (GCN normalizes; GIN aggregates raw).
pub fn propagation_for(model: ModelKind) -> Propagation {
    match model {
        ModelKind::Gcn => Propagation::GcnNormalized,
        ModelKind::Gin => Propagation::PlainAdjacency,
    }
}

/// Everything between "pick a dataset" and "plan kernels": materialized
/// data, its decomposition, the chosen scale, and the fitted AOT bucket.
pub struct Staged {
    pub scale: f64,
    pub data: Dataset,
    pub d: Decomposition,
    pub times: PreprocessTimes,
    pub bucket: BucketInfo,
}

/// Materialize (auto-scaled) + preprocess + fit a bucket against a
/// manifest. The single shared front half of every planning path —
/// [`Run::prepare`], `ModelRegistry::deploy_planned`, and the engine-free
/// `adaptgear plan` subcommand all call this, so they cannot drift apart
/// (identical scale, reorder, and therefore plan fingerprint).
///
/// The fitted bucket also caps hybrid plans: the planner sweep only
/// admits density splits whose merged sparse-class + inter operand fits
/// `bucket.edges`, so every plan staged here is executable as-is.
pub fn stage(
    manifest: &Manifest,
    spec: &DatasetSpec,
    model: ModelKind,
    strategy: Strategy,
    scale_override: Option<f64>,
    seed: u64,
) -> Result<Staged> {
    let max_v = manifest.buckets.values().map(|b| b.vertices).max().unwrap_or(0);
    let max_e = manifest.buckets.values().map(|b| b.edges).max().unwrap_or(0);
    let scale = scale_override.unwrap_or_else(|| auto_scale_for(spec, max_v, max_e));
    let data = spec.build_scaled(scale, seed);
    let (d, times) = preprocess(
        strategy,
        &data.graph,
        propagation_for(model),
        manifest.community,
        seed,
    );
    let needed_edges = d.intra.nnz().max(d.inter.nnz());
    let bucket = manifest
        .fit_bucket(d.graph.n, needed_edges)
        .with_context(|| {
            format!(
                "no AOT bucket fits n={}, edges={needed_edges}; scale the dataset down",
                d.graph.n
            )
        })?
        .clone();
    Ok(Staged { scale, data, d, times, bucket })
}

/// One fluent path from dataset to a trained model or a live deployment —
/// replaces hand-wiring `TrainConfig` + preprocess + select + train (and
/// `DeploymentSpec` plumbing on the serve side).
pub struct Run<'e> {
    engine: &'e Engine,
    spec: Option<&'static DatasetSpec>,
    model: ModelKind,
    strategy: Strategy,
    /// Training budget. Unset falls back to each terminal's documented
    /// default: 100 steps for [`Run::train`], the registry's 60 for
    /// [`Run::deploy`] — so the builder never silently changes what the
    /// equivalent direct `TrainConfig`/`DeploymentSpec` path would do.
    steps: Option<usize>,
    lr: f32,
    seed: u64,
    scale: Option<f64>,
    planner: Option<Box<dyn Planner + 'e>>,
}

impl<'e> Run<'e> {
    pub fn new(engine: &'e Engine) -> Run<'e> {
        Run {
            engine,
            spec: None,
            model: ModelKind::Gcn,
            strategy: Strategy::AdaptGear,
            steps: None,
            lr: 0.05,
            seed: 0,
            scale: None,
            planner: None,
        }
    }

    pub fn dataset(mut self, spec: &'static DatasetSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    pub fn model(mut self, model: ModelKind) -> Self {
        self.model = model;
        self
    }

    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the auto-chosen dataset scale.
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = Some(scale);
        self
    }

    /// Plug in a planner (default: sim-clock [`MonitorPlanner`], the
    /// paper's online feedback loop on the deterministic surface).
    pub fn planner(mut self, planner: impl Planner + 'e) -> Self {
        self.planner = Some(Box::new(planner));
        self
    }

    fn spec(&self) -> Result<&'static DatasetSpec> {
        self.spec.context("Run: no dataset set (call .dataset(spec) first)")
    }

    /// Materialize + preprocess + plan, without training. Returns the
    /// dataset, decomposition, chosen scale, fitted bucket, preprocess
    /// times, and plan.
    pub fn prepare(&mut self) -> Result<Prepared> {
        let spec = self.spec()?;
        let staged = stage(
            &self.engine.manifest,
            spec,
            self.model,
            self.strategy,
            self.scale,
            self.seed,
        )?;
        let req = PlanRequest::labeled(
            &staged.d,
            self.model,
            &staged.bucket,
            spec.name,
            staged.scale,
            self.strategy.reorder(),
            self.seed,
        );
        let plan = match self.planner.as_mut() {
            Some(p) => p.plan(&req)?,
            None => MonitorPlanner::sim(&A100, 3).plan(&req)?,
        };
        let Staged { scale, data, d, times, bucket } = staged;
        Ok(Prepared { scale, data, d, times, bucket, plan })
    }

    /// Train end to end: prepare (plan) then run the PJRT training loop.
    pub fn train(mut self) -> Result<PipelineReport> {
        let spec = self.spec()?;
        let prepared = self.prepare()?;
        let cfg = TrainConfig {
            model: self.model,
            steps: self.steps.unwrap_or(100),
            lr: self.lr,
            seed: self.seed,
        };
        let report =
            train_decomposition(self.engine, &prepared.data, &prepared.d, &cfg, &prepared.plan)?;
        Ok(PipelineReport {
            dataset: spec.name,
            scale: prepared.scale,
            vertices: prepared.data.graph.n,
            edges: prepared.data.graph.directed_edge_count(),
            preprocess: prepared.times,
            train: report,
        })
    }

    /// Deploy into a registry under the default `{dataset}-{model}` name.
    pub fn deploy<'r>(self, registry: &'r mut ModelRegistry) -> Result<&'r Deployment> {
        let spec = self.spec()?;
        let name = format!("{}-{}", spec.name, self.model.as_str());
        self.deploy_as(registry, name)
    }

    /// Deploy into a registry under an explicit name.
    pub fn deploy_as<'r>(
        mut self,
        registry: &'r mut ModelRegistry,
        name: impl Into<String>,
    ) -> Result<&'r Deployment> {
        let spec = self.spec()?;
        let mut dspec = DeploymentSpec::new(name, spec, self.model);
        dspec.strategy = self.strategy;
        if let Some(steps) = self.steps {
            dspec.steps = steps; // otherwise keep the registry's default
        }
        dspec.lr = self.lr;
        dspec.seed = self.seed;
        dspec.scale = self.scale;
        match self.planner.take() {
            Some(mut p) => registry.deploy_planned(self.engine, dspec, p.as_mut()),
            None => registry.deploy(self.engine, dspec),
        }
    }
}

/// Output of [`Run::prepare`]: everything needed to train or explain.
pub struct Prepared {
    pub scale: f64,
    pub data: Dataset,
    pub d: Decomposition,
    pub times: PreprocessTimes,
    pub bucket: BucketInfo,
    pub plan: GearPlan,
}

impl Prepared {
    /// Whether the plan routes the intra diagonal through more than one
    /// density class (hybrid execution).
    pub fn is_hybrid(&self) -> bool {
        self.plan.assignment.is_hybrid()
    }
}

/// Materialize a dataset (auto-scaled), preprocess it the AdaptGear way,
/// plan with the default sim-clock monitor, and train for `cfg.steps`
/// through PJRT. Thin wrapper over [`Run`].
pub fn run(
    engine: &Engine,
    spec: &'static DatasetSpec,
    cfg: &TrainConfig,
    scale_override: Option<f64>,
) -> Result<PipelineReport> {
    let mut r = Run::new(engine)
        .dataset(spec)
        .model(cfg.model)
        .steps(cfg.steps)
        .lr(cfg.lr)
        .seed(cfg.seed);
    if let Some(s) = scale_override {
        r = r.scale(s);
    }
    r.train()
}

/// Train an already-decomposed dataset under `plan` (features/labels
/// re-derived from the ORIGINAL vertex order are permuted to the
/// reordered ids).
pub fn train_decomposition(
    engine: &Engine,
    data: &Dataset,
    d: &Decomposition,
    cfg: &TrainConfig,
    plan: &GearPlan,
) -> Result<TrainReport> {
    let f_data = engine
        .manifest
        .buckets
        .values()
        .map(|b| b.features)
        .max()
        .unwrap_or(32);
    // permute rows into the decomposition's vertex order
    let (x, labels) =
        super::apply_perm(&d.perm, &data.features(f_data), &data.labels(), f_data);
    train(engine, d, &x, f_data, &labels, cfg, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn auto_scale_bounded() {
        // without an engine we can still sanity check the math by hand
        let spec = datasets::find("cora").unwrap();
        // v_scale for a 1024 bucket = 1024/2708 ≈ 0.378
        let v_scale = 1024.0 / spec.vertices as f64;
        assert!(v_scale < 1.0 && v_scale > 0.3);
        let scale = auto_scale_for(spec, 1024, 4096);
        assert!(scale > 0.0 && scale <= v_scale);
    }

    #[test]
    fn auto_scale_small_bucket_does_not_collapse() {
        // Regression: with edge capacity below ~0.35x the vertex capacity
        // the old edge-headroom term went negative and the scale silently
        // collapsed to the 1e-6 floor.
        let spec = datasets::find("cora").unwrap();
        let scale = auto_scale_for(spec, 1024, 256);
        assert!(scale > 1e-4, "scale collapsed to the floor: {scale}");
        // the floored headroom still respects the edge budget: at most 10%
        // of the bucket's capacity worth of directed edges
        let est_edges = spec.edges as f64 * scale;
        assert!(est_edges <= 256.0 * 0.10 + 1.0, "estimated edges {est_edges}");
    }

    #[test]
    fn auto_scale_no_buckets_is_identity() {
        let spec = datasets::find("cora").unwrap();
        assert_eq!(auto_scale_for(spec, 0, 0), 1.0);
    }
}
