//! End-to-end pipeline (Fig. 7's user flow): load dataset → reorder +
//! decompose → adaptive selection → train through PJRT.

use anyhow::Result;

use crate::graph::datasets::{Dataset, DatasetSpec};
use crate::partition::{Decomposition, Propagation};
use crate::runtime::Engine;

use super::modeldims::ModelKind;
use super::strategy::{preprocess, PreprocessTimes, Strategy};
use super::trainer::{train, TrainConfig, TrainReport};

/// End-to-end run summary.
#[derive(Debug)]
pub struct PipelineReport {
    pub dataset: &'static str,
    pub scale: f64,
    pub vertices: usize,
    pub edges: usize,
    pub preprocess: PreprocessTimes,
    pub train: TrainReport,
}

/// Choose a dataset scale that fits the largest AOT bucket: both vertex
/// count and the per-subgraph edge capacity must fit.
pub fn auto_scale(spec: &DatasetSpec, engine: &Engine) -> f64 {
    let max_v = engine.manifest.buckets.values().map(|b| b.vertices).max().unwrap_or(0);
    let max_e = engine.manifest.buckets.values().map(|b| b.edges).max().unwrap_or(0);
    if max_v == 0 {
        return 1.0;
    }
    // GCN-normalized nnz = directed edges + n; leave 15% headroom for
    // the randomness of the generator.
    let v_scale = max_v as f64 / spec.vertices as f64;
    let e_scale = (max_e as f64 * 0.85 - max_v as f64 * 0.3) / spec.edges as f64;
    v_scale.min(e_scale).min(1.0).max(1e-6)
}

/// Propagation matrix per model (GCN normalizes; GIN aggregates raw).
pub fn propagation_for(model: ModelKind) -> Propagation {
    match model {
        ModelKind::Gcn => Propagation::GcnNormalized,
        ModelKind::Gin => Propagation::PlainAdjacency,
    }
}

/// Materialize a dataset (auto-scaled), preprocess it the AdaptGear way,
/// and train for `cfg.steps` through PJRT.
pub fn run(
    engine: &Engine,
    spec: &DatasetSpec,
    cfg: &TrainConfig,
    scale_override: Option<f64>,
) -> Result<PipelineReport> {
    let scale = scale_override.unwrap_or_else(|| auto_scale(spec, engine));
    let data = spec.build_scaled(scale, cfg.seed);
    let (d, times) = preprocess(
        Strategy::AdaptGear,
        &data.graph,
        propagation_for(cfg.model),
        engine.manifest.community,
        cfg.seed,
    );
    let report = train_decomposition(engine, &data, &d, cfg)?;
    Ok(PipelineReport {
        dataset: spec.name,
        scale,
        vertices: data.graph.n,
        edges: data.graph.directed_edge_count(),
        preprocess: times,
        train: report,
    })
}

/// Train an already-decomposed dataset (features/labels re-derived from
/// the ORIGINAL vertex order must be permuted to the reordered ids).
pub fn train_decomposition(
    engine: &Engine,
    data: &Dataset,
    d: &Decomposition,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let f_data = engine
        .manifest
        .buckets
        .values()
        .map(|b| b.features)
        .max()
        .unwrap_or(32);
    // permute rows into the decomposition's vertex order
    let (x, labels) =
        super::apply_perm(&d.perm, &data.features(f_data), &data.labels(), f_data);
    train(engine, d, &x, f_data, &labels, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn auto_scale_bounded() {
        // without an engine we can still sanity check the math by hand
        let spec = datasets::find("cora").unwrap();
        // v_scale for a 1024 bucket = 1024/2708 ≈ 0.378
        let v_scale = 1024.0 / spec.vertices as f64;
        assert!(v_scale < 1.0 && v_scale > 0.3);
    }
}
