//! Train suite: end-to-end trainer metrics on cora and planted-mixed.
//!
//! Two measurement tiers, so the suite emits a gateable report on a bare
//! checkout AND deepens when artifacts exist:
//!
//! * **Engine-free (always)** — preprocessing wall time, a native-kernel
//!   "epoch" (one full aggregate pass over both subgraphs on the CPU
//!   mirrors), and the deterministic projected forward cost of the
//!   planned decision.
//! * **PJRT (artifacts built)** — a short real training run through
//!   [`crate::coordinator::Run`]; mean step time gates, final loss is
//!   recorded informationally.

use anyhow::Result;

use crate::coordinator::{preprocess, ModelKind, Run, Strategy};
use crate::graph::datasets;
use crate::gpusim::A100;
use crate::kernels::native;
use crate::plan::{MonitorPlanner, PlanRequest, Planner, SimCostPlanner};
use crate::runtime::{BucketInfo, Engine};
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

const COMMUNITY: usize = 16;

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("train", cfg.quick);
    let bench = super::measurer(cfg.quick);
    let engine = Engine::new(&cfg.artifacts).ok();
    report.note("engine", if engine.is_some() { "pjrt" } else { "native-only" });

    let target_n = if cfg.quick { 1024 } else { 4096 };
    for name in ["cora", "planted-mixed"] {
        let spec = datasets::find(name).expect("registry dataset");
        let scale = (target_n as f64 / spec.vertices as f64).min(1.0);
        let data = spec.build_scaled(scale, cfg.seed);
        println!(
            "\n-- train/{name}: scale={scale:.4} vertices={} edges={} --",
            data.graph.n,
            data.graph.directed_edge_count()
        );

        // preprocessing (reorder + decompose) under the AdaptGear strategy
        let m = bench.bench(&format!("prep/{name}"), || {
            std::hint::black_box(preprocess(
                Strategy::AdaptGear,
                &data.graph,
                crate::coordinator::pipeline::propagation_for(ModelKind::Gcn),
                COMMUNITY,
                cfg.seed,
            ));
        });
        report.push(format!("prep/{name}"), m.median_s() * 1e3, "ms", Direction::Lower);

        let (d, _) = preprocess(
            Strategy::AdaptGear,
            &data.graph,
            crate::coordinator::pipeline::propagation_for(ModelKind::Gcn),
            COMMUNITY,
            cfg.seed,
        );

        // one native "epoch": the full aggregate over both subgraphs at
        // the bucket width — the CPU-mirror cost a trainer step pays
        let f = 32;
        let mut rng = Rng::new(cfg.seed);
        let x: Vec<f32> = (0..d.graph.n * f).map(|_| rng.normal_f32()).collect();
        let m = bench.bench(&format!("native_epoch/{name}"), || {
            std::hint::black_box(native::csr_intra_spmm(&d.intra, &x, f, COMMUNITY));
            std::hint::black_box(native::csr_inter_spmm(&d.inter, &x, f));
        });
        report.push(format!("native_epoch/{name}"), m.median_s() * 1e3, "ms", Direction::Lower);

        // deterministic planned decision for this dataset at this scale
        let bucket = BucketInfo {
            name: "bench".to_string(),
            vertices: d.graph.n,
            edges: d.intra.nnz().max(d.inter.nnz()),
            features: f,
            hidden: f,
            classes: spec.classes.min(8),
            blocks: d.graph.n.div_ceil(COMMUNITY),
        };
        let req = PlanRequest::labeled(
            &d,
            ModelKind::Gcn,
            &bucket,
            spec.name,
            scale,
            Strategy::AdaptGear.reorder(),
            cfg.seed,
        );
        let plan = SimCostPlanner::new(&A100).plan(&req)?;
        report.push(
            format!("plan/{name}/projected_fwd_us"),
            plan.projected.total_us(),
            "us",
            Direction::Lower,
        );
        report.note(format!("plan.{name}"), plan.chosen.to_string());

        // real PJRT training when the artifacts exist
        if let Some(engine) = engine.as_ref() {
            let steps = if cfg.quick { 5 } else { 25 };
            match Run::new(engine)
                .dataset(spec)
                .model(ModelKind::Gcn)
                .steps(steps)
                .seed(cfg.seed)
                .planner(MonitorPlanner::sim(&A100, 2))
                .train()
            {
                Ok(r) => {
                    report.push(
                        format!("train/{name}/mean_step_ms"),
                        r.train.mean_step_secs() * 1e3,
                        "ms",
                        Direction::Lower,
                    );
                    report.push(
                        format!("train/{name}/pack_ms"),
                        r.train.pack_secs * 1e3,
                        "ms",
                        Direction::Lower,
                    );
                    let loss = r.train.final_loss() as f64;
                    if loss.is_finite() {
                        report.push(
                            format!("train/{name}/final_loss"),
                            loss,
                            "loss",
                            Direction::None,
                        );
                    }
                    println!(
                        "train/{name}: {} steps, mean {:.2}ms/step, final loss {:.4}",
                        steps,
                        r.train.mean_step_secs() * 1e3,
                        r.train.final_loss()
                    );
                }
                Err(e) => {
                    report.note(format!("train.{name}.skipped"), format!("{e:#}"));
                    println!("train/{name}: PJRT run skipped ({e:#})");
                }
            }
        }
    }
    if engine.is_none() {
        println!("train: artifacts not built — PJRT metrics omitted (native + sim tiers only)");
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn quick_run_emits_engine_free_tiers_on_bare_checkout() {
        let cfg = BenchConfig {
            quick: true,
            artifacts: "definitely-not-an-artifacts-dir".to_string(),
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "train");
        assert_eq!(report.context.get("engine").map(String::as_str), Some("native-only"));
        for name in ["cora", "planted-mixed"] {
            assert!(report.get(&format!("prep/{name}")).is_some());
            assert!(report.get(&format!("native_epoch/{name}")).is_some());
            assert!(report.get(&format!("plan/{name}/projected_fwd_us")).is_some());
        }
        // and no PJRT metrics leaked in without an engine
        assert!(report.get("train/cora/mean_step_ms").is_none());
    }
}
