//! Feat suite: feature-dimension sparsity end to end — top-k selection
//! throughput, sparse-vs-dense native aggregation across k/F ratios, and
//! the density-aware cost model's pricing of the same trade (DESIGN.md
//! Sec. 15).
//!
//! Workloads are planted-partition graphs with wide feature matrices
//! compressed to their per-row top-k lanes. Each ratio reports the
//! measured wall-time speedup of the SpGEMM-style sparse aggregation
//! over the dense reference, the deterministic cost-model speedup at the
//! same density, and whether the cost model's intra argmin agrees with
//! the measured ranking. The `f256_k32` row is the acceptance workload:
//! F >= 256 at k = F/8 must price (and measure) sparse cheaper than
//! dense, or the density term in `kernel_cost_density` has drifted.

use anyhow::Result;

use crate::graph::generate::planted_partition;
use crate::graph::{Csr, DenseBlocks};
use crate::gpusim::kernel_cost::CostCtx;
use crate::gpusim::{class_kernel_cost, kernel_cost, kernel_cost_density, ClassDims, A100};
use crate::kernels::native::{dense_block_spmm, sparse_aggregate, SparseFeat};
use crate::kernels::native_model::topk_mask_rows;
use crate::kernels::KernelKind;
use crate::partition::{Decomposition, Propagation, Reorder};
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

/// One k/F ratio workload. The label is part of the suite contract —
/// baselines key on it.
struct Ratio {
    label: &'static str,
    f: usize,
    k: usize,
}

const COMMUNITY: usize = 16;

fn ratios(quick: bool) -> Vec<Ratio> {
    let mut v = vec![
        // Acceptance workload: wide features, k = F/8.
        Ratio { label: "f256_k32", f: 256, k: 32 },
        // Narrow features at the same 1/8 live fraction.
        Ratio { label: "f64_k8", f: 64, k: 8 },
    ];
    if !quick {
        // Mild compression: the regime where the dense engines stay
        // competitive and the argmin is allowed to flip.
        v.push(Ratio { label: "f256_k128", f: 256, k: 128 });
    }
    v
}

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("feat", cfg.quick);
    report.note("engine", "native-only");
    let bench = super::measurer(cfg.quick);

    let n = if cfg.quick { 1024 } else { 4096 };
    // Deterministic workload: the seed is part of the suite contract.
    let mut rng = Rng::new(cfg.seed ^ 0xfea7);
    let g = planted_partition(n, COMMUNITY, 0.25, 16.0 / n as f64, &mut rng);
    let a = Csr::gcn_normalized(&g);
    let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, COMMUNITY, 0);
    let blocks = DenseBlocks::from_block_diagonal_csr(&d.intra, COMMUNITY);
    let profile = d.intra_block_profile();
    let intra_rows: usize = profile.blocks.iter().map(|&(r, _)| r).sum();
    report.note(
        "workload",
        format!("n={n} nnz={} intra_nnz={} inter_nnz={}", a.nnz(), d.intra.nnz(), d.inter.nnz()),
    );

    for r in ratios(cfg.quick) {
        let rho = r.k as f64 / r.f as f64;
        let x: Vec<f32> = (0..n * r.f).map(|_| rng.normal_f32()).collect();
        println!("\n-- feat/{}: n={n} f={} k={} rho={rho:.4} --", r.label, r.f, r.k);

        // ---- top-k selection throughput (the fused activation's cost)
        let m = bench.bench(&format!("select/from_dense/{}", r.label), || {
            std::hint::black_box(SparseFeat::from_dense(&x, n, r.f, r.k));
        });
        report.push(
            format!("select/from_dense/{}", r.label),
            n as f64 / m.median_s().max(1e-12),
            "rows/s",
            Direction::Higher,
        );
        let m = bench.bench(&format!("select/mask_rows/{}", r.label), || {
            let mut h = x.clone();
            topk_mask_rows(&mut h, r.f, r.k);
            std::hint::black_box(h);
        });
        report.push(
            format!("select/mask_rows/{}", r.label),
            n as f64 / m.median_s().max(1e-12),
            "rows/s",
            Direction::Higher,
        );

        // ---- sparse vs dense native aggregation on the full adjacency
        let sf = SparseFeat::from_dense(&x, n, r.f, r.k);
        let m = bench.bench(&format!("agg/sparse/{}", r.label), || {
            std::hint::black_box(sparse_aggregate(&a, &sf));
        });
        let sparse_us = m.median_s() * 1e6;
        report.push(format!("agg/sparse/{}", r.label), sparse_us, "us", Direction::Lower);
        let m = bench.bench(&format!("agg/dense/{}", r.label), || {
            std::hint::black_box(a.spmm(&x, r.f));
        });
        let dense_us = m.median_s() * 1e6;
        report.push(format!("agg/dense/{}", r.label), dense_us, "us", Direction::Lower);
        let speedup = dense_us / sparse_us.max(1e-9);
        report.push(format!("agg/speedup/{}", r.label), speedup, "x", Direction::Higher);
        println!("feat: {} measured sparse-vs-dense speedup {speedup:.2}x", r.label);

        // ---- cost-model pricing of the same trade (deterministic)
        let sim_sparse =
            kernel_cost_density(KernelKind::CsrInter, &a, r.f, COMMUNITY, &A100, rho).time_us;
        let sim_dense = kernel_cost(KernelKind::CsrInter, &a, r.f, COMMUNITY, &A100).time_us;
        report.push(
            format!("cost/speedup/{}", r.label),
            sim_dense / sim_sparse.max(1e-9),
            "x",
            Direction::Higher,
        );

        // ---- argmin agreement: does the density-aware model rank the
        // sparse-feature CSR schedule against the lane-oblivious dense
        // engine the same way the measured times do?
        let m = bench.bench(&format!("agg/intra_sparse/{}", r.label), || {
            std::hint::black_box(sparse_aggregate(&d.intra, &sf));
        });
        let meas_sparse_us = m.median_s() * 1e6;
        let m = bench.bench(&format!("agg/intra_dense_block/{}", r.label), || {
            std::hint::black_box(dense_block_spmm(&blocks, &x, r.f));
        });
        let meas_dense_us = m.median_s() * 1e6;
        let sim = |kind: KernelKind, density: f64| -> f64 {
            let dims =
                ClassDims { kind, blocks: profile.len(), rows: intra_rows, nnz: d.intra.nnz() };
            let ctx = CostCtx::new(dims, r.f, d.community, &A100).with_feat_density(density);
            class_kernel_cost(&ctx).time_us
        };
        let sim_csr = sim(KernelKind::CsrIntra, rho);
        let sim_blk = sim(KernelKind::DenseBlock, rho);
        let agree = (sim_csr < sim_blk) == (meas_sparse_us < meas_dense_us);
        report.push(
            format!("cost/argmin_agree/{}", r.label),
            if agree { 1.0 } else { 0.0 },
            "bool",
            Direction::None,
        );
        if !agree {
            println!(
                "feat: {} ARGMIN DISAGREES — sim prices csr_intra {sim_csr:.1}us vs \
                 dense_block {sim_blk:.1}us, measurement says {meas_sparse_us:.1}us vs \
                 {meas_dense_us:.1}us",
                r.label
            );
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// One full quick run emits a schema-valid report covering every
    /// k/F ratio, and the acceptance workload (F=256, k=F/8) shows both
    /// the measured aggregation and the cost model pricing sparse
    /// features cheaper than dense.
    #[test]
    fn quick_run_prices_wide_sparse_features_cheaper() {
        let cfg = BenchConfig {
            quick: true,
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "feat");
        for label in ["f256_k32", "f64_k8"] {
            for metric in [
                "select/from_dense",
                "select/mask_rows",
                "agg/sparse",
                "agg/dense",
                "agg/speedup",
                "cost/speedup",
                "cost/argmin_agree",
            ] {
                assert!(
                    report.get(&format!("{metric}/{label}")).is_some(),
                    "missing metric {metric}/{label}"
                );
            }
            let agree = report.get(&format!("cost/argmin_agree/{label}")).unwrap();
            assert!(agree.value == 0.0 || agree.value == 1.0);
        }
        // Acceptance bar: at F=256, k=F/8 the sparse path must win on
        // both axes — measured wall time and simulated cost.
        let meas = report.get("agg/speedup/f256_k32").unwrap().value;
        assert!(meas > 1.0, "measured sparse aggregation speedup {meas} <= 1 at k=F/8");
        let sim = report.get("cost/speedup/f256_k32").unwrap().value;
        assert!(sim > 1.0, "cost model prices sparse features no cheaper than dense: {sim}");
        // strict decode of its own serialization
        let text = crate::util::json::write(&report.to_json());
        let back = BenchReport::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, report);
    }
}
