//! Serve suite: closed-loop loadgen against the micro-batched serving
//! runtime at max-batch 1 (no coalescing) and max-batch 16 — throughput,
//! tail latency, and the batching win.
//!
//! Needs built artifacts (a PJRT engine); on a bare checkout it emits a
//! schema-valid report with zero metrics and a `skipped` context note, so
//! `bench --validate` still passes and the comparator simply has nothing
//! to gate until a machine with artifacts records a baseline.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::ModelKind;
use crate::graph::datasets;
use crate::runtime::Engine;
use crate::serve::{
    loadgen, DeploymentSpec, LoadGenConfig, ModelRegistry, ServeConfig, ServeSession, SloReport,
    Stage,
};

use super::report::{BenchReport, Direction};
use super::BenchConfig;

fn serve_once(
    engine: &Engine,
    registry: &mut ModelRegistry,
    deployment: &str,
    n: usize,
    f_data: usize,
    max_batch: usize,
    requests: usize,
) -> Result<SloReport> {
    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
    };
    let load = LoadGenConfig { requests, clients: 32, ..Default::default() };
    let (session, client) = ServeSession::new(engine, registry, cfg);
    let gen = loadgen::spawn(client, deployment.to_string(), n, f_data, load);
    let report = session.run()?;
    gen.join();
    Ok(report)
}

fn push_slo(report: &mut BenchReport, tag: &str, r: &SloReport) {
    report.push(format!("serve/{tag}/throughput_rps"), r.throughput_rps, "rps", Direction::Higher);
    report.push(format!("serve/{tag}/p50_ms"), r.p50_ms, "ms", Direction::Lower);
    report.push(format!("serve/{tag}/p99_ms"), r.p99_ms, "ms", Direction::Lower);
    report.push(format!("serve/{tag}/mean_occupancy"), r.mean_occupancy, "reqs", Direction::Higher);
    report.push(format!("serve/{tag}/shed_rate"), r.shed_rate, "frac", Direction::Lower);
    report.push(
        format!("serve/{tag}/forward_calls"),
        r.forward_calls as f64,
        "calls",
        Direction::None,
    );
    // Four-way stage split: where the latency went, not just how big
    // it was. Informational — stage shares shift with batching config.
    for stage in Stage::ALL {
        report.push(
            format!("serve/{tag}/stage_{}_p50_ms", stage.name()),
            r.stage(stage).p50_ms,
            "ms",
            Direction::None,
        );
    }
}

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("serve", cfg.quick);
    let engine = match Engine::new(&cfg.artifacts) {
        Ok(e) => e,
        Err(e) => {
            report.note("skipped", format!("artifacts not available: {e:#}"));
            println!("serve: skipping (artifacts not built — run `make artifacts`)");
            return Ok(report);
        }
    };

    let requests = if cfg.quick { 120 } else { 400 };
    let spec = datasets::find("citeseer").expect("registry dataset");
    let mut registry = ModelRegistry::new();
    let mut dspec = DeploymentSpec::new("bench", spec, ModelKind::Gcn);
    dspec.steps = if cfg.quick { 20 } else { 40 };
    let dep = registry.deploy(&engine, dspec)?;
    let (n, f_data) = (dep.n, dep.f_data);
    println!(
        "serve: deployed {} on {} ({} vertices, kernels {})",
        dep.model.as_str(),
        spec.name,
        n,
        dep.chosen()
    );
    report.note("dataset", spec.name);
    report.note("requests", requests.to_string());

    let unbatched = serve_once(&engine, &mut registry, "bench", n, f_data, 1, requests)?;
    println!("\n-- max-batch 1 (no coalescing) --\n{}", unbatched.render());
    let batched = serve_once(&engine, &mut registry, "bench", n, f_data, 16, requests)?;
    println!("\n-- max-batch 16 --\n{}", batched.render());

    push_slo(&mut report, "mb1", &unbatched);
    push_slo(&mut report, "mb16", &batched);
    if unbatched.throughput_rps > 0.0 {
        let speedup = batched.throughput_rps / unbatched.throughput_rps;
        report.push("serve/batching_speedup", speedup, "x", Direction::Higher);
        println!(
            "batching speedup {speedup:.2}x ({:.1} -> {:.1} req/s, {} -> {} forwards)",
            unbatched.throughput_rps,
            batched.throughput_rps,
            unbatched.forward_calls,
            batched.forward_calls
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn bare_checkout_emits_schema_valid_skip_report() {
        let cfg = BenchConfig {
            quick: true,
            artifacts: "definitely-not-an-artifacts-dir".to_string(),
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "serve");
        assert!(report.metrics.is_empty());
        assert!(report.context.contains_key("skipped"));
        let text = crate::util::json::write(&report.to_json());
        assert!(BenchReport::from_json(&crate::util::json::parse(&text).unwrap()).is_ok());
    }
}
