//! Plan suite: partitioner speed/quality, planner sweep latency, the
//! hybrid threshold sweep, and PlanStore save/hit latency.
//!
//! Everything here is engine-free (gpusim surface + on-disk store), so
//! the suite gates on a bare checkout. Alongside the wall-clock numbers
//! it records the *deterministic* decision surface — projected forward
//! cost and assignment cost of the chosen plan — which is noise-free and
//! therefore the tightest regression gate in the whole bench subsystem:
//! any cost-model or planner change moves these digits.

use anyhow::Result;

use crate::coordinator::ModelKind;
use crate::graph::generate::{planted_partition, planted_partition_mixed};
use crate::graph::stats;
use crate::gpusim::A100;
use crate::partition::{metis_order, quality, rabbit_order, Decomposition, Propagation, Reorder};
use crate::plan::{
    hybrid, CachedPlanner, MonitorPlanner, PlanRequest, PlanStore, Planner, SimCostPlanner,
};
use crate::runtime::BucketInfo;
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

const COMMUNITY: usize = 16;

fn bucket_for(d: &Decomposition) -> BucketInfo {
    BucketInfo {
        name: "bench".to_string(),
        vertices: d.graph.n,
        edges: d.intra.nnz().max(d.inter.nnz()),
        features: 32,
        hidden: 32,
        classes: 8,
        blocks: d.graph.n.div_ceil(COMMUNITY),
    }
}

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("plan", cfg.quick);
    let bench = super::measurer(cfg.quick);
    let n = if cfg.quick { 2048 } else { 16384 };

    // ---- partitioners: speed and ordering quality on a hidden-community
    // planted graph (the preprocessing half of the Sec. 6.3 overheads)
    let mut rng = Rng::new(cfg.seed ^ 0x9a57);
    let g = planted_partition(n, COMMUNITY, 0.45, 2.0 / n as f64, &mut rng);
    let mut shuffle: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut shuffle);
    let hidden = g.relabel(&shuffle);
    report.note("partition.workload", format!("n={n} edges={}", hidden.directed_edge_count()));
    println!("\n-- plan: partitioners on n={n} --");

    let m = bench.bench("partition/metis_order", || {
        std::hint::black_box(metis_order(&hidden, COMMUNITY, 1));
    });
    report.push("partition/metis_order", m.median_s() * 1e6, "us", Direction::Lower);
    let m = bench.bench("partition/rabbit_order", || {
        std::hint::black_box(rabbit_order(&hidden, COMMUNITY));
    });
    report.push("partition/rabbit_order", m.median_s() * 1e6, "us", Direction::Lower);

    // ordering quality is deterministic — exact regression gates
    for (name, perm) in [
        ("metis", metis_order(&hidden, COMMUNITY, 1)),
        ("rabbit", rabbit_order(&hidden, COMMUNITY)),
    ] {
        let reordered = hidden.relabel(&perm);
        let split = stats::density_split(&reordered, COMMUNITY);
        let parts = quality::parts_from_order(&perm, COMMUNITY);
        let intra_frac = split.intra_edges as f64 / hidden.edge_count().max(1) as f64;
        report.push(
            format!("partition/{name}/intra_frac"),
            intra_frac,
            "frac",
            Direction::Higher,
        );
        report.push(
            format!("partition/{name}/modularity"),
            quality::modularity(&hidden, &parts),
            "q",
            Direction::Higher,
        );
        println!("   quality/{name}: intra_frac={intra_frac:.3}");
    }

    // ---- planner latency over the decomposed graph
    let d = Decomposition::build(&hidden, Reorder::Metis, Propagation::GcnNormalized, COMMUNITY, 1);
    let bucket = bucket_for(&d);
    let req = PlanRequest::new(&d, ModelKind::Gcn, &bucket);

    let m = bench.bench("planner/simcost", || {
        std::hint::black_box(SimCostPlanner::new(&A100).plan(&req).unwrap());
    });
    report.push("planner/simcost", m.median_s() * 1e6, "us", Direction::Lower);

    let mut monitor = MonitorPlanner::sim(&A100, 3);
    let m = bench.bench("planner/monitor_sim", || {
        std::hint::black_box(monitor.plan(&req).unwrap());
    });
    report.push("planner/monitor_sim", m.median_s() * 1e6, "us", Direction::Lower);

    // ---- hybrid threshold sweep on a mixed-density diagonal
    let n_mixed = if cfg.quick { 4096 } else { 32768 };
    let mut rng = Rng::new(cfg.seed ^ 0x4217);
    let gm =
        planted_partition_mixed(n_mixed, COMMUNITY, 0.9, 0.01, 3, 0.3 / n_mixed as f64, &mut rng);
    let dm = Decomposition::build(&gm, Reorder::Identity, Propagation::GcnNormalized, COMMUNITY, 0);
    let profile = dm.intra_block_profile();
    let tile_cap = crate::kernels::tile::tile_capacity(profile.len(), COMMUNITY);
    let m = bench.bench("planner/hybrid_sweep", || {
        std::hint::black_box(hybrid::sweep(
            &profile,
            &dm.inter,
            &[32, 32],
            usize::MAX,
            tile_cap,
            &A100,
        ));
    });
    report.push("planner/hybrid_sweep", m.median_s() * 1e6, "us", Direction::Lower);

    // ---- plan store: save, on-disk hit, and warm cached-planner plan
    let plan = SimCostPlanner::new(&A100).plan(&req)?;
    let store_dir =
        std::env::temp_dir().join(format!("adaptgear-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = PlanStore::new(&store_dir);
    let m = bench.bench("store/save", || {
        store.save(&plan).unwrap();
    });
    report.push("store/save", m.median_s() * 1e6, "us", Direction::Lower);
    let fp = plan.fingerprint;
    let m = bench.bench("store/hit", || {
        std::hint::black_box(store.load(fp).unwrap());
    });
    report.push("store/hit", m.median_s() * 1e6, "us", Direction::Lower);

    let mut cached = CachedPlanner::new(store.clone(), MonitorPlanner::sim(&A100, 3));
    cached.plan(&req)?; // warm
    let m = bench.bench("planner/cached_warm", || {
        std::hint::black_box(cached.plan(&req).unwrap());
    });
    report.push("planner/cached_warm", m.median_s() * 1e6, "us", Direction::Lower);
    let _ = std::fs::remove_dir_all(&store_dir);

    // ---- observability overhead: a span guard with no subscriber
    // installed must stay near-free (one relaxed atomic load, no
    // allocation). Gated so instrumentation creep shows up here first.
    let m = bench.bench("obs/span_disabled", || {
        std::hint::black_box(crate::obs::span("bench.probe.disabled"));
    });
    report.push("obs/span_disabled_ns", m.median_s() * 1e9, "ns", Direction::Lower);
    report.note("obs.span_subscriber", crate::obs::enabled().to_string());

    // ---- deterministic decision surface (noise-free gates)
    report.push(
        "plan/projected_fwd_us",
        plan.projected.total_us(),
        "us",
        Direction::Lower,
    );
    report.push(
        "plan/assignment_cost_us",
        plan.assignment.total_cost_us(),
        "us",
        Direction::Lower,
    );
    report.note("plan.chosen", plan.chosen.to_string());
    println!(
        "plan: chosen {} | projected {:.1}us/fwd (deterministic)",
        plan.chosen,
        plan.projected.total_us()
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn quick_run_is_schema_valid_and_deterministic_where_promised() {
        let cfg = BenchConfig { quick: true, out: PathBuf::from("."), ..Default::default() };
        let a = run(&cfg).unwrap();
        assert_eq!(a.suite, "plan");
        for name in [
            "partition/metis_order",
            "partition/metis/intra_frac",
            "planner/simcost",
            "planner/hybrid_sweep",
            "store/hit",
            "planner/cached_warm",
            "obs/span_disabled_ns",
            "plan/projected_fwd_us",
        ] {
            assert!(a.get(name).is_some(), "missing metric {name}");
        }
        // the decision-surface metrics are bit-deterministic across runs
        let b = run(&cfg).unwrap();
        for name in
            ["plan/projected_fwd_us", "plan/assignment_cost_us", "partition/metis/intra_frac"]
        {
            assert_eq!(a.get(name).unwrap().value, b.get(name).unwrap().value, "{name} drifted");
        }
    }
}
