//! Deterministic benchmark subsystem — the measurement backbone every
//! perf PR gates on (DESIGN.md Sec. 9).
//!
//! Seven fixed-workload suites emit schema-versioned `BENCH_*.json`
//! reports through one writer ([`report::BenchReport`]):
//!
//! | suite     | covers                                                |
//! |-----------|-------------------------------------------------------|
//! | `kernels` | per-kernel spmm + pack across density classes, plus   |
//! |           | the gpusim calibration cross-check                    |
//! | `plan`    | partitioner speed/quality, planner sweep, PlanStore   |
//! |           | hit latency, deterministic decision costs             |
//! | `train`   | preprocess + native epoch + projected cost; real PJRT |
//! |           | steps when artifacts exist                            |
//! | `serve`   | loadgen p50/p99/throughput at max-batch 1 and 16      |
//! | `sample`  | sampler throughput, amortized per-batch plan-cache    |
//! |           | hit rate, sampled vs full-graph epoch cost            |
//! | `stream`  | delta-apply throughput, overlay read overhead, drift- |
//! |           | triggered replan rate, live plan-swap latency         |
//! | `feat`    | top-k select throughput, sparse-vs-dense aggregation  |
//! |           | across k/F ratios, density-aware cost-model agreement |
//!
//! The `adaptgear bench` subcommand runs them; `bench --check --baseline
//! <dir>` diffs fresh reports against committed baselines with
//! [`compare`] and exits non-zero on regression; `bench --validate`
//! schema-checks emitted files. The targets under `rust/benches/` are
//! thin wrappers over these suites, so `cargo bench` and CI gate on the
//! same numbers.
//!
//! Workloads are seeded and fixed per suite: rerunning a suite on the
//! same machine re-times the *identical* computation. `--quick` swaps in
//! the reduced profile (smaller graphs, shorter sampling budgets) used
//! by `./ci.sh bench`; quick and full reports are flagged when compared
//! against each other.

pub mod compare;
pub mod feat;
pub mod kernels;
pub mod plan;
pub mod report;
pub mod sample;
pub mod serve;
pub mod stream;
pub mod train;

use std::path::PathBuf;

use anyhow::{bail, Result};

pub use compare::{check_dirs, compare, validate_dir, CheckOutcome, Comparison, Tolerance, Verdict};
pub use report::{BenchReport, Direction, Metric, SCHEMA_VERSION};

use crate::util::bench::Bench;

/// The suites `bench` runs (and `--validate`/`--check` expect) by default.
pub const SUITES: [&str; 7] = ["kernels", "plan", "train", "serve", "sample", "stream", "feat"];

/// Shared knobs for one suite invocation.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Reduced workload + sampling profile (CI mode).
    pub quick: bool,
    /// Artifacts directory for the PJRT-backed tiers (train/serve).
    pub artifacts: String,
    /// Where `BENCH_*.json` files are written.
    pub out: PathBuf,
    /// Workload seed — part of the suite contract; change it and every
    /// baseline must be re-recorded.
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            quick: false,
            artifacts: "artifacts".to_string(),
            out: PathBuf::from("."),
            seed: 7,
        }
    }
}

/// The measurement profile suites sample with.
pub(crate) fn measurer(quick: bool) -> Bench {
    if quick {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Run one suite by name. Every report carries an observability
/// snapshot in its context (`obs.counters`): what the plan caches,
/// sampler, and batcher did while the suite ran.
pub fn run_suite(name: &str, cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = match name {
        "kernels" => kernels::run(cfg),
        "plan" => plan::run(cfg),
        "train" => train::run(cfg),
        "serve" => serve::run(cfg),
        "sample" => sample::run(cfg),
        "stream" => stream::run(cfg),
        "feat" => feat::run(cfg),
        other => bail!("unknown bench suite {other:?} (expected one of {SUITES:?})"),
    }?;
    let counters = crate::obs::snapshot().counters_line();
    if !counters.is_empty() {
        report.note("obs.counters", counters);
    }
    Ok(report)
}

/// Run `names` (or every suite when empty) and write each report into
/// `cfg.out`; returns the written paths.
pub fn run_and_write(names: &[&str], cfg: &BenchConfig) -> Result<Vec<PathBuf>> {
    let names: Vec<&str> = if names.is_empty() { SUITES.to_vec() } else { names.to_vec() };
    let mut paths = Vec::new();
    for name in names {
        let report = run_suite(name, cfg)?;
        let path = report.write_at(&cfg.out)?;
        println!("wrote {}", path.display());
        paths.push(path);
    }
    Ok(paths)
}
