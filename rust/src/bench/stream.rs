//! Stream suite: delta-apply throughput, overlay read overhead against
//! the frozen CSR, drift-triggered replan rate, and live plan-swap
//! latency — all engine-free (native kernels + the cost simulator), so
//! the suite gates on a bare checkout.
//!
//! Fixed-seed workload: `planted-mixed` scaled to the profile's target
//! size, then rounds of block densification plus random edge churn
//! through a [`crate::stream::StreamSession`], re-planning whenever the
//! drift tracker fires. The acceptance bar — the workload must trigger
//! at least one replan and the swapped plan's forward must match the
//! whole-graph reference within 1e-4 — is enforced by this module's
//! unit test, so tier-1 fails if streaming replans regress.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{preprocess, ModelKind, Strategy};
use crate::graph::datasets;
use crate::gpusim::A100;
use crate::kernels::native::aggregate_assignment;
use crate::plan::{PlanRequest, Planner, SimCostPlanner};
use crate::runtime::BucketInfo;
use crate::serve::{Deployment, PlanSwap};
use crate::stream::{CsrOverlay, DeltaLog, DeltaOp, Replanned, StreamConfig, StreamSession};
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

const COMMUNITY: usize = 16;

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("stream", cfg.quick);
    report.note("engine", "native-only");
    let bench = super::measurer(cfg.quick);

    let target_n = if cfg.quick { 1024 } else { 4096 };
    let rounds = if cfg.quick { 4 } else { 8 };
    let churn = if cfg.quick { 64 } else { 256 };
    let spec = datasets::find("planted-mixed").expect("registry dataset");
    let scale = (target_n as f64 / spec.vertices as f64).min(1.0);
    let data = spec.build_scaled(scale, cfg.seed);
    let (d, _) = preprocess(
        Strategy::AdaptGear,
        &data.graph,
        crate::coordinator::pipeline::propagation_for(ModelKind::Gcn),
        COMMUNITY,
        cfg.seed,
    );
    let n = d.graph.n;
    let nnz = d.intra.nnz() + d.inter.nnz();
    println!("\n-- stream/planted-mixed: scale={scale:.4} vertices={n} edges={nnz} rounds={rounds} --");
    let bucket = BucketInfo {
        name: "stream-bench".to_string(),
        vertices: n,
        edges: nnz + rounds * COMMUNITY * COMMUNITY + 64,
        features: 16,
        hidden: 16,
        classes: 4,
        blocks: n.div_ceil(COMMUNITY),
    };
    let plan = SimCostPlanner::new(&A100)
        .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))?;

    // ---- overlay read overhead vs the frozen CSR: stage ~1/8 of the
    // rows copy-on-write via reweights (same structure, same flops — the
    // measured delta is purely the staged-row indirection)
    let f = 16;
    let base = d.whole();
    let mut overlay = CsrOverlay::new(base.clone());
    let mut log = DeltaLog::new();
    for (r, c, w) in base.to_triplets().into_iter().step_by(8).take(n / 8) {
        overlay.apply(&log.append(DeltaOp::Reweight { u: r, v: c, w }))?;
    }
    let x: Vec<f32> = vec![0.5; n * f];
    let m_base = bench.bench("stream/base_spmm", || {
        std::hint::black_box(base.spmm(&x, f));
    });
    let m_overlay = bench.bench("stream/overlay_spmm", || {
        std::hint::black_box(overlay.spmm(&x, f));
    });
    let overhead = m_overlay.median_s() / m_base.median_s().max(1e-12);
    report.push("overlay/read_overhead", overhead, "x", Direction::Lower);
    report.note("overlay.staged", format!("{} of {} rows", overlay.staged_rows(), n));

    // ---- mutation workload: rounds of one-block densification + random
    // churn, re-planning whenever the tracker reports drift
    let mut session = StreamSession::new(
        &d,
        plan.clone(),
        bucket.clone(),
        StreamConfig::new(ModelKind::Gcn, &A100),
    );
    let mut rng = Rng::new(cfg.seed ^ 0x57e4);
    let n_blocks = n / COMMUNITY;
    let mut total_deltas = 0usize;
    let mut replans = 0usize;
    let mut apply_secs = 0.0f64;
    let mut last: Option<Replanned> = None;
    for round in 0..rounds {
        let lo = (((round * 3 + 1) % n_blocks) * COMMUNITY) as u32;
        let t0 = Instant::now();
        for u in lo..lo + COMMUNITY as u32 {
            for v in (u + 1)..lo + COMMUNITY as u32 {
                session.apply(DeltaOp::InsertEdge { u, v, w: 0.3 })?;
                total_deltas += 1;
            }
        }
        for _ in 0..churn {
            let (u, v) = (rng.below(n as u64) as u32, rng.below(n as u64) as u32);
            session.apply(DeltaOp::DeleteEdge { u, v })?;
            total_deltas += 1;
        }
        apply_secs += t0.elapsed().as_secs_f64();
        if let Some(r) = session.maybe_replan()? {
            replans += 1;
            last = Some(r);
        }
    }
    report.push(
        "delta/apply_per_s",
        total_deltas as f64 / apply_secs.max(1e-12),
        "deltas/s",
        Direction::Higher,
    );
    report.push(
        "replan/per_10k_deltas",
        replans as f64 * 10_000.0 / total_deltas.max(1) as f64,
        "replans",
        Direction::None,
    );
    println!(
        "stream: {total_deltas} deltas over {rounds} rounds -> {replans} replans, \
         graph version {}",
        session.graph_version()
    );
    let r = last.context("mutation workload triggered no replan")?;

    // ---- swapped-plan forward vs the whole-graph reference
    let xs: Vec<f32> = (0..r.d.graph.n * f).map(|_| 0.25).collect();
    let swapped = aggregate_assignment(&r.d, &r.plan.assignment, &xs, f)?;
    let whole = r.d.whole().spmm(&xs, f);
    let max_err = swapped
        .iter()
        .zip(&whole)
        .map(|(p, q)| (p - q).abs() as f64)
        .fold(0.0f64, f64::max);
    report.push("replan/forward_max_err", max_err, "abs", Direction::Lower);

    // ---- live swap latency: install the replanned graph into a
    // registry-shaped deployment (validation + state swap, the exact
    // work the serve event loop does at its linearization point)
    let f_data = 8;
    let mut dep = Deployment {
        name: "stream-bench".to_string(),
        model: ModelKind::Gcn,
        strategy: Strategy::AdaptGear,
        d: d.clone(),
        x: vec![0.0; n * f_data],
        labels: vec![0; n],
        f_data,
        n,
        plan,
        params: Vec::new(),
        fwd_name: "fwd_native".to_string(),
        fwd_bucket: bucket.clone(),
        graph_ops: Vec::new(),
        bucket_vertices: n,
        classes: 4,
        final_loss: 0.0,
        warm_secs: 0.0,
    };
    let added = r.d.graph.n - n;
    let swap = PlanSwap {
        plan: r.plan.clone(),
        d: r.d.clone(),
        graph_ops: Vec::new(),
        fwd_name: "fwd_native".to_string(),
        fwd_bucket: bucket,
        new_rows: vec![0.0; added * f_data],
        new_labels: vec![0; added],
    };
    let t0 = Instant::now();
    dep.apply_swap(swap)?;
    let swap_us = t0.elapsed().as_secs_f64() * 1e6;
    report.push("swap/latency_us", swap_us, "us", Direction::Lower);
    println!(
        "stream: swap installed {} in {swap_us:.0}us, forward max err {max_err:.2e}",
        dep.plan.fingerprint
    );

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn quick_suite_replans_and_stays_numerically_faithful() {
        let cfg = BenchConfig {
            quick: true,
            artifacts: "definitely-not-an-artifacts-dir".to_string(),
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "stream");
        for name in [
            "overlay/read_overhead",
            "delta/apply_per_s",
            "replan/per_10k_deltas",
            "replan/forward_max_err",
            "swap/latency_us",
        ] {
            assert!(report.get(name).is_some(), "missing metric {name}");
        }
        // THE acceptance bars: the workload must actually trigger online
        // replans, and the swapped plan must stay numerically faithful.
        let replans = report.get("replan/per_10k_deltas").unwrap().value;
        assert!(replans > 0.0, "workload must trigger at least one replan");
        let err = report.get("replan/forward_max_err").unwrap().value;
        assert!(err < 1e-4, "swapped plan diverged: max err {err:.2e}");
    }
}
