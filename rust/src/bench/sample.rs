//! Sample suite: sampler throughput, amortized plan-cache hit rate, and
//! sampled-vs-full epoch cost — all engine-free (native schedules), so
//! the suite gates on a bare checkout.
//!
//! Fixed-seed workload: `planted-mixed` scaled to the profile's target
//! size, fanout 10,10, two epochs of batches. The headline metric is
//! `plan_cache/hit_rate_after_epoch1` — the fraction of epoch-2 batches
//! served from the profile-keyed [`crate::plan::BatchPlanner`] without
//! re-running the threshold sweep; the acceptance bar (> 0.5) is
//! enforced by this module's unit test, so tier-1 fails if amortization
//! regresses.

use anyhow::Result;

use crate::coordinator::{preprocess, ModelKind, Strategy};
use crate::graph::datasets;
use crate::gpusim::A100;
use crate::kernels::{native, AssignmentExec};
use crate::plan::{BatchPlanner, PlanRequest, Planner, SimCostPlanner};
use crate::runtime::BucketInfo;
use crate::sample::{Fanout, NeighborSampler};
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

const COMMUNITY: usize = 16;

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("sample", cfg.quick);
    report.note("engine", "native-only");
    let bench = super::measurer(cfg.quick);

    let target_n = if cfg.quick { 1024 } else { 4096 };
    let batch_size = if cfg.quick { 128 } else { 256 };
    let spec = datasets::find("planted-mixed").expect("registry dataset");
    let scale = (target_n as f64 / spec.vertices as f64).min(1.0);
    let data = spec.build_scaled(scale, cfg.seed);
    let (d, _) = preprocess(
        Strategy::AdaptGear,
        &data.graph,
        crate::coordinator::pipeline::propagation_for(ModelKind::Gcn),
        COMMUNITY,
        cfg.seed,
    );
    let n = d.graph.n;
    println!(
        "\n-- sample/planted-mixed: scale={scale:.4} vertices={n} edges={} batch={batch_size} --",
        data.graph.directed_edge_count()
    );
    let prop = d.whole();
    let fanouts = vec![Fanout::Uniform(10), Fanout::Uniform(10)];
    let sampler = NeighborSampler::new(&prop, fanouts)?;

    // ---- sampler throughput on one fixed batch
    let targets: Vec<u32> = (0..batch_size.min(n) as u32).collect();
    let reference = sampler.sample(&targets, &mut Rng::new(cfg.seed));
    let m = bench.bench("sample/batch", || {
        std::hint::black_box(sampler.sample(&targets, &mut Rng::new(cfg.seed)));
    });
    report.push("sampler/batch_ms", m.median_s() * 1e3, "ms", Direction::Lower);
    let edges_per_s = reference.nnz() as f64 / m.median_s().max(1e-12);
    report.push("sampler/edges_per_s", edges_per_s, "edges/s", Direction::Higher);
    report.note(
        "batch.shape",
        format!("{} nodes, {} nnz", reference.n(), reference.nnz()),
    );

    // ---- two epochs of sample -> decompose -> amortized plan
    let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
    let mut rng = Rng::new(cfg.seed ^ 0xba7c);
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut plan_us_epoch2 = Vec::new();
    let mut sampled_agg_s = 0.0f64;
    let f = 32;
    let mut hits_before_epoch2 = 0;
    let mut plans_before_epoch2 = 0;
    for epoch in 0..2 {
        rng.shuffle(&mut order);
        if epoch == 1 {
            hits_before_epoch2 = planner.hits();
            plans_before_epoch2 = planner.hits() + planner.misses();
        }
        for chunk in order.chunks(batch_size) {
            let batch = sampler.sample(chunk, &mut rng);
            let bd = batch.decompose(crate::partition::Reorder::Metis, COMMUNITY, cfg.seed);
            let bucket = BucketInfo {
                name: "sample-bench".to_string(),
                vertices: bd.graph.n,
                edges: bd.intra.nnz() + bd.inter.nnz(),
                features: f,
                hidden: f,
                classes: 4,
                blocks: bd.graph.n.div_ceil(COMMUNITY),
            };
            let req = PlanRequest::labeled(
                &bd,
                ModelKind::Gcn,
                &bucket,
                spec.name,
                scale,
                crate::partition::Reorder::Metis,
                cfg.seed,
            );
            let t0 = std::time::Instant::now();
            let plan = planner.plan(&req)?;
            let plan_elapsed = t0.elapsed().as_secs_f64();
            if epoch == 1 {
                plan_us_epoch2.push(plan_elapsed * 1e6);
            }
            // sampled "epoch" aggregate cost: run the planned assignment
            // on the native schedules (second epoch only, one pass)
            if epoch == 1 {
                let exec = AssignmentExec::build(&bd, &plan.assignment)?;
                let x: Vec<f32> = vec![0.5; bd.graph.n * f];
                let t1 = std::time::Instant::now();
                std::hint::black_box(exec.aggregate(&x, f));
                sampled_agg_s += t1.elapsed().as_secs_f64();
            }
        }
    }
    let total = planner.hits() + planner.misses();
    let epoch2_plans = total - plans_before_epoch2;
    let epoch2_hits = planner.hits() - hits_before_epoch2;
    let hit_rate = epoch2_hits as f64 / epoch2_plans.max(1) as f64;
    report.push(
        "plan_cache/hit_rate_after_epoch1",
        hit_rate,
        "frac",
        Direction::Higher,
    );
    report.push(
        "plan_cache/distinct_profiles",
        planner.len() as f64,
        "profiles",
        Direction::None,
    );
    if !plan_us_epoch2.is_empty() {
        let mean_us = plan_us_epoch2.iter().sum::<f64>() / plan_us_epoch2.len() as f64;
        report.push("plan_cache/epoch2_plan_us", mean_us, "us", Direction::Lower);
    }
    println!(
        "sample: {} plans over 2 epochs, epoch-2 hit rate {:.2} ({} hits / {} plans, {} profiles)",
        total,
        hit_rate,
        epoch2_hits,
        epoch2_plans,
        planner.len()
    );

    // ---- sampled epoch vs full-graph epoch, native aggregate cost
    report.push(
        "epoch/sampled_agg_ms",
        sampled_agg_s * 1e3,
        "ms",
        Direction::Lower,
    );
    let x_full: Vec<f32> = vec![0.5; n * f];
    let m = bench.bench("sample/full_epoch", || {
        std::hint::black_box(native::csr_intra_spmm(&d.intra, &x_full, f, COMMUNITY));
        std::hint::black_box(native::csr_inter_spmm(&d.inter, &x_full, f));
    });
    report.push("epoch/full_agg_ms", m.median_s() * 1e3, "ms", Direction::Lower);

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn quick_suite_meets_the_amortization_bar() {
        let cfg = BenchConfig {
            quick: true,
            artifacts: "definitely-not-an-artifacts-dir".to_string(),
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "sample");
        for name in [
            "sampler/batch_ms",
            "sampler/edges_per_s",
            "plan_cache/hit_rate_after_epoch1",
            "epoch/sampled_agg_ms",
            "epoch/full_agg_ms",
        ] {
            assert!(report.get(name).is_some(), "missing metric {name}");
        }
        // THE acceptance bar: after the first epoch, most batches must be
        // served from the profile-keyed cache.
        let hit_rate = report.get("plan_cache/hit_rate_after_epoch1").unwrap().value;
        assert!(
            hit_rate > 0.5,
            "epoch-2 plan-cache hit rate {hit_rate:.2} must exceed 0.5"
        );
    }
}
