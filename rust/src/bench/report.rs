//! Schema-versioned benchmark reports — the one `BENCH_*.json` shape.
//!
//! Every suite (and every wrapper under `rust/benches/`) emits results
//! through [`BenchReport`], so baselines recorded by one PR stay
//! comparable against numbers emitted by the next. The schema is
//! deliberately flat: a suite id, the run profile, free-form string
//! context, and a list of named scalar [`Metric`]s each tagged with the
//! direction that counts as *better* — which is all the comparator
//! (`bench::compare`) needs to gate a regression.
//!
//! [`SCHEMA_VERSION`] gates decoding: a file written by a different
//! schema fails to load with a distinct error instead of silently
//! comparing incompatible shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Bump when the report shape changes incompatibly; the comparator
/// refuses to diff across versions. v2: the kernels suite added the
/// tile-sparse class (`spmm/tile_sparse/*`, `pack/tile_sparse/*`,
/// `tile/*` metrics), so v1 kernel baselines are not comparable.
pub const SCHEMA_VERSION: u64 = 2;

/// Which way a metric improves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Smaller is better (latency, cost).
    Lower,
    /// Larger is better (throughput, quality fractions).
    Higher,
    /// Informational only — recorded and diffed but never gated
    /// (calibration ratios, losses without a quality contract).
    None,
}

impl Direction {
    pub fn as_str(&self) -> &'static str {
        match self {
            Direction::Lower => "lower",
            Direction::Higher => "higher",
            Direction::None => "none",
        }
    }
}

impl std::str::FromStr for Direction {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Direction, Self::Err> {
        match s {
            "lower" => Ok(Direction::Lower),
            "higher" => Ok(Direction::Higher),
            "none" => Ok(Direction::None),
            other => Err(anyhow!("unknown direction {other:?} (expected lower|higher|none)")),
        }
    }
}

/// One named scalar result.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: String,
    pub better: Direction,
}

/// One suite's results for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Suite id; the file name is derived from it (`BENCH_<suite>.json`).
    pub suite: String,
    /// True when the run used the reduced `--quick` workload profile.
    /// Quick and full numbers are not comparable; the comparator warns
    /// when the profiles differ.
    pub quick: bool,
    /// Free-form provenance (workload dims, skip reasons, chosen plans).
    pub context: BTreeMap<String, String>,
    pub metrics: Vec<Metric>,
}

impl BenchReport {
    pub fn new(suite: impl Into<String>, quick: bool) -> BenchReport {
        BenchReport {
            suite: suite.into(),
            quick,
            context: BTreeMap::new(),
            metrics: Vec::new(),
        }
    }

    /// Record a context string (overwrites an existing key).
    pub fn note(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.context.insert(key.into(), value.into());
    }

    /// Append a metric. Names must be unique within a report and values
    /// finite — both are suite programming errors, caught loudly here
    /// rather than emitted as an unparseable or ambiguous file.
    pub fn push(&mut self, name: impl Into<String>, value: f64, unit: &str, better: Direction) {
        let name = name.into();
        assert!(value.is_finite(), "metric {name:?} has non-finite value {value}");
        assert!(
            self.get(&name).is_none(),
            "duplicate metric name {name:?} in suite {}",
            self.suite
        );
        self.metrics.push(Metric { name, value, unit: unit.to_string(), better });
    }

    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// Canonical file name for a suite.
    pub fn file_name(suite: &str) -> String {
        format!("BENCH_{suite}.json")
    }

    /// Canonical path of a suite's report inside `dir`.
    pub fn path_in(dir: &Path, suite: &str) -> PathBuf {
        dir.join(Self::file_name(suite))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("suite", Json::str(self.suite.clone())),
            ("quick", Json::Bool(self.quick)),
            (
                "context",
                Json::Obj(
                    self.context
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "metrics",
                Json::Arr(
                    self.metrics
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("name", Json::str(m.name.clone())),
                                ("value", Json::num(m.value)),
                                ("unit", Json::str(m.unit.clone())),
                                ("better", Json::str(m.better.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Strict decode: this IS the schema validator — every rule a report
    /// must satisfy is enforced here, so `load` and `--validate` cannot
    /// drift apart.
    pub fn from_json(v: &Json) -> Result<BenchReport> {
        let version = v
            .get("schema_version")
            .as_f64()
            .ok_or_else(|| anyhow!("report missing schema_version"))?;
        if version != SCHEMA_VERSION as f64 {
            bail!(
                "schema version mismatch: file is v{version}, this binary reads v{SCHEMA_VERSION} — re-record the baseline"
            );
        }
        let suite = v
            .get("suite")
            .as_str()
            .ok_or_else(|| anyhow!("report missing suite"))?;
        if suite.is_empty() {
            bail!("report suite must be non-empty");
        }
        let quick = v
            .get("quick")
            .as_bool()
            .ok_or_else(|| anyhow!("report missing quick flag"))?;
        let mut context = BTreeMap::new();
        if let Some(obj) = v.get("context").as_obj() {
            for (k, val) in obj {
                let s = val
                    .as_str()
                    .ok_or_else(|| anyhow!("context entry {k:?} must be a string"))?;
                context.insert(k.clone(), s.to_string());
            }
        }
        let raw = v
            .get("metrics")
            .as_arr()
            .ok_or_else(|| anyhow!("report missing metrics array"))?;
        let mut metrics: Vec<Metric> = Vec::with_capacity(raw.len());
        for m in raw {
            let name = m
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("metric missing name"))?;
            if name.is_empty() {
                bail!("metric name must be non-empty");
            }
            if metrics.iter().any(|x| x.name == name) {
                bail!("duplicate metric name {name:?}");
            }
            let value = m
                .get("value")
                .as_f64()
                .ok_or_else(|| anyhow!("metric {name:?} missing numeric value"))?;
            if !value.is_finite() {
                bail!("metric {name:?} has non-finite value");
            }
            metrics.push(Metric {
                name: name.to_string(),
                value,
                unit: m
                    .get("unit")
                    .as_str()
                    .ok_or_else(|| anyhow!("metric {name:?} missing unit"))?
                    .to_string(),
                better: m
                    .get("better")
                    .as_str()
                    .ok_or_else(|| anyhow!("metric {name:?} missing better"))?
                    .parse()
                    .with_context(|| format!("metric {name:?}"))?,
            });
        }
        Ok(BenchReport { suite: suite.to_string(), quick, context, metrics })
    }

    /// Write `BENCH_<suite>.json` into `dir`; returns the path.
    pub fn write_at(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {}", dir.display()))?;
        let path = Self::path_in(dir, &self.suite);
        let doc = self.to_json();
        // Writer/checker anti-drift rule (DESIGN.md Sec. 13): what the
        // suite writes must pass the bench analyzer's schema audit.
        crate::check::debug_self_check("BenchReport::write_at", |d| {
            crate::check::bench::lint_report_json(&doc, &path.display().to_string(), d);
        });
        std::fs::write(&path, json::write(&doc))
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Load and validate one report file.
    pub fn load(path: &Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v).with_context(|| format!("validating {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut r = BenchReport::new("kernels", true);
        r.note("workload.dense", "n=2048 f=32");
        r.push("spmm/csr_intra/dense", 12.5, "us", Direction::Lower);
        r.push("serve/throughput", 810.0, "rps", Direction::Higher);
        r.push("calib/ratio", 0.4, "x", Direction::None);
        r
    }

    #[test]
    fn roundtrips_losslessly() {
        let r = sample();
        let text = json::write(&r.to_json());
        let back = BenchReport::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn schema_version_mismatch_is_a_distinct_error() {
        let Json::Obj(mut obj) = sample().to_json() else { unreachable!() };
        obj.insert("schema_version".into(), Json::num(99.0));
        let err = BenchReport::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(err.to_string().contains("schema version mismatch"), "{err:#}");
    }

    #[test]
    fn rejects_malformed_reports() {
        for text in [
            "{}",
            r#"{"schema_version":2}"#,
            r#"{"schema_version":2,"suite":"","quick":false,"metrics":[]}"#,
            r#"{"schema_version":2,"suite":"k","quick":false}"#,
            r#"{"schema_version":2,"suite":"k","quick":false,
                "metrics":[{"name":"a","value":1,"unit":"us","better":"sideways"}]}"#,
            r#"{"schema_version":2,"suite":"k","quick":false,
                "metrics":[{"name":"a","value":1,"unit":"us","better":"lower"},
                            {"name":"a","value":2,"unit":"us","better":"lower"}]}"#,
            r#"{"schema_version":2,"suite":"k","quick":false,
                "metrics":[{"name":"a","unit":"us","better":"lower"}]}"#,
        ] {
            let v = json::parse(text).unwrap();
            assert!(BenchReport::from_json(&v).is_err(), "accepted: {text}");
        }
    }

    #[test]
    #[should_panic(expected = "duplicate metric name")]
    fn push_rejects_duplicate_names() {
        let mut r = BenchReport::new("x", false);
        r.push("a", 1.0, "us", Direction::Lower);
        r.push("a", 2.0, "us", Direction::Lower);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn push_rejects_non_finite_values() {
        let mut r = BenchReport::new("x", false);
        r.push("a", f64::INFINITY, "us", Direction::Lower);
    }

    #[test]
    fn write_and_load() {
        let dir = std::env::temp_dir().join(format!(
            "adaptgear-benchreport-{}-{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let r = sample();
        let path = r.write_at(&dir).unwrap();
        assert_eq!(path, BenchReport::path_in(&dir, "kernels"));
        assert_eq!(BenchReport::load(&path).unwrap(), r);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_names_are_canonical() {
        assert_eq!(BenchReport::file_name("serve"), "BENCH_serve.json");
    }
}
