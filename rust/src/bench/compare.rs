//! Baseline comparator — the CI perf-regression gate behind
//! `adaptgear bench --check --baseline <dir>`.
//!
//! Policy (each rule has a dedicated test):
//!
//! * Baseline and current must share the suite id and schema version
//!   (version is enforced at load by `report::BenchReport::from_json`).
//! * A metric present in the baseline but absent from the current run is
//!   a failure — silently dropping a gated number is how regressions
//!   hide. Exception: when the two runs ran at different *capability
//!   tiers* (the `engine` / `skipped` context notes differ — e.g. the
//!   baseline was recorded with built artifacts and CI runs on a bare
//!   checkout), artifact-tier metrics legitimately disappear, so they
//!   are skipped instead of failed; same-tier metrics still gate.
//! * A metric new in the current run is informational (it becomes gated
//!   once a baseline containing it is committed).
//! * Quick and full profiles time different workloads; [`check_dirs`]
//!   refuses to compare across them (flagged, not failed).
//! * Gating uses the *baseline's* `better` direction: the committed
//!   baseline is the contract.
//! * `better == none` metrics are diffed but never fail.
//! * A zero-valued baseline has no defined relative delta: equal-zero
//!   passes, any movement in the worse direction fails.
//! * The relative tolerance is a strict bound: `worse == tolerance`
//!   passes, anything beyond fails.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::report::{BenchReport, Direction};

/// Allowed relative degradation before a metric fails the gate.
#[derive(Debug, Clone, Copy)]
pub struct Tolerance {
    /// e.g. 0.5 = a metric may be up to 50% worse than its baseline.
    /// Wall-clock benches on shared CI machines are noisy; deterministic
    /// gpusim metrics regress far past this when something real breaks.
    pub rel: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance { rel: 0.5 }
    }
}

/// Outcome for one metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// Better than baseline by more than the tolerance (reported so
    /// stale baselines get refreshed, never a failure).
    Improved,
    Regression,
    /// In the baseline, absent from the current run — a failure.
    MissingInCurrent,
    /// In the current run, absent from the baseline — informational.
    New,
    /// `better == none`: diffed, never gated.
    Info,
    /// Absent from the current run, but the two runs were produced at
    /// different capability tiers (artifacts vs bare checkout) — the
    /// metric's tier did not run, so its absence is not a failure.
    Skipped,
}

impl Verdict {
    pub fn is_failure(&self) -> bool {
        matches!(self, Verdict::Regression | Verdict::MissingInCurrent)
    }

    fn tag(&self) -> &'static str {
        match self {
            Verdict::Pass => "ok  ",
            Verdict::Improved => "good",
            Verdict::Regression => "REGR",
            Verdict::MissingInCurrent => "MISS",
            Verdict::New => "new ",
            Verdict::Info => "info",
            Verdict::Skipped => "skip",
        }
    }
}

/// Per-metric diff.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    pub name: String,
    pub base: Option<f64>,
    pub current: Option<f64>,
    /// Relative change in the *worse* direction (positive = worse), when
    /// defined.
    pub worse_frac: Option<f64>,
    pub verdict: Verdict,
}

/// One suite's full diff.
#[derive(Debug)]
pub struct Comparison {
    pub suite: String,
    pub deltas: Vec<MetricDelta>,
}

impl Comparison {
    pub fn failures(&self) -> usize {
        self.deltas.iter().filter(|d| d.verdict.is_failure()).count()
    }

    pub fn render(&self) -> String {
        let mut out = format!("suite {}:\n", self.suite);
        for d in &self.deltas {
            let fmt = |v: Option<f64>| match v {
                Some(x) => format!("{x:.4}"),
                None => "-".to_string(),
            };
            let delta = match d.worse_frac {
                Some(w) => format!("{:+.1}% worse", w * 100.0),
                None => String::new(),
            };
            out.push_str(&format!(
                "  [{}] {:<48} {:>14} -> {:>14}  {delta}\n",
                d.verdict.tag(),
                d.name,
                fmt(d.base),
                fmt(d.current),
            ));
        }
        let fails = self.failures();
        out.push_str(&format!(
            "  {} metrics, {} failures\n",
            self.deltas.len(),
            fails
        ));
        out
    }
}

fn judge(better: Direction, base: f64, cur: f64, tol: Tolerance) -> (Option<f64>, Verdict) {
    let dir_sign = match better {
        Direction::None => {
            let frac = if base != 0.0 { Some((cur - base) / base.abs()) } else { None };
            return (frac, Verdict::Info);
        }
        Direction::Lower => 1.0,
        Direction::Higher => -1.0,
    };
    if base == 0.0 {
        // No relative scale: only an exact hold (or an improvement) passes.
        let worse = cur * dir_sign > 0.0;
        return (None, if worse { Verdict::Regression } else { Verdict::Pass });
    }
    let worse_frac = dir_sign * (cur - base) / base.abs();
    let verdict = if worse_frac > tol.rel {
        Verdict::Regression
    } else if worse_frac < -tol.rel {
        Verdict::Improved
    } else {
        Verdict::Pass
    };
    (Some(worse_frac), verdict)
}

/// Diff `current` against `baseline` (same suite).
pub fn compare(
    baseline: &BenchReport,
    current: &BenchReport,
    tol: Tolerance,
) -> Result<Comparison> {
    if baseline.suite != current.suite {
        bail!(
            "cannot compare suite {:?} against baseline suite {:?}",
            current.suite,
            baseline.suite
        );
    }
    // Capability tier: suites note how they were produced ("engine" for
    // the train tiers, "skipped" for a no-artifacts serve run). When the
    // tiers differ, metrics only the richer tier emits are expected to
    // be absent — skipped, not failed.
    let tier_differs = baseline.context.get("engine") != current.context.get("engine")
        || baseline.context.contains_key("skipped") != current.context.contains_key("skipped");
    let mut deltas = Vec::new();
    for m in &baseline.metrics {
        match current.get(&m.name) {
            None => deltas.push(MetricDelta {
                name: m.name.clone(),
                base: Some(m.value),
                current: None,
                worse_frac: None,
                verdict: if tier_differs {
                    Verdict::Skipped
                } else {
                    Verdict::MissingInCurrent
                },
            }),
            Some(c) => {
                let (worse_frac, verdict) = judge(m.better, m.value, c.value, tol);
                deltas.push(MetricDelta {
                    name: m.name.clone(),
                    base: Some(m.value),
                    current: Some(c.value),
                    worse_frac,
                    verdict,
                });
            }
        }
    }
    for c in &current.metrics {
        if baseline.get(&c.name).is_none() {
            deltas.push(MetricDelta {
                name: c.name.clone(),
                base: None,
                current: Some(c.value),
                worse_frac: None,
                verdict: Verdict::New,
            });
        }
    }
    Ok(Comparison { suite: baseline.suite.clone(), deltas })
}

/// Result of checking a set of suites across two directories.
#[derive(Debug)]
pub struct CheckOutcome {
    pub comparisons: Vec<Comparison>,
    /// Suites with no committed baseline file (skipped, not failed).
    pub skipped: Vec<String>,
    /// Suites where baseline and current ran different profiles (quick
    /// vs full). The profiles time different workloads, so comparing
    /// them would produce spurious verdicts — these suites are NOT
    /// compared, only flagged.
    pub profile_mismatch: Vec<String>,
}

impl CheckOutcome {
    pub fn failures(&self) -> usize {
        self.comparisons.iter().map(Comparison::failures).sum()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.comparisons {
            out.push_str(&c.render());
        }
        for s in &self.skipped {
            out.push_str(&format!(
                "suite {s}: no baseline file — skipped (commit BENCH_{s}.json into the baseline dir to gate it)\n"
            ));
        }
        for s in &self.profile_mismatch {
            out.push_str(&format!(
                "suite {s}: WARNING quick/full profile mismatch — different workloads, not compared (re-record the baseline at the profile CI runs)\n"
            ));
        }
        out.push_str(&format!("total failures: {}\n", self.failures()));
        out
    }
}

/// Check every requested suite's current report (in `current_dir`)
/// against its committed baseline (in `baseline_dir`). A missing
/// *current* file is an error (the suite was not run); a missing
/// *baseline* file skips that suite with a message.
pub fn check_dirs(
    baseline_dir: &Path,
    current_dir: &Path,
    suites: &[&str],
    tol: Tolerance,
) -> Result<CheckOutcome> {
    let mut out = CheckOutcome {
        comparisons: Vec::new(),
        skipped: Vec::new(),
        profile_mismatch: Vec::new(),
    };
    for &suite in suites {
        let cur_path = BenchReport::path_in(current_dir, suite);
        let current = BenchReport::load(&cur_path)
            .with_context(|| format!("suite {suite}: run `adaptgear bench` first"))?;
        let base_path = BenchReport::path_in(baseline_dir, suite);
        if !base_path.exists() {
            out.skipped.push(suite.to_string());
            continue;
        }
        let baseline =
            BenchReport::load(&base_path).with_context(|| format!("suite {suite}: baseline"))?;
        if baseline.quick != current.quick {
            // Different workload profiles: a diff would be meaningless
            // and gate on noise-vs-noise — refuse, loudly.
            out.profile_mismatch.push(suite.to_string());
            continue;
        }
        out.comparisons.push(compare(&baseline, &current, tol)?);
    }
    Ok(out)
}

/// Load + validate each suite's report in `dir` (schema validation is
/// the load path itself). Errors name the first offending file.
pub fn validate_dir(dir: &Path, suites: &[&str]) -> Result<Vec<BenchReport>> {
    let mut reports = Vec::new();
    for &suite in suites {
        let path = BenchReport::path_in(dir, suite);
        let report = BenchReport::load(&path)?;
        if report.suite != suite {
            bail!(
                "{} declares suite {:?}, expected {suite:?}",
                path.display(),
                report.suite
            );
        }
        reports.push(report);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(suite: &str, metrics: &[(&str, f64, Direction)]) -> BenchReport {
        let mut r = BenchReport::new(suite, true);
        for &(name, value, better) in metrics {
            r.push(name, value, "us", better);
        }
        r
    }

    fn verdict_of(c: &Comparison, name: &str) -> Verdict {
        c.deltas.iter().find(|d| d.name == name).unwrap().verdict
    }

    #[test]
    fn identical_reports_pass() {
        let r = report("kernels", &[("a", 10.0, Direction::Lower), ("b", 5.0, Direction::Higher)]);
        let c = compare(&r, &r, Tolerance::default()).unwrap();
        assert_eq!(c.failures(), 0);
        assert!(c.deltas.iter().all(|d| d.verdict == Verdict::Pass));
    }

    #[test]
    fn injected_2x_regression_fails() {
        let base = report("kernels", &[("a", 100.0, Direction::Lower)]);
        let cur = report("kernels", &[("a", 200.0, Direction::Lower)]);
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "a"), Verdict::Regression);
        assert_eq!(c.failures(), 1);
    }

    #[test]
    fn tolerance_boundary_is_inclusive() {
        // exactly at the bound passes; epsilon beyond fails
        let base = report("k", &[("a", 100.0, Direction::Lower)]);
        let at = report("k", &[("a", 150.0, Direction::Lower)]);
        let past = report("k", &[("a", 150.0001, Direction::Lower)]);
        let tol = Tolerance { rel: 0.5 };
        assert_eq!(verdict_of(&compare(&base, &at, tol).unwrap(), "a"), Verdict::Pass);
        assert_eq!(verdict_of(&compare(&base, &past, tol).unwrap(), "a"), Verdict::Regression);
    }

    #[test]
    fn higher_is_better_inverts_the_gate() {
        let base = report("k", &[("rps", 100.0, Direction::Higher)]);
        let worse = report("k", &[("rps", 40.0, Direction::Higher)]);
        let better = report("k", &[("rps", 400.0, Direction::Higher)]);
        let c = compare(&base, &worse, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "rps"), Verdict::Regression);
        let c = compare(&base, &better, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "rps"), Verdict::Improved);
        assert_eq!(c.failures(), 0, "improvement is not a failure");
    }

    #[test]
    fn tier_mismatch_skips_artifact_metrics_but_gates_shared_ones() {
        // Baseline recorded with built artifacts; CI runs a bare
        // checkout: the PJRT-tier metric is skipped, the engine-free
        // metric still gates (and here, still regresses).
        let mut base = report(
            "train",
            &[
                ("prep/cora", 10.0, Direction::Lower),
                ("train/cora/mean_step_ms", 3.0, Direction::Lower),
            ],
        );
        base.note("engine", "pjrt");
        let mut cur = report("train", &[("prep/cora", 100.0, Direction::Lower)]);
        cur.note("engine", "native-only");
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "train/cora/mean_step_ms"), Verdict::Skipped);
        assert_eq!(verdict_of(&c, "prep/cora"), Verdict::Regression);
        assert_eq!(c.failures(), 1, "only the same-tier regression fails");

        // serve's skip-report marker works the same way
        let mut base = report("serve", &[("serve/mb16/p99_ms", 5.0, Direction::Lower)]);
        base.note("dataset", "citeseer");
        let mut cur = report("serve", &[]);
        cur.note("skipped", "artifacts not available");
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "serve/mb16/p99_ms"), Verdict::Skipped);
        assert_eq!(c.failures(), 0);
    }

    #[test]
    fn metric_missing_from_current_fails() {
        let base = report("k", &[("a", 1.0, Direction::Lower), ("b", 1.0, Direction::Lower)]);
        let cur = report("k", &[("a", 1.0, Direction::Lower)]);
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "b"), Verdict::MissingInCurrent);
        assert_eq!(c.failures(), 1);
    }

    #[test]
    fn metric_missing_from_baseline_is_informational() {
        let base = report("k", &[("a", 1.0, Direction::Lower)]);
        let cur = report("k", &[("a", 1.0, Direction::Lower), ("b", 9.0, Direction::Lower)]);
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "b"), Verdict::New);
        assert_eq!(c.failures(), 0);
    }

    #[test]
    fn zero_valued_baseline_rules() {
        // equal-zero holds; any worse-direction movement fails; the
        // improvement direction passes.
        let base = report(
            "k",
            &[("errs", 0.0, Direction::Lower), ("rps", 0.0, Direction::Higher)],
        );
        let hold = report(
            "k",
            &[("errs", 0.0, Direction::Lower), ("rps", 7.0, Direction::Higher)],
        );
        let c = compare(&base, &hold, Tolerance::default()).unwrap();
        assert_eq!(c.failures(), 0);
        let regress = report(
            "k",
            &[("errs", 0.1, Direction::Lower), ("rps", 0.0, Direction::Higher)],
        );
        let c = compare(&base, &regress, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "errs"), Verdict::Regression);
        assert_eq!(verdict_of(&c, "rps"), Verdict::Pass);
    }

    #[test]
    fn none_direction_never_fails() {
        let base = report("k", &[("ratio", 1.0, Direction::None)]);
        let cur = report("k", &[("ratio", 50.0, Direction::None)]);
        let c = compare(&base, &cur, Tolerance::default()).unwrap();
        assert_eq!(verdict_of(&c, "ratio"), Verdict::Info);
        assert_eq!(c.failures(), 0);
    }

    #[test]
    fn suite_mismatch_is_an_error() {
        let a = report("kernels", &[]);
        let b = report("serve", &[]);
        assert!(compare(&a, &b, Tolerance::default()).is_err());
    }

    #[test]
    fn check_dirs_end_to_end() {
        let root = std::env::temp_dir().join(format!(
            "adaptgear-checkdirs-{}-{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let base_dir = root.join("base");
        let cur_dir = root.join("cur");
        report("kernels", &[("a", 100.0, Direction::Lower)])
            .write_at(&base_dir)
            .unwrap();
        report("kernels", &[("a", 100.0, Direction::Lower)])
            .write_at(&cur_dir)
            .unwrap();
        report("plan", &[("p", 1.0, Direction::Lower)])
            .write_at(&cur_dir)
            .unwrap();

        // kernels gated + passes; plan has no baseline -> skipped
        let out = check_dirs(&base_dir, &cur_dir, &["kernels", "plan"], Tolerance::default())
            .unwrap();
        assert_eq!(out.failures(), 0);
        assert_eq!(out.skipped, vec!["plan".to_string()]);
        assert!(out.render().contains("no baseline file"));

        // a missing CURRENT file is an error, not a skip
        assert!(check_dirs(&base_dir, &cur_dir, &["serve"], Tolerance::default()).is_err());

        // a quick-vs-full profile mismatch is flagged and NOT compared —
        // even a 10x "regression" cannot fail across profiles
        let mut full_base = BenchReport::new("kernels", false);
        full_base.push("a", 10.0, "us", Direction::Lower);
        full_base.write_at(&base_dir).unwrap();
        let mut quick_cur = BenchReport::new("kernels", true);
        quick_cur.push("a", 100.0, "us", Direction::Lower);
        quick_cur.write_at(&cur_dir).unwrap();
        let out =
            check_dirs(&base_dir, &cur_dir, &["kernels"], Tolerance::default()).unwrap();
        assert_eq!(out.failures(), 0);
        assert!(out.comparisons.is_empty());
        assert_eq!(out.profile_mismatch, vec!["kernels".to_string()]);
        assert!(out.render().contains("profile mismatch"));

        // validate_dir: present suites validate; absent ones error
        assert!(validate_dir(&cur_dir, &["kernels", "plan"]).is_ok());
        assert!(validate_dir(&cur_dir, &["serve"]).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }
}
