//! Kernel suite: per-kernel spmm + operand-packing microbenches across
//! density classes, plus the gpusim calibration cross-check.
//!
//! Workloads are planted-partition graphs at three intra-density regimes
//! (dense / mixed / sparse blocks) with fixed seeds, so the *workload* is
//! bit-identical across runs and machines — only the clock varies. The
//! calibration section prices the same subgraphs through
//! `gpusim::class_kernel_cost` and flags every role where the cost
//! model's argmin disagrees with the measured native ranking; those
//! disagreements are the cost-model bug reports future planner fixes
//! start from (DESIGN.md Sec. 9).

use anyhow::Result;

use crate::graph::generate::planted_partition;
use crate::graph::{Csr, DenseBlocks};
use crate::gpusim::kernel_cost::CostCtx;
use crate::gpusim::{class_kernel_cost, kernel_cost, kernel_cost_density, ClassDims, A100};
use crate::kernels::tile::TileSparse;
use crate::kernels::{candidates, native, pack, KernelKind, Role};
use crate::partition::{Decomposition, Propagation, Reorder};
use crate::runtime::BucketInfo;
use crate::util::rng::Rng;

use super::report::{BenchReport, Direction};
use super::BenchConfig;

/// One density-regime workload (fixed dims; fixed seed at build time).
struct Workload {
    label: &'static str,
    n: usize,
    p_intra: f64,
    f: usize,
}

const COMMUNITY: usize = 16;

fn workloads(quick: bool) -> Vec<Workload> {
    let n = if quick { 2048 } else { 8192 };
    vec![
        Workload { label: "dense", n, p_intra: 0.60, f: 32 },
        Workload { label: "mixed", n, p_intra: 0.25, f: 32 },
        Workload { label: "sparse", n, p_intra: 0.04, f: 32 },
    ]
}

/// Bucket sized exactly to the workload so packing measures translation,
/// not padding slack.
fn bucket_for(d: &Decomposition, f: usize) -> BucketInfo {
    BucketInfo {
        name: "bench".to_string(),
        vertices: d.graph.n,
        edges: d.intra.nnz().max(d.inter.nnz()),
        features: f,
        hidden: f,
        classes: 8,
        blocks: d.graph.n.div_ceil(COMMUNITY),
    }
}

pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new("kernels", cfg.quick);
    let bench = super::measurer(cfg.quick);

    for w in workloads(cfg.quick) {
        // Deterministic workload: the seed is part of the suite contract.
        let mut rng = Rng::new(cfg.seed ^ 0x6e57);
        let g = planted_partition(w.n, COMMUNITY, w.p_intra, 16.0 / w.n as f64, &mut rng);
        let d =
            Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, COMMUNITY, 0);
        let x: Vec<f32> = (0..w.n * w.f).map(|_| rng.normal_f32()).collect();
        let blocks = DenseBlocks::from_block_diagonal_csr(&d.intra, COMMUNITY);
        let inter_trips = d.inter.to_triplets();
        let bucket = bucket_for(&d, w.f);
        report.note(
            format!("workload.{}", w.label),
            format!(
                "n={} f={} p_intra={:.2} intra_nnz={} inter_nnz={}",
                w.n,
                w.f,
                w.p_intra,
                d.intra.nnz(),
                d.inter.nnz()
            ),
        );
        println!(
            "\n-- kernels/{}: n={} f={} intra_nnz={} inter_nnz={} --",
            w.label,
            w.n,
            w.f,
            d.intra.nnz(),
            d.inter.nnz()
        );

        // ---- native spmm executions (the GPU schedules' CPU mirrors)
        let mut measured: Vec<(KernelKind, bool, f64)> = Vec::new();
        let mut spmm = |kind: KernelKind, is_intra: bool, f_run: &mut dyn FnMut()| {
            let m = bench.bench(&format!("spmm/{}/{}", kind.as_str(), w.label), f_run);
            let us = m.median_s() * 1e6;
            report.push(
                format!("spmm/{}/{}", kind.as_str(), w.label),
                us,
                "us",
                Direction::Lower,
            );
            measured.push((kind, is_intra, us));
        };
        spmm(KernelKind::CsrIntra, true, &mut || {
            std::hint::black_box(native::csr_intra_spmm(&d.intra, &x, w.f, COMMUNITY));
        });
        spmm(KernelKind::DenseBlock, true, &mut || {
            std::hint::black_box(native::dense_block_spmm(&blocks, &x, w.f));
        });
        let tiles = TileSparse::from_block_diagonal_csr(&d.intra, COMMUNITY);
        spmm(KernelKind::TileSparse, true, &mut || {
            std::hint::black_box(native::tile_sparse_spmm(&tiles, &x, w.f));
        });
        spmm(KernelKind::CsrInter, false, &mut || {
            std::hint::black_box(native::csr_inter_spmm(&d.inter, &x, w.f));
        });
        spmm(KernelKind::Coo, false, &mut || {
            std::hint::black_box(native::coo_spmm(w.n, &inter_trips, &x, w.f));
        });
        let m = bench.bench(&format!("spmm/reference/{}", w.label), || {
            std::hint::black_box(d.inter.spmm(&x, w.f));
        });
        report.push(
            format!("spmm/reference/{}", w.label),
            m.median_s() * 1e6,
            "us",
            Direction::Lower,
        );

        // ---- AOT operand packing (the pack half of every cold start)
        for (kind, matrix) in [
            (KernelKind::CsrIntra, &d.intra),
            (KernelKind::DenseBlock, &d.intra),
            (KernelKind::TileSparse, &d.intra),
            (KernelKind::CsrInter, &d.inter),
            (KernelKind::Coo, &d.inter),
        ] {
            let m = bench.bench(&format!("pack/{}/{}", kind.as_str(), w.label), || {
                std::hint::black_box(
                    pack::pack_kernel_operands(kind, matrix, COMMUNITY, &bucket).unwrap(),
                );
            });
            report.push(
                format!("pack/{}/{}", kind.as_str(), w.label),
                m.median_s() * 1e6,
                "us",
                Direction::Lower,
            );
            if kind == KernelKind::TileSparse {
                // Tile translation throughput + how full the reserved
                // grid actually is (the exact counterpart of the sweep's
                // `est_occupied_tiles` admissibility estimate).
                let pack_s = m.median_s().max(1e-12);
                report.push(
                    format!("tile/pack_per_s/{}", w.label),
                    tiles.n_tiles().max(1) as f64 / pack_s,
                    "tiles/s",
                    Direction::Higher,
                );
                report.push(
                    format!("tile/occupied_frac/{}", w.label),
                    tiles.occupied_frac(),
                    "frac",
                    Direction::None,
                );
            }
        }

        calibrate(&mut report, &d, w.f, w.label, &measured);
    }

    // ---- graph-construction substrate + cost-model evaluation latency
    // (carried over from the pre-suite benches/kernels.rs: the former
    // sits on every preprocess cold path, the latter on the selector's
    // hot path — neither is visible through the spmm numbers alone)
    let n = if cfg.quick { 4096 } else { 32768 };
    let mut rng = Rng::new(cfg.seed ^ 0x97a9);
    let g = planted_partition(n, COMMUNITY, 0.3, 8.0 / n as f64, &mut rng);
    println!("\n-- kernels/substrate: n={n} --");
    let m = bench.bench("graph/gcn_normalized", || {
        std::hint::black_box(Csr::gcn_normalized(&g));
    });
    report.push("graph/gcn_normalized", m.median_s() * 1e6, "us", Direction::Lower);
    let a = Csr::gcn_normalized(&g);
    let m = bench.bench("graph/split_block_diagonal", || {
        std::hint::black_box(a.split_block_diagonal(COMMUNITY));
    });
    report.push("graph/split_block_diagonal", m.median_s() * 1e6, "us", Direction::Lower);
    let m = bench.bench("graph/transpose", || {
        std::hint::black_box(a.transpose());
    });
    report.push("graph/transpose", m.median_s() * 1e6, "us", Direction::Lower);

    let (intra, inter) = a.split_block_diagonal(COMMUNITY);
    let m = bench.bench("gpusim/kernel_cost_csr", || {
        std::hint::black_box(kernel_cost(KernelKind::CsrInter, &inter, 32, COMMUNITY, &A100));
    });
    report.push("gpusim/kernel_cost_csr", m.median_s() * 1e6, "us", Direction::Lower);
    let m = bench.bench("gpusim/kernel_cost_dense", || {
        std::hint::black_box(kernel_cost(KernelKind::DenseBlock, &intra, 32, COMMUNITY, &A100));
    });
    report.push("gpusim/kernel_cost_dense", m.median_s() * 1e6, "us", Direction::Lower);
    Ok(report)
}

/// Cross-check the simulated `class_kernel_cost` against the measured
/// native times: record the simulated cost and sim/measured ratio per
/// candidate, and whether the cost model's argmin agrees with the
/// measured argmin per role. Disagreements are *flagged*, not gated —
/// the native CPU mirror has no tensor cores, so a ranking flip is a
/// calibration lead, not automatically a bug.
fn calibrate(
    report: &mut BenchReport,
    d: &Decomposition,
    f: usize,
    label: &str,
    measured: &[(KernelKind, bool, f64)],
) {
    // The feat-density the sparse-feature agreement rows re-rank at
    // (k = F/8, the feat suite's acceptance ratio).
    const SPARSE_RHO: f64 = 0.125;
    let profile = d.intra_block_profile();
    let rows: usize = profile.blocks.iter().map(|&(r, _)| r).sum();
    let sim_us_rho = |kind: KernelKind, is_intra: bool, rho: f64| -> f64 {
        if is_intra {
            let dims = ClassDims { kind, blocks: profile.len(), rows, nnz: d.intra.nnz() };
            let ctx = CostCtx::new(dims, f, d.community, &A100).with_feat_density(rho);
            class_kernel_cost(&ctx).time_us
        } else {
            kernel_cost_density(kind, &d.inter, f, d.community, &A100, rho).time_us
        }
    };
    let sim_us = |kind: KernelKind, is_intra: bool| -> f64 { sim_us_rho(kind, is_intra, 1.0) };

    for &(kind, is_intra, meas) in measured {
        let sim = sim_us(kind, is_intra);
        report.push(
            format!("calib/sim/{}/{label}", kind.as_str()),
            sim,
            "us",
            Direction::None,
        );
        if meas > 0.0 {
            report.push(
                format!("calib/ratio/{}/{label}", kind.as_str()),
                sim / meas,
                "x",
                Direction::None,
            );
        }
    }

    // The intra role ranks everything the intra artifact slot can run —
    // including the tile class — so argmin agreement covers the full
    // registry, not just the uniform-selector pair.
    for (role, cands) in [
        ("intra", candidates(Role::IntraSlot)),
        ("inter", candidates(Role::Inter)),
    ] {
        let is_intra = role == "intra";
        let argmin = |key: &dyn Fn(KernelKind) -> f64| -> KernelKind {
            cands
                .iter()
                .copied()
                .min_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap())
                .unwrap()
        };
        let sim_winner = argmin(&|k| sim_us(k, is_intra));
        let meas_winner = argmin(&|k| {
            measured
                .iter()
                .find(|&&(m, mi, _)| m == k && mi == is_intra)
                .map(|&(_, _, us)| us)
                .unwrap_or(f64::INFINITY)
        });
        let agree = sim_winner == meas_winner;
        report.push(
            format!("calib/agree/{role}/{label}"),
            if agree { 1.0 } else { 0.0 },
            "bool",
            Direction::None,
        );
        // Sparse-feature variant: re-rank the same candidates with the
        // cost model at rho = 1/8 live lanes. The measurement side stays
        // the dense-feature mirror, so a disagreement here flags exactly
        // the roles where top-k features would flip the kernel choice —
        // a calibration lead for the feat suite, not a gate.
        let sparse_winner = argmin(&|k| sim_us_rho(k, is_intra, SPARSE_RHO));
        report.push(
            format!("calib/agree/{role}/{label}/sparsefeat"),
            if sparse_winner == meas_winner { 1.0 } else { 0.0 },
            "bool",
            Direction::None,
        );
        if sparse_winner != sim_winner {
            println!(
                "calibration: {role}/{label} density {SPARSE_RHO} shifts the sim argmin {} -> {}",
                sim_winner.as_str(),
                sparse_winner.as_str()
            );
        }
        if agree {
            println!("calibration: {role}/{label} argmin agrees ({})", sim_winner.as_str());
        } else {
            println!(
                "calibration: {role}/{label} ARGMIN DISAGREES — sim picks {}, measurement picks {}",
                sim_winner.as_str(),
                meas_winner.as_str()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// One full quick run emits a schema-valid report covering every
    /// kernel x density class, with the calibration section present.
    /// (This is the suite's own integration test; it runs the real
    /// measurement loop at the quick profile.)
    #[test]
    fn quick_run_is_schema_valid_and_complete() {
        let cfg = BenchConfig {
            quick: true,
            out: PathBuf::from("."),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.suite, "kernels");
        for label in ["dense", "mixed", "sparse"] {
            for kind in ["csr_intra", "dense_block", "tile_sparse", "csr_inter", "coo"] {
                assert!(report.get(&format!("spmm/{kind}/{label}")).is_some());
                assert!(report.get(&format!("pack/{kind}/{label}")).is_some());
                assert!(report.get(&format!("calib/sim/{kind}/{label}")).is_some());
            }
            for role in ["intra", "inter"] {
                let m = report.get(&format!("calib/agree/{role}/{label}")).unwrap();
                assert!(m.value == 0.0 || m.value == 1.0);
                let m = report.get(&format!("calib/agree/{role}/{label}/sparsefeat")).unwrap();
                assert!(m.value == 0.0 || m.value == 1.0);
            }
            let frac = report.get(&format!("tile/occupied_frac/{label}")).unwrap();
            assert!(frac.value > 0.0 && frac.value <= 1.0, "occupied_frac {}", frac.value);
            assert!(report.get(&format!("tile/pack_per_s/{label}")).unwrap().value > 0.0);
        }
        // denser blocks occupy more of the tile grid
        assert!(
            report.get("tile/occupied_frac/dense").unwrap().value
                >= report.get("tile/occupied_frac/sparse").unwrap().value
        );
        for name in [
            "graph/gcn_normalized",
            "graph/split_block_diagonal",
            "graph/transpose",
            "gpusim/kernel_cost_csr",
            "gpusim/kernel_cost_dense",
        ] {
            assert!(report.get(name).is_some(), "missing substrate metric {name}");
        }
        // strict decode of its own serialization
        let text = crate::util::json::write(&report.to_json());
        let back = BenchReport::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, report);
    }
}
