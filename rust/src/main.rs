//! AdaptGear CLI — the leader entrypoint.
//!
//! ```text
//! adaptgear datasets                         # Table 1 registry + measured stats
//! adaptgear decompose --dataset cora         # reorder + split, print density report
//! adaptgear plan --dataset cora --model gcn [--explain]
//!                                            # compute + persist a GearPlan
//! adaptgear train --dataset cora --model gcn --steps 200 [--planner cached]
//! adaptgear train --dataset planted-mixed --sampled --fanout 10,10
//!                                            # mini-batch neighbor-sampled training
//! adaptgear serve --dataset citeseer --requests 500 --max-batch 16
//!                                            # micro-batched serving + SLO report
//! adaptgear stream --dataset planted-mixed   # mutation workload: deltas -> drift
//!                                            # tracking -> online replan + swap
//! adaptgear bench --quick --suite sample     # fixed workload suites -> BENCH_*.json
//! adaptgear check --plans                    # static invariant audit -> CHECK_report.json
//! adaptgear selftest                         # artifact <-> runtime smoke check
//! ```
//!
//! `adaptgear help <command>` prints the full per-command flag reference.
//!
//! Figure regeneration lives in the bench harness: `cargo bench --bench
//! figures -- <fig2b|fig3a|...|all>`.

// Same lint posture as the library crate (DESIGN.md Sec. 13).
#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};

use adaptgear::coordinator::{pipeline, Clock, ModelKind, Run, Strategy};
use adaptgear::graph::{datasets, stats};
use adaptgear::gpusim::{kernel_cost_density, GpuModel};
use adaptgear::kernels::{benefits_from_sparse_features, candidates, Role};
use adaptgear::partition::{Decomposition, Propagation};
use adaptgear::plan::{
    CachedPlanner, GearPlan, MonitorPlanner, PlanRequest, PlanStore, Planner, SimCostPlanner,
};
use adaptgear::runtime::{BucketInfo, Engine, Manifest};
use adaptgear::util::cli::Args;
use adaptgear::util::json;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    // `adaptgear <command> --help` and `adaptgear help <command>` both
    // print the focused per-command reference.
    if args.flag("help") && cmd != "help" {
        if let Some(text) = command_help(cmd) {
            println!("{text}");
            return;
        }
    }
    // `--trace-out FILE`: subscribe the span recorder before the command
    // runs; the trace document (spans + metrics snapshot) is written
    // after it finishes, whether it succeeded or failed.
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        adaptgear::obs::install();
    }
    let result = match cmd {
        "datasets" => cmd_datasets(&args),
        "decompose" => cmd_decompose(&args),
        "plan" => cmd_plan(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "stream" => cmd_stream(&args),
        "bench" => cmd_bench(&args),
        "check" => cmd_check(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "--help" => {
            match args.positional.get(1).and_then(|c| command_help(c)) {
                Some(text) => println!("{text}"),
                None => print_help(),
            }
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Some(path) = &trace_out {
        match adaptgear::obs::write_trace(path) {
            Ok(trace) => {
                println!("\nspan summary:");
                print!("{}", trace.render_tree());
                println!("trace: {} events -> {}", trace.events.len(), path.display());
            }
            Err(e) => eprintln!("warning: trace export failed: {e:#}"),
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Focused reference for one subcommand: every flag it accepts and one
/// copy-pasteable example (smoke-checked by ci.sh).
fn command_help(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "datasets" => {
            "adaptgear datasets — list the Table 1 registry plus synthetic stand-ins.\n\n\
             FLAGS: none.\n\n\
             EXAMPLE:\n  adaptgear datasets"
        }
        "decompose" => {
            "adaptgear decompose — reorder a dataset, split intra/inter, print the\n\
             density report and an adjacency heat map.\n\n\
             FLAGS:\n\
             \x20 --dataset NAME      dataset or figure code (required)\n\
             \x20 --scale S           vertex-count scale factor (default: fits ~20k)\n\
             \x20 --community C       community width (default 16)\n\
             \x20 --seed N            generation + reorder seed (default 0)\n\n\
             EXAMPLE:\n  adaptgear decompose --dataset cora --community 16"
        }
        "plan" => {
            "adaptgear plan — compute a GearPlan (kernel decision) without training,\n\
             print it, and persist it to <artifacts>/plans/. Needs only the bucket\n\
             manifest unless --clock wall.\n\n\
             FLAGS:\n\
             \x20 --dataset NAME      dataset (default cora)\n\
             \x20 --model gcn|gin     model kind (default gcn)\n\
             \x20 --planner cached|monitor|sim   planning strategy (default cached)\n\
             \x20 --clock sim|wall    monitor timing source (default sim)\n\
             \x20 --gpu a100|v100     simulated GPU (default a100)\n\
             \x20 --monitor-repeats N monitored iterations per candidate (default 3)\n\
             \x20 --scale S           dataset scale override\n\
             \x20 --seed N            generation seed (default 0)\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\
             \x20 --explain           per-candidate costs, density histogram,\n\
             \x20                     per-class hybrid assignment, and the sweep\n\
             \x20                     provenance persisted with the plan\n\
             \x20 --no-save           do not write the plan store\n\
             \x20 --out FILE          also write the plan JSON to FILE\n\
             \x20 --trace-out FILE    write a Chrome trace (spans + metrics) of the run\n\n\
             EXAMPLE:\n  adaptgear plan --dataset planted-mixed --explain"
        }
        "train" => {
            "adaptgear train — plan (or load a cached plan), then train through PJRT.\n\
             With --sampled, run mini-batch neighbor-sampled training instead: each\n\
             batch subgraph is planned through the amortized profile-keyed cache and\n\
             executed on the hybrid pack/forward paths (PJRT when artifacts exist,\n\
             the native CPU backend otherwise).\n\n\
             FLAGS:\n\
             \x20 --dataset NAME      dataset (default cora)\n\
             \x20 --model gcn|gin     model kind (default gcn)\n\
             \x20 --steps N           full-graph training steps (default 200)\n\
             \x20 --lr F              learning rate (default 0.05)\n\
             \x20 --planner monitor|cached|sim  (default monitor)\n\
             \x20 --clock sim|wall    monitor timing source (default sim)\n\
             \x20 --gpu a100|v100     simulated GPU (default a100)\n\
             \x20 --scale S           dataset scale override\n\
             \x20 --seed N            generation + init seed (default 0)\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\
             \x20 --sampled           mini-batch neighbor-sampled training\n\
             \x20 --fanout K1,K2,...  per-layer neighbor budgets; 'full' or 0 keeps\n\
             \x20                     every neighbor (default 10,10)\n\
             \x20 --batch-size N      target vertices per batch (default 256)\n\
             \x20 --epochs N          passes over the vertex set (default 1)\n\
             \x20 --topk K            keep only the K largest hidden lanes per row\n\
             \x20                     (MaxK-style activation sparsity; plans price\n\
             \x20                     kernels at feature density K/hidden; native\n\
             \x20                     backend only)\n\
             \x20 --trace-out FILE    write a Chrome trace (spans + metrics) of the run\n\n\
             EXAMPLE:\n  adaptgear train --dataset planted-mixed --sampled --topk 16"
        }
        "serve" => {
            "adaptgear serve — deploy (plan + train + warm) through the registry,\n\
             then drive the micro-batched serving loop with the closed-loop load\n\
             generator and print the SLO report.\n\n\
             FLAGS:\n\
             \x20 --dataset NAME      dataset (default citeseer)\n\
             \x20 --model gcn|gin     model kind (default gcn)\n\
             \x20 --requests N        total requests (default 500)\n\
             \x20 --clients N         closed-loop client threads (default 32)\n\
             \x20 --max-batch N       micro-batch size cap (default 16)\n\
             \x20 --max-wait-us N     micro-batch wait cap (default 2000)\n\
             \x20 --queue-depth N     admission bound on in-flight requests (default 256)\n\
             \x20 --steps N           training budget before serving (default 60)\n\
             \x20 --seed N            loadgen seed (default 99)\n\
             \x20 --train-seed N      training seed (default 0)\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\
             \x20 --trace-out FILE    write a Chrome trace (spans + metrics) of the run\n\n\
             EXAMPLE:\n  adaptgear serve --dataset citeseer --requests 500 --max-batch 16"
        }
        "stream" => {
            "adaptgear stream — drive a deterministic mutation workload against a\n\
             planned graph: apply edge/vertex deltas through the CSR overlay, track\n\
             per-block density drift, re-plan the drifted classes online, and verify\n\
             the swapped plan's forward against a cold full re-plan. Engine-free\n\
             (native kernels + the cost simulator).\n\n\
             FLAGS:\n\
             \x20 --dataset NAME      dataset (default planted-mixed)\n\
             \x20 --model gcn|gin     model kind (default gcn)\n\
             \x20 --gpu a100|v100     simulated GPU (default a100)\n\
             \x20 --scale S           dataset scale override (default fits ~1k vertices)\n\
             \x20 --community C       community width (default 16)\n\
             \x20 --target-block B    diagonal block the workload densifies (default 1)\n\
             \x20 --reweights N       weight-only updates sprinkled elsewhere (default 200)\n\
             \x20 --compact-ratio F   staged-row fraction that triggers compaction\n\
             \x20                     (default 0.25)\n\
             \x20 --seed N            generation + reorder seed (default 0)\n\
             \x20 --trace-out FILE    write a Chrome trace (spans + metrics) of the run\n\n\
             EXAMPLE:\n  adaptgear stream --dataset planted-mixed --reweights 200"
        }
        "bench" => {
            "adaptgear bench — run the fixed workload suites and emit schema-versioned\n\
             BENCH_*.json reports; validate or regression-gate emitted reports.\n\n\
             FLAGS:\n\
             \x20 --quick             reduced CI workload profile\n\
             \x20 --suite all|kernels|plan|train|serve|sample|stream|feat  (default all)\n\
             \x20 --out DIR           report directory (default .)\n\
             \x20 --seed N            workload seed (default 7)\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\
             \x20 --validate          schema-check emitted reports, run nothing\n\
             \x20 --check             diff against --baseline DIR; non-zero exit on\n\
             \x20                     regression beyond --tolerance F (default 0.5)\n\n\
             EXAMPLE:\n  adaptgear bench --quick --suite sample"
        }
        "check" => {
            "adaptgear check — static invariant audit over everything the system\n\
             persists: plans in the store (fingerprints, coverage, edge caps, sweep\n\
             provenance, cost-model drift), delta logs (contiguity + replay), traces\n\
             (pairing, clocks, counter naming), and BENCH_*.json reports. Runs every\n\
             analyzer engine-free, writes CHECK_report.json, and exits non-zero when\n\
             any Error-severity lint (stable AG* codes, DESIGN.md Sec. 13) fires.\n\n\
             FLAGS:\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\
             \x20 --plans             audit <artifacts>/plans (default: on when present)\n\
             \x20 --trace FILE        audit a trace file (default: ./TRACE_*.json)\n\
             \x20 --delta FILE        audit a serialized delta log\n\
             \x20 --bench DIR         audit BENCH_*.json in DIR (default: . when present)\n\
             \x20 --baseline DIR      also diff bench metric sets against DIR\n\
             \x20 --deny warn         promote warnings to errors\n\
             \x20 --out FILE          report path (default CHECK_report.json)\n\n\
             EXAMPLE:\n  adaptgear check"
        }
        "selftest" => {
            "adaptgear selftest — execute every kernel artifact against the native\n\
             Rust kernels on a random decomposed graph and compare numerics.\n\n\
             FLAGS:\n\
             \x20 --artifacts DIR     artifacts directory (default artifacts)\n\n\
             EXAMPLE:\n  adaptgear selftest"
        }
        _ => return None,
    })
}

fn print_help() {
    println!(
        "adaptgear — adaptive subgraph-level GNN training (CF'23 reproduction)\n\n\
         USAGE: adaptgear <command> [options]\n\n\
         COMMANDS:\n\
         \x20 datasets                          list the Table 1 registry\n\
         \x20 decompose --dataset NAME [--scale S] [--community C]\n\
         \x20                                   reorder + split; print density report\n\
         \x20 plan --dataset NAME [--model gcn|gin] [--planner cached|monitor|sim]\n\
         \x20      [--clock sim|wall] [--gpu a100|v100] [--monitor-repeats N]\n\
         \x20      [--scale S] [--seed N] [--explain] [--no-save] [--out FILE]\n\
         \x20                                   compute the kernel plan, print it, and\n\
         \x20                                   persist it to <artifacts>/plans/\n\
         \x20 train --dataset NAME [--model gcn|gin] [--steps N] [--lr F]\n\
         \x20       [--planner monitor|cached|sim] [--clock sim|wall]\n\
         \x20       [--gpu a100|v100] [--scale S] [--seed N]\n\
         \x20       [--sampled [--fanout 10,10] [--batch-size N] [--epochs N]\n\
         \x20        [--topk K]]\n\
         \x20                                   plan (or load a cached plan), then train;\n\
         \x20                                   --sampled runs mini-batch neighbor-sampled\n\
         \x20                                   training with amortized per-batch plans\n\
         \x20 serve --dataset NAME [--model gcn|gin] [--requests N] [--clients N]\n\
         \x20       [--max-batch N] [--max-wait-us N] [--queue-depth N] [--steps N]\n\
         \x20       [--seed N (loadgen)] [--train-seed N]\n\
         \x20                                   micro-batched serving loop + SLO report\n\
         \x20                                   (deploys plan through the plan cache)\n\
         \x20 stream --dataset NAME [--reweights N] [--target-block B] [--scale S]\n\
         \x20                                   deterministic mutation workload: delta\n\
         \x20                                   log -> drift tracking -> online replan\n\
         \x20 bench [--quick] [--suite all|kernels|plan|train|serve|sample|stream|feat]\n\
         \x20       [--out DIR]\n\
         \x20                                   run the fixed workload suites, emit\n\
         \x20                                   schema-versioned BENCH_*.json reports\n\
         \x20 bench --validate [--out DIR]      schema-check emitted BENCH_*.json\n\
         \x20 bench --check --baseline DIR [--tolerance F] [--out DIR]\n\
         \x20                                   diff emitted reports against committed\n\
         \x20                                   baselines; non-zero exit on regression\n\
         \x20 check [--plans] [--trace FILE] [--delta FILE] [--bench DIR]\n\
         \x20       [--baseline DIR] [--deny warn] [--out FILE]\n\
         \x20                                   static invariant audit (stable AG* lint\n\
         \x20                                   codes) -> CHECK_report.json; non-zero\n\
         \x20                                   exit on any Error diagnostic\n\
         \x20 selftest                          verify artifacts + runtime numerics\n\n\
         OBSERVABILITY: pass --trace-out FILE to plan/train/serve to record spans\n\
         and a metrics snapshot into a Perfetto-loadable Chrome trace file.\n\n\
         Run `adaptgear help <command>` (or `adaptgear <command> --help`) for every\n\
         flag plus a copy-pasteable example.\n\n\
         Figures: cargo bench --bench figures -- <fig2b|fig3a|fig3b|fig4|fig8|\n\
         \x20        fig9|fig10|fig11|fig12|table2|overhead|all>"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn cmd_datasets(_args: &Args) -> Result<()> {
    println!(
        "{:<28} {:>9} {:>9} {:>6} {:>7} {:>10}",
        "dataset", "#Vertex", "#Edge", "#Feat", "#Class", "density"
    );
    for d in datasets::DATASETS {
        println!(
            "{:<28} {:>9} {:>9} {:>6} {:>7} {:>10.2e}",
            d.name, d.vertices, d.edges, d.features, d.classes, d.density()
        );
    }
    let pm = &datasets::PLANTED_MIXED;
    println!(
        "{:<28} {:>9} {:>9} {:>6} {:>7} {:>10.2e}  (synthetic, mixed-density)",
        pm.name, pm.vertices, pm.edges, pm.features, pm.classes, pm.density()
    );
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?;
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let scale = args.get_f64("scale", f64::min(0.05, 20_000.0 / spec.vertices as f64));
    let community = args.get_usize("community", 16);
    let seed = args.get_u64("seed", 0);

    let data = spec.build_scaled(scale, seed);
    println!(
        "dataset={} scale={:.4} vertices={} edges={}",
        spec.name,
        scale,
        data.graph.n,
        data.graph.directed_edge_count()
    );

    let before = stats::density_split(&data.graph, community);
    let (d, times) = adaptgear::coordinator::preprocess(
        Strategy::AdaptGear,
        &data.graph,
        Propagation::GcnNormalized,
        community,
        seed,
    );
    let after = stats::density_split(&d.graph, community);

    println!("reorder: {:.3}s  decompose: {:.3}s", times.reorder_secs, times.decompose_secs);
    println!(
        "density   before: full={:.2e} intra={:.2e} inter={:.2e}",
        before.full, before.intra, before.inter
    );
    println!(
        "density   after:  full={:.2e} intra={:.2e} inter={:.2e}  (intra edges {} -> {})",
        after.full, after.intra, after.inter, before.intra_edges, after.intra_edges
    );
    println!("\nadjacency heat map after reordering (dark = dense):");
    print!("{}", stats::render_heat_grid(&stats::adjacency_heat_grid(&d.graph, 24)));
    Ok(())
}

/// Compute a `GearPlan` for a dataset without training: decompose, run the
/// requested planner, print (optionally `--explain` per-candidate costs),
/// and persist it to the plan store so later `train`/`serve` runs skip
/// monitoring. Needs only the artifact *manifest* unless `--clock wall`.
fn cmd_plan(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("cora");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model: ModelKind = args.get_or("model", "gcn").parse()?;
    let gpu: &'static GpuModel = args.get_or("gpu", "a100").parse()?;
    let clock: Clock = args.get_or("clock", "sim").parse()?;
    let repeats = args.get_usize("monitor-repeats", 3);
    let seed = args.get_u64("seed", 0);
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;

    let scale_override = args.get("scale").map(|s| s.parse::<f64>()).transpose()?;
    // Same staging path as `train`/`deploy`, so the fingerprint (and
    // therefore the plan cache key) is identical across subcommands.
    let strategy = Strategy::AdaptGear;
    let staged = pipeline::stage(&manifest, spec, model, strategy, scale_override, seed)
        .context("staging the dataset (pass a smaller --scale?)")?;
    println!(
        "dataset={} scale={:.4} vertices={} edges={} | reorder {:.3}s decompose {:.3}s",
        spec.name,
        staged.scale,
        staged.data.graph.n,
        staged.data.graph.directed_edge_count(),
        staged.times.reorder_secs,
        staged.times.decompose_secs
    );
    let (d, bucket) = (&staged.d, &staged.bucket);
    let req = PlanRequest::labeled(
        d,
        model,
        bucket,
        spec.name,
        staged.scale,
        strategy.reorder(),
        seed,
    );

    let store = PlanStore::in_artifacts(&dir);
    let no_save = args.flag("no-save");
    let planner_kind = args.get_or("planner", "cached");
    // `--clock wall` is the only configuration that needs a live engine.
    let engine = match clock {
        Clock::Wall => Some(Engine::new(&dir)?),
        Clock::Sim => None,
    };
    // --no-save makes the cached planner read-only: hits still serve, but
    // a miss is computed without mutating the store.
    let mut planner = build_planner(
        planner_kind,
        clock,
        gpu,
        repeats,
        engine.as_ref(),
        store.clone(),
        no_save,
    )?;
    let plan = planner.plan(&req)?;
    // Report what THIS run did (a stale file for the same fingerprint must
    // not read as "persisted"): a cached hit was served from disk, a
    // cached miss was written by the planner unless read-only, and the
    // plain planners save here.
    let persisted = if planner_kind == "cached" {
        plan.provenance.cached || !no_save
    } else if no_save {
        false
    } else {
        store.save(&plan)?;
        true
    };
    if persisted {
        println!("store: {}", store.path_for(plan.fingerprint).display());
    } else {
        println!("store: not persisted (--no-save)");
    }

    println!("{}", plan.summary());
    if let Some(out) = args.get("out") {
        std::fs::write(out, json::write(&plan.to_json()))
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if args.flag("explain") {
        explain_plan(&plan, d, bucket, gpu);
    }
    Ok(())
}

/// `--explain`: the per-candidate cost surface behind the decision, the
/// intra density histogram, and the per-class hybrid assignment.
fn explain_plan(
    plan: &GearPlan,
    d: &Decomposition,
    bucket: &adaptgear::runtime::BucketInfo,
    gpu: &'static GpuModel,
) {
    let widths = [bucket.features, bucket.hidden];
    let rho = plan.feat_density;
    if rho < 1.0 {
        println!(
            "\nfeature density: {rho:.4} (top-k sparse features; candidates marked 's' \
             are priced by live lanes, dense engines traverse every lane)"
        );
    } else {
        println!("\nfeature density: {rho:.4} (dense features)");
    }
    println!("per-candidate gpusim costs (us; * = chosen):");
    for &w in &widths {
        println!("  width {w}:");
        let show = |role: &str,
                        matrix: &adaptgear::graph::Csr,
                        candidates: &[adaptgear::kernels::KernelKind],
                        chosen: &str| {
            for &k in candidates {
                let c = kernel_cost_density(k, matrix, w, d.community, gpu, rho);
                let mark = if k.as_str() == chosen { "*" } else { " " };
                let sparse = if benefits_from_sparse_features(k) { "s" } else { " " };
                println!(
                    "   {mark}{sparse} {role:<5} {:<12} {:>9.2} = launch {:.2} + max(compute {:.2}, memory {:.2})",
                    k.as_str(),
                    c.time_us,
                    c.launch_us,
                    c.compute_us,
                    c.memory_us
                );
            }
        };
        show("intra", &d.intra, candidates(Role::IntraSlot), plan.chosen.intra_str());
        show("inter", &d.inter, candidates(Role::Inter), plan.chosen.inter.as_str());
    }
    let fmt_times = |m: &std::collections::BTreeMap<String, f64>| {
        m.iter()
            .map(|(k, v)| format!("{k}={v:.2}"))
            .collect::<Vec<_>>()
            .join(" ")
    };
    println!(
        "  monitored means (us): intra[{}] inter[{}]",
        fmt_times(&plan.intra_times),
        fmt_times(&plan.inter_times)
    );
    println!(
        "  projected forward: {:.1}us aggregate + {:.1}us update + {:.1}us overhead = {:.1}us ({} launches)",
        plan.projected.aggregate_us,
        plan.projected.update_us,
        plan.projected.overhead_us,
        plan.projected.total_us(),
        plan.projected.kernel_launches
    );

    // ---- per-block density histogram over the intra block diagonal
    let profile = d.intra_block_profile();
    println!(
        "\nintra block density histogram ({} blocks of community {}):",
        profile.len(),
        d.community
    );
    let hist = profile.histogram(10);
    let peak = hist.iter().copied().max().unwrap_or(1).max(1);
    for (i, &count) in hist.iter().enumerate() {
        let lo = i as f64 / 10.0;
        let hi = (i + 1) as f64 / 10.0;
        let bar = "#".repeat((count * 40).div_ceil(peak).min(40));
        println!("  [{lo:.1},{hi:.1}) {count:>7} {bar}");
    }

    // ---- the per-class decision and what the alternatives would cost
    println!("\nassignment (density threshold {:.3}):", plan.assignment.threshold);
    for c in &plan.assignment.classes {
        println!(
            "  {:<12} -> {:<12} {:>7} blocks {:>9} nnz {:>10.2}us",
            c.class.as_str(),
            c.kernel.as_str(),
            c.blocks,
            c.nnz,
            c.time_us
        );
    }
    let kernels = plan
        .assignment
        .intra_kernels()
        .iter()
        .map(|k| k.as_str())
        .collect::<Vec<_>>()
        .join("+");
    println!(
        "intra classes: {} ({kernels})",
        plan.assignment.intra_classes().count()
    );
    // Prefer the provenance persisted WITH the decision (per-class
    // candidate costs, evaluated/rejected thresholds) — a plan loaded
    // from the store explains itself without re-running the sweep. Plans
    // from before provenance existed fall back to a live re-sweep.
    match &plan.assignment.provenance {
        Some(p) => {
            println!("\nthreshold sweep (persisted with the plan):");
            print!("{}", p.render());
        }
        None => {
            let sweep = adaptgear::plan::hybrid::sweep_with_density(
                &profile,
                &d.inter,
                &widths,
                bucket.edges,
                adaptgear::kernels::tile::tile_capacity(bucket.blocks, d.community),
                gpu,
                rho,
            );
            println!(
                "intra+inter simulated (re-swept; plan has no provenance): chosen {:.2}us | \
                 all-dense_block {:.2}us | all-csr_intra {:.2}us",
                plan.assignment.total_cost_us(),
                sweep.all_dense_us,
                sweep.all_sparse_us
            );
        }
    }
}

/// The monitoring planner for a clock; wall needs a live engine.
fn monitor_planner<'e>(
    clock: Clock,
    gpu: &'static GpuModel,
    repeats: usize,
    engine: Option<&'e Engine>,
) -> Result<Box<dyn Planner + 'e>> {
    Ok(match clock {
        Clock::Sim => Box::new(MonitorPlanner::sim(gpu, repeats)),
        Clock::Wall => {
            let engine = engine.context("--clock wall needs the artifacts engine")?;
            Box::new(MonitorPlanner::wall(engine, repeats).gpu(gpu))
        }
    })
}

/// The single `--planner` x `--clock` dispatch shared by the `plan` and
/// `train` subcommands.
fn build_planner<'e>(
    kind: &str,
    clock: Clock,
    gpu: &'static GpuModel,
    repeats: usize,
    engine: Option<&'e Engine>,
    store: PlanStore,
    read_only: bool,
) -> Result<Box<dyn Planner + 'e>> {
    Ok(match kind {
        "sim" => Box::new(SimCostPlanner::new(gpu)),
        "monitor" => monitor_planner(clock, gpu, repeats, engine)?,
        "cached" => {
            let inner = monitor_planner(clock, gpu, repeats, engine)?;
            if read_only {
                Box::new(CachedPlanner::read_only(store, inner))
            } else {
                Box::new(CachedPlanner::new(store, inner))
            }
        }
        other => bail!("--planner must be cached|monitor|sim, got {other}"),
    })
}

/// Build the planner the `train` subcommand asked for.
fn planner_from_args<'e>(args: &Args, engine: &'e Engine) -> Result<Box<dyn Planner + 'e>> {
    let gpu: &'static GpuModel = args.get_or("gpu", "a100").parse()?;
    let clock: Clock = args.get_or("clock", "sim").parse()?;
    let repeats = args.get_usize("monitor-repeats", 3);
    build_planner(
        args.get_or("planner", "monitor"),
        clock,
        gpu,
        repeats,
        Some(engine),
        PlanStore::in_artifacts(&engine.manifest.dir),
        false,
    )
}

fn cmd_train(args: &Args) -> Result<()> {
    if args.flag("sampled") {
        return cmd_train_sampled(args);
    }
    let name = args.get("dataset").unwrap_or("cora");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model: ModelKind = args.get_or("model", "gcn").parse()?;
    let scale = args.get("scale").map(|s| s.parse::<f64>()).transpose()?;

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={} artifacts={}", engine.platform(), engine.manifest.artifacts.len());

    let planner = planner_from_args(args, &engine)?;
    let mut run = Run::new(&engine)
        .dataset(spec)
        .model(model)
        .steps(args.get_usize("steps", 200))
        .lr(args.get_f64("lr", 0.05) as f32)
        .seed(args.get_u64("seed", 0))
        .planner(planner);
    if let Some(s) = scale {
        run = run.scale(s);
    }
    let report = run.train()?;
    println!(
        "dataset={} scale={:.4} vertices={} edges={} bucket={}",
        report.dataset, report.scale, report.vertices, report.edges, report.train.bucket
    );
    let plan = &report.train.plan;
    println!(
        "preprocess: reorder {:.3}s decompose {:.3}s | plan[{}{}]: {} after {} monitor iters ({:.1}us overhead)",
        report.preprocess.reorder_secs,
        report.preprocess.decompose_secs,
        plan.provenance.planner,
        if plan.provenance.cached { ", cache hit" } else { "" },
        plan.chosen,
        plan.monitor_iters,
        plan.monitor_overhead_us,
    );
    let losses = &report.train.losses;
    let every = (losses.len() / 10).max(1);
    for (i, l) in losses.iter().enumerate() {
        if i % every == 0 || i + 1 == losses.len() {
            println!("step {i:>5}  loss {l:.5}");
        }
    }
    println!(
        "final loss {:.5} (from {:.5}) | mean step {:.2}ms | compile {:.2}s pack {:.3}s",
        report.train.final_loss(),
        losses.first().copied().unwrap_or(f32::NAN),
        report.train.mean_step_secs() * 1e3,
        report.train.compile_secs,
        report.train.pack_secs,
    );
    Ok(())
}

/// `train --sampled`: mini-batch neighbor-sampled training. Batches are
/// planned through the amortized profile-keyed cache and execute on the
/// PJRT artifacts when they exist, else on the native CPU backend — so
/// the sampled loop runs end to end on a bare checkout.
fn cmd_train_sampled(args: &Args) -> Result<()> {
    use adaptgear::coordinator::{
        apply_perm, preprocess, train_sampled, SampleConfig, SampledBackend,
        SampledTrainReport, TrainConfig,
    };
    use adaptgear::partition::Reorder;
    use adaptgear::sample::parse_fanouts;

    let name = args.get("dataset").unwrap_or("cora");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model: ModelKind = args.get_or("model", "gcn").parse()?;
    let fanouts = parse_fanouts(args.get_or("fanout", "10,10"))?;
    let scfg = SampleConfig {
        fanouts,
        batch_size: args.get_usize("batch-size", 256),
        epochs: args.get_usize("epochs", 1),
        reorder: Reorder::Metis,
        topk: args.get("topk").map(|s| s.parse::<usize>()).transpose()?,
    };
    let cfg = TrainConfig {
        model,
        steps: 0, // sampled training budgets in epochs, not steps
        lr: args.get_f64("lr", 0.05) as f32,
        seed: args.get_u64("seed", 0),
    };
    let scale_override = args.get("scale").map(|s| s.parse::<f64>()).transpose()?;

    let print_report = |report: &SampledTrainReport, scfg: &SampleConfig| {
        for (e, mean) in report.epoch_mean_loss.iter().enumerate() {
            println!("epoch {e:>3}  mean loss {mean:.5}");
        }
        println!(
            "sampled training [{}]: {} epochs (fanout {}, batch {}{}) = {} batches | final loss {:.5}",
            report.backend,
            report.epochs,
            scfg.fanouts
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join(","),
            scfg.batch_size,
            match scfg.topk {
                Some(k) => format!(", topk {k}"),
                None => String::new(),
            },
            report.batches,
            report.final_loss(),
        );
        println!(
            "plan cache: {} hits / {} misses (hit rate {:.2})",
            report.plan_hits,
            report.plan_misses,
            report.plan_hit_rate(),
        );
        println!("stages: {}", report.stages.render());
        if report.epoch_stages.len() > 1 {
            for (e, es) in report.epoch_stages.iter().enumerate() {
                println!("  epoch {e:>3}  {}", es.render());
            }
        }
    };

    match Engine::new(artifacts_dir(args)) {
        Ok(engine) => {
            println!(
                "platform={} artifacts={}",
                engine.platform(),
                engine.manifest.artifacts.len()
            );
            // Unlike full-graph training, the FULL graph does not need to
            // fit an AOT bucket — only each sampled batch does (fitted
            // per batch inside train_sampled). So no pipeline::stage /
            // auto-scale-to-bucket here: materialize at the requested
            // scale and preprocess with the manifest's community width.
            let scale = scale_override
                .unwrap_or_else(|| (50_000.0 / spec.vertices as f64).min(1.0));
            let data = spec.build_scaled(scale, cfg.seed);
            let (d, times) = preprocess(
                Strategy::AdaptGear,
                &data.graph,
                pipeline::propagation_for(model),
                engine.manifest.community,
                cfg.seed,
            );
            println!(
                "dataset={} scale={:.4} vertices={} edges={} | reorder {:.3}s decompose {:.3}s",
                spec.name,
                scale,
                data.graph.n,
                data.graph.directed_edge_count(),
                times.reorder_secs,
                times.decompose_secs
            );
            let f_data = engine
                .manifest
                .buckets
                .values()
                .map(|b| b.features)
                .max()
                .context("manifest has no buckets")?;
            let (x, labels) = apply_perm(&d.perm, &data.features(f_data), &data.labels(), f_data);
            let mut backend = SampledBackend::Pjrt(&engine);
            let report = train_sampled(&mut backend, &d, &x, f_data, &labels, &cfg, &scfg)?;
            print_report(&report, &scfg);
        }
        Err(e) => {
            println!("artifacts unavailable ({e:#}); running the native CPU backend");
            let scale =
                scale_override.unwrap_or_else(|| (4096.0 / spec.vertices as f64).min(1.0));
            let data = spec.build_scaled(scale, cfg.seed);
            let (d, times) = preprocess(
                Strategy::AdaptGear,
                &data.graph,
                pipeline::propagation_for(model),
                datasets::COMMUNITY,
                cfg.seed,
            );
            println!(
                "dataset={} scale={:.4} vertices={} edges={} | reorder {:.3}s decompose {:.3}s",
                spec.name,
                scale,
                data.graph.n,
                data.graph.directed_edge_count(),
                times.reorder_secs,
                times.decompose_secs
            );
            let f_data = 32;
            let (x, labels) = apply_perm(&d.perm, &data.features(f_data), &data.labels(), f_data);
            let mut backend = SampledBackend::Native {
                hidden: 32,
                classes: spec.classes.clamp(2, 8),
            };
            let report = train_sampled(&mut backend, &d, &x, f_data, &labels, &cfg, &scfg)?;
            print_report(&report, &scfg);
        }
    }
    Ok(())
}

/// Closed-loop serving run: deploy (plan + train + warm) a model through
/// the registry — the plan comes from the persistent cache when warm —
/// then drive the micro-batched event loop with the synthetic load
/// generator and print the SLO report.
fn cmd_serve(args: &Args) -> Result<()> {
    use adaptgear::serve::{loadgen, LoadGenConfig, ModelRegistry, ServeConfig, ServeSession};
    use std::time::Duration;

    let name = args.get_or("dataset", "citeseer");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model: ModelKind = args.get_or("model", "gcn").parse()?;
    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 16),
        max_wait: Duration::from_micros(args.get_u64("max-wait-us", 2000)),
        queue_depth: args.get_usize("queue-depth", 256),
    };
    let load = LoadGenConfig {
        requests: args.get_usize("requests", 500),
        clients: args.get_usize("clients", 32),
        seed: args.get_u64("seed", 99),
        ..Default::default()
    };

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={} artifacts={}", engine.platform(), engine.manifest.artifacts.len());

    let mut registry = ModelRegistry::new();
    let deployment = format!("{}-{}", spec.name, model.as_str());
    let dep = Run::new(&engine)
        .dataset(spec)
        .model(model)
        .steps(args.get_usize("steps", 60))
        .seed(args.get_u64("train-seed", 0))
        .deploy_as(&mut registry, deployment.clone())?;
    println!(
        "deployed {:?}: {} vertices, kernels {} ({} intra classes, {} monitor iters{}), final loss {:.3}, forward warmed in {:.2}s",
        dep.name,
        dep.n,
        dep.chosen(),
        dep.assignment().intra_classes().count(),
        dep.plan.monitor_iters,
        if dep.plan.provenance.cached { ", plan cache hit" } else { "" },
        dep.final_loss,
        dep.warm_secs
    );
    let (n, f_data) = (dep.n, dep.f_data);

    println!(
        "serving: {} requests from {} closed-loop clients (max-batch {}, max-wait {:?}, queue depth {})",
        load.requests, load.clients, cfg.max_batch, cfg.max_wait, cfg.queue_depth
    );
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, deployment, n, f_data, load);
    let report = session.run()?;
    let summary = gen.join();

    println!("\n{}", report.render());
    println!(
        "clients: sent {} answered {} shed {} failed {}",
        summary.sent, summary.answered, summary.shed, summary.failed
    );
    if report.forward_calls < report.served {
        println!(
            "micro-batching amortized {} requests over {} artifact executions ({:.2}x)",
            report.served,
            report.forward_calls,
            report.served as f64 / report.forward_calls.max(1) as f64
        );
    }
    Ok(())
}

/// Deterministic streaming-mutation workload (DESIGN.md Sec. 12):
/// decompose + plan a dataset, densify one diagonal block through the
/// delta log while sprinkling weight-only updates elsewhere, let the
/// drift tracker pick out the moved class(es), re-plan online, and
/// check the swapped plan's forward against a cold full re-plan.
/// Engine-free: native kernels + the cost simulator.
fn cmd_stream(args: &Args) -> Result<()> {
    use adaptgear::kernels::native::aggregate_assignment;
    use adaptgear::stream::{DeltaOp, StreamConfig, StreamSession};
    use adaptgear::util::rng::Rng;

    let name = args.get_or("dataset", "planted-mixed");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model: ModelKind = args.get_or("model", "gcn").parse()?;
    let gpu: &'static GpuModel = args.get_or("gpu", "a100").parse()?;
    let scale = args.get_f64("scale", f64::min(1.0, 1024.0 / spec.vertices as f64));
    let community = args.get_usize("community", 16);
    let seed = args.get_u64("seed", 0);
    let reweights = args.get_usize("reweights", 200);
    let target_block = args.get_usize("target-block", 1);

    let data = spec.build_scaled(scale, seed);
    let (d, _times) = adaptgear::coordinator::preprocess(
        Strategy::AdaptGear,
        &data.graph,
        Propagation::GcnNormalized,
        community,
        seed,
    );
    let n = d.graph.n;
    let nnz = d.intra.nnz() + d.inter.nnz();
    // Synthetic bucket with headroom for the mutation workload — stream
    // planning is simulator-driven, no AOT manifest needed.
    let bucket = BucketInfo {
        name: format!("bstream{n}"),
        vertices: n + community,
        edges: nnz + community * community + 64,
        features: 16,
        hidden: 16,
        classes: spec.classes,
        blocks: (n + community).div_ceil(community),
    };
    let mut req = PlanRequest::new(&d, model, &bucket);
    req.dataset = spec.name.to_string();
    let plan = SimCostPlanner::new(gpu).plan(&req)?;
    println!(
        "dataset={} scale={scale:.4} vertices={n} edges={nnz} | plan {} ({} classes, threshold {})",
        spec.name,
        plan.chosen,
        plan.assignment.classes.len(),
        plan.assignment.threshold,
    );

    let mut cfg = StreamConfig::new(model, gpu);
    cfg.compact_ratio = args.get_f64("compact-ratio", 0.25);
    cfg.dataset = spec.name.to_string();
    let total_classes = plan.assignment.classes.len();
    let mut session = StreamSession::new(&d, plan, bucket.clone(), cfg);

    // Deterministic workload: densify one diagonal block to near-clique...
    let lo = (target_block * community).min(n.saturating_sub(community)) as u32;
    let hi = (lo as usize + community).min(n) as u32;
    let mut inserted = 0usize;
    for u in lo..hi {
        for v in (u + 1)..hi {
            inserted += session.apply(DeltaOp::InsertEdge { u, v, w: 0.3 })?.changed.len();
        }
    }
    // ...and touch only weights everywhere else (structurally invisible).
    let trips = session.overlay().to_csr().to_triplets();
    for (k, &(r, c, w)) in trips.iter().step_by(7).take(reweights).enumerate() {
        session.apply(DeltaOp::Reweight { u: r, v: c, w: w + 0.001 * (k % 3) as f32 })?;
    }
    println!(
        "applied {} deltas ({inserted} inserted entries in block {target_block}, {} reweights); \
         overlay: {} staged rows, version {}",
        session.log().len(),
        reweights.min(trips.len().div_ceil(7)),
        session.overlay().staged_rows(),
        session.overlay().version(),
    );

    let Some(r) = session.maybe_replan()? else {
        bail!("mutation workload produced no drift — densify more (lower --scale?)");
    };
    let drifted: Vec<&str> = r.drifted.iter().map(|c| c.as_str()).collect();
    println!(
        "drift: classes [{}] moved ({} of {} plan classes), {}",
        drifted.join(", "),
        r.drifted.len(),
        total_classes,
        if r.swept { "full sweep (cached decision inadmissible)" } else { "adapted cached decision" },
    );
    println!(
        "plan swapped: {} -> {} (graph version {})",
        r.old_fingerprint, r.plan.fingerprint, r.graph_version
    );

    // Numeric check: the swapped plan's aggregation must match both a
    // cold full re-plan and the whole-graph reference on the mutated CSR.
    let f = 8;
    let mut rng = Rng::new(seed ^ 0xf00d);
    let x: Vec<f32> = (0..r.d.graph.n * f).map(|_| rng.normal_f32()).collect();
    let swapped = aggregate_assignment(&r.d, &r.plan.assignment, &x, f)?;
    let mut cold_req = PlanRequest::new(&r.d, model, &bucket);
    cold_req.graph_version = r.graph_version;
    let cold = SimCostPlanner::new(gpu).plan(&cold_req)?;
    let colded = aggregate_assignment(&r.d, &cold.assignment, &x, f)?;
    let whole = r.d.whole().spmm(&x, f);
    let max_err = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max)
    };
    let (vs_cold, vs_whole) = (max_err(&swapped, &colded), max_err(&swapped, &whole));
    println!("forward max err: vs cold replan {vs_cold:.2e}, vs whole-graph spmm {vs_whole:.2e}");
    if vs_cold > 1e-4 || vs_whole > 1e-4 {
        bail!("swapped plan diverged from the cold re-plan (>{:.0e})", 1e-4);
    }
    println!("counters: {}", adaptgear::obs::snapshot().counters_line());
    Ok(())
}

/// The benchmark subsystem front end (DESIGN.md Sec. 9): run the fixed
/// workload suites and emit `BENCH_*.json`, or — in `--validate` /
/// `--check` mode — schema-check / regression-gate already-emitted
/// reports without re-running anything.
fn cmd_bench(args: &Args) -> Result<()> {
    use adaptgear::bench::{self, BenchConfig, Tolerance};
    use std::path::Path;

    let out = std::path::PathBuf::from(args.get_or("out", "."));
    let suites: Vec<&str> = match args.get_or("suite", "all") {
        "all" => bench::SUITES.to_vec(),
        one => vec![one],
    };
    for &s in &suites {
        if !bench::SUITES.contains(&s) {
            bail!("--suite must be all|{}, got {s:?}", bench::SUITES.join("|"));
        }
    }

    if args.flag("validate") {
        let reports = bench::validate_dir(&out, &suites)?;
        for r in &reports {
            println!(
                "{}: schema v{} ok ({} metrics{})",
                adaptgear::bench::BenchReport::file_name(&r.suite),
                adaptgear::bench::SCHEMA_VERSION,
                r.metrics.len(),
                if r.quick { ", quick profile" } else { "" },
            );
        }
        return Ok(());
    }

    if args.flag("check") {
        let baseline = args
            .get("baseline")
            .context("bench --check requires --baseline DIR")?;
        let tol = Tolerance { rel: args.get_f64("tolerance", Tolerance::default().rel) };
        let outcome = bench::check_dirs(Path::new(baseline), &out, &suites, tol)?;
        print!("{}", outcome.render());
        if outcome.failures() > 0 {
            bail!(
                "{} metric(s) regressed beyond the {:.0}% tolerance",
                outcome.failures(),
                tol.rel * 100.0
            );
        }
        println!("bench check passed");
        return Ok(());
    }

    let cfg = BenchConfig {
        quick: args.flag("quick"),
        artifacts: artifacts_dir(args),
        out,
        seed: args.get_u64("seed", BenchConfig::default().seed),
    };
    bench::run_and_write(&suites, &cfg)?;
    Ok(())
}

/// Static invariant audit (DESIGN.md Sec. 13): run every registered
/// analyzer over whatever this checkout holds — the plan store, traces,
/// delta logs, bench reports — write `CHECK_report.json`, and exit
/// non-zero when any Error-severity lint fires. Engine-free by
/// construction: analyzers re-derive, replay, and reprice, but never
/// execute a training step.
fn cmd_check(args: &Args) -> Result<()> {
    use adaptgear::bench::BenchReport;
    use adaptgear::check::{self, CheckContext};
    use std::path::PathBuf;

    let artifacts = PathBuf::from(artifacts_dir(args));
    let deny_warn = match args.get("deny") {
        None => false,
        Some("warn") => true,
        Some(other) => bail!("--deny accepts only 'warn', got {other:?}"),
    };
    // Flags select inputs explicitly; with no selection the audit runs
    // over what it can discover (plans dir, ./TRACE_*.json,
    // ./BENCH_*.json), so a bare `adaptgear check` audits everything
    // present and skips — with Info diagnostics — everything absent.
    let plans = args.flag("plans") || artifacts.join("plans").is_dir();
    let mut traces: Vec<PathBuf> = args.get("trace").map(PathBuf::from).into_iter().collect();
    if traces.is_empty() {
        if let Ok(entries) = std::fs::read_dir(".") {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("TRACE_") && name.ends_with(".json") {
                    traces.push(e.path());
                }
            }
        }
        traces.sort();
    }
    let deltas: Vec<PathBuf> = args.get("delta").map(PathBuf::from).into_iter().collect();
    let bench_dir = match args.get("bench") {
        Some(d) => Some(PathBuf::from(d)),
        None => {
            let cwd = PathBuf::from(".");
            adaptgear::bench::SUITES
                .iter()
                .any(|s| BenchReport::path_in(&cwd, s).exists())
                .then_some(cwd)
        }
    };
    let baseline = args.get("baseline").map(PathBuf::from);

    let ctx = CheckContext { artifacts, plans, traces, deltas, bench_dir, baseline };
    let report = check::run_all(&ctx, deny_warn);
    let out = args.get_or("out", "CHECK_report.json");
    std::fs::write(out, json::write(&report.to_json()))
        .with_context(|| format!("writing {out}"))?;
    print!("{}", report.render());
    println!("report: {out}");
    if report.errors() > 0 {
        bail!("{} error diagnostic(s) — see {out}", report.errors());
    }
    Ok(())
}

/// Smoke check: every kernel artifact computes the same aggregate as the
/// native Rust kernels on a random decomposed graph.
fn cmd_selftest(args: &Args) -> Result<()> {
    use adaptgear::graph::generate::planted_partition;
    use adaptgear::kernels::pack;
    use adaptgear::kernels::KernelKind;
    use adaptgear::util::rng::Rng;

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={}", engine.platform());
    let bucket = engine
        .manifest
        .buckets
        .values()
        .min_by_key(|b| b.vertices)
        .context("no buckets in manifest")?
        .clone();

    let mut rng = Rng::new(7);
    let g = planted_partition(bucket.vertices / 2, engine.manifest.community, 0.3, 0.02, &mut rng);
    let d = adaptgear::partition::Decomposition::build(
        &g,
        adaptgear::partition::Reorder::Metis,
        Propagation::GcnNormalized,
        engine.manifest.community,
        1,
    );
    let f = bucket.features;
    let x: Vec<f32> = (0..d.graph.n * f).map(|_| rng.normal_f32()).collect();
    let x_packed = pack::pack_features(&x, d.graph.n, f, &bucket)?;

    for (kind, matrix) in [
        (KernelKind::CsrIntra, &d.intra),
        (KernelKind::DenseBlock, &d.intra),
        (KernelKind::CsrInter, &d.inter),
        (KernelKind::Coo, &d.inter),
    ] {
        let name = adaptgear::runtime::Manifest::kernel_name(kind.as_str(), &bucket.name);
        let mut ops = pack::pack_kernel_operands(kind, matrix, d.community, &bucket)?;
        ops.push(x_packed.clone());
        let out = engine.run(&name, &ops)?;
        let y: Vec<f32> = out[0].to_vec()?;
        let expect = matrix.spmm(&x, f);
        let mut max_err = 0f32;
        for r in 0..d.graph.n {
            for j in 0..f {
                max_err = max_err.max((y[r * f + j] - expect[r * f + j]).abs());
            }
        }
        println!("{name:<28} max_err={max_err:.2e}");
        if max_err > 1e-3 {
            bail!("{name} disagrees with native kernel (max_err {max_err})");
        }
    }
    println!("selftest OK");
    Ok(())
}
