//! AdaptGear CLI — the leader entrypoint.
//!
//! ```text
//! adaptgear datasets                         # Table 1 registry + measured stats
//! adaptgear decompose --dataset cora         # reorder + split, print density report
//! adaptgear train --dataset cora --model gcn --steps 200 [--clock wall|sim]
//! adaptgear serve --dataset citeseer --requests 500 --max-batch 16
//!                                            # micro-batched serving + SLO report
//! adaptgear selftest                         # artifact <-> runtime smoke check
//! ```
//!
//! Figure regeneration lives in the bench harness: `cargo bench --bench
//! figures -- <fig2b|fig3a|...|all>`.

use anyhow::{bail, Context, Result};

use adaptgear::coordinator::{pipeline, Clock, ModelKind, Strategy, TrainConfig};
use adaptgear::graph::{datasets, stats};
use adaptgear::gpusim::GpuModel;
use adaptgear::partition::Propagation;
use adaptgear::runtime::Engine;
use adaptgear::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "datasets" => cmd_datasets(&args),
        "decompose" => cmd_decompose(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "adaptgear — adaptive subgraph-level GNN training (CF'23 reproduction)\n\n\
         USAGE: adaptgear <command> [options]\n\n\
         COMMANDS:\n\
         \x20 datasets                          list the Table 1 registry\n\
         \x20 decompose --dataset NAME [--scale S] [--community C]\n\
         \x20                                   reorder + split; print density report\n\
         \x20 train --dataset NAME [--model gcn|gin] [--steps N] [--lr F]\n\
         \x20       [--clock sim|wall] [--gpu a100|v100] [--scale S] [--seed N]\n\
         \x20 serve --dataset NAME [--model gcn|gin] [--requests N] [--clients N]\n\
         \x20       [--max-batch N] [--max-wait-us N] [--queue-depth N] [--steps N]\n\
         \x20       [--seed N (loadgen)] [--train-seed N]\n\
         \x20                                   micro-batched serving loop + SLO report\n\
         \x20 selftest                          verify artifacts + runtime numerics\n\n\
         Figures: cargo bench --bench figures -- <fig2b|fig3a|fig3b|fig4|fig8|\n\
         \x20        fig9|fig10|fig11|fig12|table2|overhead|all>"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn cmd_datasets(_args: &Args) -> Result<()> {
    println!(
        "{:<28} {:>9} {:>9} {:>6} {:>7} {:>10}",
        "dataset", "#Vertex", "#Edge", "#Feat", "#Class", "density"
    );
    for d in datasets::DATASETS {
        println!(
            "{:<28} {:>9} {:>9} {:>6} {:>7} {:>10.2e}",
            d.name, d.vertices, d.edges, d.features, d.classes, d.density()
        );
    }
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?;
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let scale = args.get_f64("scale", f64::min(0.05, 20_000.0 / spec.vertices as f64));
    let community = args.get_usize("community", 16);
    let seed = args.get_u64("seed", 0);

    let data = spec.build_scaled(scale, seed);
    println!(
        "dataset={} scale={:.4} vertices={} edges={}",
        spec.name,
        scale,
        data.graph.n,
        data.graph.directed_edge_count()
    );

    let before = stats::density_split(&data.graph, community);
    let (d, times) = adaptgear::coordinator::preprocess(
        Strategy::AdaptGear,
        &data.graph,
        Propagation::GcnNormalized,
        community,
        seed,
    );
    let after = stats::density_split(&d.graph, community);

    println!("reorder: {:.3}s  decompose: {:.3}s", times.reorder_secs, times.decompose_secs);
    println!(
        "density   before: full={:.2e} intra={:.2e} inter={:.2e}",
        before.full, before.intra, before.inter
    );
    println!(
        "density   after:  full={:.2e} intra={:.2e} inter={:.2e}  (intra edges {} -> {})",
        after.full, after.intra, after.inter, before.intra_edges, after.intra_edges
    );
    println!("\nadjacency heat map after reordering (dark = dense):");
    print!("{}", stats::render_heat_grid(&stats::adjacency_heat_grid(&d.graph, 24)));
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("cora");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model = ModelKind::parse(args.get_or("model", "gcn")).context("--model gcn|gin")?;
    let clock = match args.get_or("clock", "sim") {
        "sim" => Clock::Sim,
        "wall" => Clock::Wall,
        other => bail!("--clock must be sim or wall, got {other}"),
    };
    let gpu = GpuModel::by_name(args.get_or("gpu", "a100")).context("--gpu a100|v100")?;
    let cfg = TrainConfig {
        model,
        steps: args.get_usize("steps", 200),
        lr: args.get_f64("lr", 0.05) as f32,
        monitor_repeats: args.get_usize("monitor-repeats", 3),
        clock,
        gpu,
        seed: args.get_u64("seed", 0),
    };
    let scale = args.get("scale").map(|s| s.parse::<f64>()).transpose()?;

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={} artifacts={}", engine.platform(), engine.manifest.artifacts.len());

    let report = pipeline::run(&engine, spec, &cfg, scale)?;
    println!(
        "dataset={} scale={:.4} vertices={} edges={} bucket={}",
        report.dataset, report.scale, report.vertices, report.edges, report.train.bucket
    );
    println!(
        "preprocess: reorder {:.3}s decompose {:.3}s | selector: chose {} after {} monitor iters ({:.1}us overhead)",
        report.preprocess.reorder_secs,
        report.preprocess.decompose_secs,
        report.train.chosen,
        report.train.selector.monitor_iters,
        report.train.selector.monitor_overhead_us,
    );
    let losses = &report.train.losses;
    let every = (losses.len() / 10).max(1);
    for (i, l) in losses.iter().enumerate() {
        if i % every == 0 || i + 1 == losses.len() {
            println!("step {i:>5}  loss {l:.5}");
        }
    }
    println!(
        "final loss {:.5} (from {:.5}) | mean step {:.2}ms | compile {:.2}s pack {:.3}s",
        report.train.final_loss(),
        losses.first().copied().unwrap_or(f32::NAN),
        report.train.mean_step_secs() * 1e3,
        report.train.compile_secs,
        report.train.pack_secs,
    );
    Ok(())
}

/// Closed-loop serving run: deploy (train + warm) a model through the
/// registry, then drive the micro-batched event loop with the synthetic
/// load generator and print the SLO report.
fn cmd_serve(args: &Args) -> Result<()> {
    use adaptgear::serve::{
        loadgen, DeploymentSpec, LoadGenConfig, ModelRegistry, ServeConfig, ServeSession,
    };
    use std::time::Duration;

    let name = args.get_or("dataset", "citeseer");
    let spec = datasets::find(name).with_context(|| format!("unknown dataset {name:?}"))?;
    let model = ModelKind::parse(args.get_or("model", "gcn")).context("--model gcn|gin")?;
    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 16),
        max_wait: Duration::from_micros(args.get_u64("max-wait-us", 2000)),
        queue_depth: args.get_usize("queue-depth", 256),
    };
    let load = LoadGenConfig {
        requests: args.get_usize("requests", 500),
        clients: args.get_usize("clients", 32),
        seed: args.get_u64("seed", 99),
        ..Default::default()
    };

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={} artifacts={}", engine.platform(), engine.manifest.artifacts.len());

    let mut registry = ModelRegistry::new();
    let deployment = format!("{}-{}", spec.name, model.as_str());
    let mut dspec = DeploymentSpec::new(deployment.clone(), spec, model);
    dspec.steps = args.get_usize("steps", 60);
    dspec.seed = args.get_u64("train-seed", 0);
    let dep = registry.deploy(&engine, dspec)?;
    println!(
        "deployed {:?}: {} vertices, kernels {}, final loss {:.3}, forward warmed in {:.2}s",
        dep.name, dep.n, dep.chosen, dep.final_loss, dep.warm_secs
    );
    let (n, f_data) = (dep.n, dep.f_data);

    println!(
        "serving: {} requests from {} closed-loop clients (max-batch {}, max-wait {:?}, queue depth {})",
        load.requests, load.clients, cfg.max_batch, cfg.max_wait, cfg.queue_depth
    );
    let (session, client) = ServeSession::new(&engine, &mut registry, cfg);
    let gen = loadgen::spawn(client, deployment, n, f_data, load);
    let report = session.run()?;
    let summary = gen.join();

    println!("\n{}", report.render());
    println!(
        "clients: sent {} answered {} shed {} failed {}",
        summary.sent, summary.answered, summary.shed, summary.failed
    );
    if report.forward_calls < report.served {
        println!(
            "micro-batching amortized {} requests over {} artifact executions ({:.2}x)",
            report.served,
            report.forward_calls,
            report.served as f64 / report.forward_calls.max(1) as f64
        );
    }
    Ok(())
}

/// Smoke check: every kernel artifact computes the same aggregate as the
/// native Rust kernels on a random decomposed graph.
fn cmd_selftest(args: &Args) -> Result<()> {
    use adaptgear::graph::generate::planted_partition;
    use adaptgear::kernels::pack;
    use adaptgear::kernels::KernelKind;
    use adaptgear::util::rng::Rng;

    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform={}", engine.platform());
    let bucket = engine
        .manifest
        .buckets
        .values()
        .min_by_key(|b| b.vertices)
        .context("no buckets in manifest")?
        .clone();

    let mut rng = Rng::new(7);
    let g = planted_partition(bucket.vertices / 2, engine.manifest.community, 0.3, 0.02, &mut rng);
    let d = adaptgear::partition::Decomposition::build(
        &g,
        adaptgear::partition::Reorder::Metis,
        Propagation::GcnNormalized,
        engine.manifest.community,
        1,
    );
    let f = bucket.features;
    let x: Vec<f32> = (0..d.graph.n * f).map(|_| rng.normal_f32()).collect();
    let x_packed = pack::pack_features(&x, d.graph.n, f, &bucket)?;

    for (kind, matrix) in [
        (KernelKind::CsrIntra, &d.intra),
        (KernelKind::DenseBlock, &d.intra),
        (KernelKind::CsrInter, &d.inter),
        (KernelKind::Coo, &d.inter),
    ] {
        let name = adaptgear::runtime::Manifest::kernel_name(kind.as_str(), &bucket.name);
        let mut ops = pack::pack_kernel_operands(kind, matrix, d.community, &bucket)?;
        ops.push(x_packed.clone());
        let out = engine.run(&name, &ops)?;
        let y: Vec<f32> = out[0].to_vec()?;
        let expect = matrix.spmm(&x, f);
        let mut max_err = 0f32;
        for r in 0..d.graph.n {
            for j in 0..f {
                max_err = max_err.max((y[r * f + j] - expect[r * f + j]).abs());
            }
        }
        println!("{name:<28} max_err={max_err:.2e}");
        if max_err > 1e-3 {
            bail!("{name} disagrees with native kernel (max_err {max_err})");
        }
    }
    println!("selftest OK");
    Ok(())
}
