//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client (lazily, cached), and executes them with validated
//! operands.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// Compiled-executable cache entry with compile-time telemetry.
pub struct LoadedArtifact {
    pub exe: xla::PjRtLoadedExecutable,
    pub compile_secs: f64,
}

/// The engine owns the PJRT client, the manifest, and the executable cache.
///
/// PJRT handles are not `Send`; the engine lives on the coordinator thread
/// (Python never appears here — artifacts were lowered at build time).
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
}

impl Engine {
    /// Create an engine over an artifacts directory (must contain
    /// `manifest.json`; run `make artifacts` to produce it).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(hit) = self.cache.borrow().get(name) {
            return Ok(hit.clone());
        }
        let meta = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of artifact {name}"))?;
        let loaded = Rc::new(LoadedArtifact { exe, compile_secs: t0.elapsed().as_secs_f64() });
        self.cache.borrow_mut().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Pre-compile an artifact without executing it (serve startup warms
    /// forward executables so the first request pays no XLA compile).
    /// Returns the artifact's compile time — 0-cost if already cached.
    pub fn warm(&self, name: &str) -> Result<f64> {
        Ok(self.load(name)?.compile_secs)
    }

    /// Validate operands against the manifest and execute; returns output
    /// literals in manifest order.
    pub fn run(&self, name: &str, args: &[Tensor]) -> Result<Vec<xla::Literal>> {
        let meta = self.manifest.get(name)?;
        self.validate_args(meta, args)?;
        let loaded = self.load(name)?;
        let literals = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let outputs = loaded.exe.execute::<xla::Literal>(&literals)?;
        Self::untuple(outputs, meta.outputs.len())
    }

    /// Execute an already-loaded artifact with pre-packed literals,
    /// skipping manifest validation — the training hot loop, where the
    /// decomposed outputs of one step are fed back as the next step's
    /// inputs without re-packing.
    pub fn run_literals(
        &self,
        loaded: &LoadedArtifact,
        args: &[&xla::Literal],
        n_outputs: usize,
    ) -> Result<Vec<xla::Literal>> {
        let outputs = loaded.exe.execute::<&xla::Literal>(args)?;
        Self::untuple(outputs, n_outputs)
    }

    fn validate_args(&self, meta: &ArtifactMeta, args: &[Tensor]) -> Result<()> {
        if args.len() != meta.inputs.len() {
            bail!(
                "artifact {} expects {} operands, got {}",
                meta.name,
                meta.inputs.len(),
                args.len()
            );
        }
        for (t, spec) in args.iter().zip(&meta.inputs) {
            t.validate(spec).with_context(|| format!("artifact {}", meta.name))?;
        }
        Ok(())
    }

    fn untuple(outputs: Vec<Vec<xla::PjRtBuffer>>, n: usize) -> Result<Vec<xla::Literal>> {
        let replica = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no replica outputs"))?;
        if replica.len() == 1 {
            // aot.py lowers with return_tuple=True, so the root is a tuple
            // even for single outputs; decompose it.
            let lit = replica[0].to_literal_sync()?;
            if lit.shape()?.is_tuple() {
                let parts = lit.to_tuple()?;
                if parts.len() != n {
                    bail!("tuple arity {} != expected {n}", parts.len());
                }
                return Ok(parts);
            }
            if n == 1 {
                return Ok(vec![lit]);
            }
            bail!("single non-tuple output buffer but {n} outputs expected");
        }
        if replica.len() == n {
            // PJRT untupled for us.
            return replica.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        bail!("unexpected output layout: {} buffers for {n} outputs", replica.len())
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Helpers for reading output literals.
pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = literal_f32(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}
