//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! request path. See DESIGN.md Sec. 5 for the dataflow.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{literal_f32, literal_scalar_f32, Engine, LoadedArtifact};
pub use manifest::{ArtifactKind, ArtifactMeta, BucketInfo, DType, Manifest, TensorSpec};
pub use tensor::Tensor;
