//! Host-side tensor values and packing into PJRT literals/buffers.

use anyhow::{bail, Result};

use super::manifest::{DType, TensorSpec};

/// A host tensor matched against a manifest `TensorSpec` before upload.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        Tensor::F32(data, shape.to_vec())
    }
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        Tensor::I32(data, shape.to_vec())
    }
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(d, _) => d.len(),
            Tensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            Tensor::I32(..) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            Tensor::F32(..) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Check this tensor against an operand spec (name used in errors only).
    pub fn validate(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("operand {}: dtype mismatch ({:?} vs {:?})", spec.name, self.dtype(), spec.dtype);
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "operand {}: shape mismatch ({:?} vs {:?})",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.len() != spec.element_count() {
            bail!("operand {}: element count mismatch", spec.name);
        }
        Ok(())
    }

    /// Convert to an xla literal (host copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(d, _) => xla::Literal::vec1(d),
            Tensor::I32(d, _) => xla::Literal::vec1(d),
        };
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn validation_accepts_matching() {
        let t = Tensor::f32(vec![0.0; 6], &[2, 3]);
        assert!(t.validate(&spec("x", &[2, 3], DType::F32)).is_ok());
    }

    #[test]
    fn validation_rejects_mismatches() {
        let t = Tensor::f32(vec![0.0; 6], &[2, 3]);
        assert!(t.validate(&spec("x", &[3, 2], DType::F32)).is_err());
        assert!(t.validate(&spec("x", &[2, 3], DType::I32)).is_err());
    }

    #[test]
    fn scalar_shape_is_empty() {
        let t = Tensor::scalar_f32(0.5);
        assert!(t.shape().is_empty());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn accessors() {
        let t = Tensor::i32(vec![1, 2], &[2]);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }
}
