//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` describes every AOT-lowered HLO module — its
//! operand names/shapes/dtypes in positional order, its outputs, and which
//! (model, intra-kernel, inter-kernel, bucket) variant it implements. The
//! coordinator selects executables purely through this index; it never
//! inspects HLO text.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Tensor dtype in the manifest (matches aot.py's F32/I32 tags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// One operand or result of an artifact.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.get("name").as_str().ok_or_else(|| anyhow!("tensor missing name"))?.to_string(),
            shape: v
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
            dtype: DType::parse(v.get("dtype").as_str().unwrap_or(""))?,
        })
    }
}

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// A single aggregation kernel in isolation (selector timing).
    Kernel,
    /// Model forward pass -> logits (serving).
    Forward,
    /// Fused fwd+bwd+SGD step (training).
    TrainStep,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "kernel" => Ok(ArtifactKind::Kernel),
            "forward" => Ok(ArtifactKind::Forward),
            "train_step" => Ok(ArtifactKind::TrainStep),
            other => bail!("unknown artifact kind {other:?}"),
        }
    }
}

/// Manifest entry for one HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: String,
    pub kind: ArtifactKind,
    pub bucket: String,
    /// For kernel artifacts: the kernel id. For model artifacts: empty.
    pub kernel: String,
    pub model: String,
    pub intra: String,
    pub inter: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Static-shape compilation bucket (mirrors python/compile/buckets.py).
#[derive(Debug, Clone)]
pub struct BucketInfo {
    pub name: String,
    pub vertices: usize,
    pub edges: usize,
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub blocks: usize,
}

/// The full parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub community: usize,
    pub buckets: BTreeMap<String, BucketInfo>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let community = root
            .get("community")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest missing community"))?;

        let mut buckets = BTreeMap::new();
        for (name, b) in root.get("buckets").as_obj().ok_or_else(|| anyhow!("missing buckets"))? {
            let req = |k: &str| {
                b.get(k).as_usize().ok_or_else(|| anyhow!("bucket {name} missing {k}"))
            };
            buckets.insert(
                name.clone(),
                BucketInfo {
                    name: name.clone(),
                    vertices: req("vertices")?,
                    edges: req("edges")?,
                    features: req("features")?,
                    hidden: req("hidden")?,
                    classes: req("classes")?,
                    blocks: req("blocks")?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").as_arr().ok_or_else(|| anyhow!("missing artifacts"))? {
            let name = a.get("name").as_str().ok_or_else(|| anyhow!("artifact missing name"))?;
            let meta = ArtifactMeta {
                name: name.to_string(),
                path: a.get("path").as_str().unwrap_or_default().to_string(),
                kind: ArtifactKind::parse(a.get("kind").as_str().unwrap_or(""))?,
                bucket: a.get("bucket").as_str().unwrap_or_default().to_string(),
                kernel: a.get("kernel").as_str().unwrap_or_default().to_string(),
                model: a.get("model").as_str().unwrap_or_default().to_string(),
                intra: a.get("intra").as_str().unwrap_or_default().to_string(),
                inter: a.get("inter").as_str().unwrap_or_default().to_string(),
                inputs: a
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
            };
            if !buckets.contains_key(&meta.bucket) {
                bail!("artifact {name} references unknown bucket {}", meta.bucket);
            }
            artifacts.insert(name.to_string(), meta);
        }
        Ok(Manifest { dir, community, buckets, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.path)
    }

    /// Name of a train-step artifact for a variant.
    pub fn train_name(model: &str, intra: &str, inter: &str, bucket: &str) -> String {
        format!("train_{model}_{intra}_{inter}_{bucket}")
    }

    /// Name of a forward artifact for a variant.
    pub fn fwd_name(model: &str, intra: &str, inter: &str, bucket: &str) -> String {
        format!("fwd_{model}_{intra}_{inter}_{bucket}")
    }

    /// Name of a kernel-only artifact.
    pub fn kernel_name(kernel: &str, bucket: &str) -> String {
        format!("kernel_{kernel}_{bucket}")
    }

    /// Smallest bucket that fits `vertices` padded vertices and `edges`
    /// padded edges (buckets ordered by capacity).
    pub fn fit_bucket(&self, vertices: usize, edges: usize) -> Option<&BucketInfo> {
        self.buckets
            .values()
            .filter(|b| b.vertices >= vertices && b.edges >= edges)
            .min_by_key(|b| b.vertices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "community": 16,
      "buckets": {"b256": {"vertices":256,"edges":1024,"features":32,
                            "hidden":32,"classes":8,"blocks":16}},
      "artifacts": [
        {"name":"kernel_coo_b256","path":"kernel_coo_b256.hlo.txt",
         "kind":"kernel","bucket":"b256","kernel":"coo",
         "inputs":[{"name":"inter_src","shape":[1024],"dtype":"i32"},
                    {"name":"x","shape":[256,32],"dtype":"f32"}],
         "outputs":[{"name":"y","shape":[256,32],"dtype":"f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.community, 16);
        let a = m.get("kernel_coo_b256").unwrap();
        assert_eq!(a.kind, ArtifactKind::Kernel);
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(a.inputs[1].shape, vec![256, 32]);
        assert_eq!(a.outputs[0].element_count(), 256 * 32);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn bucket_fitting() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.fit_bucket(200, 900).unwrap().name, "b256");
        assert!(m.fit_bucket(300, 10).is_none());
    }

    #[test]
    fn name_helpers() {
        assert_eq!(Manifest::train_name("gcn", "csr_intra", "coo", "b256"),
                   "train_gcn_csr_intra_coo_b256");
        assert_eq!(Manifest::kernel_name("dense_block", "b1024"),
                   "kernel_dense_block_b1024");
    }

    #[test]
    fn rejects_bad_bucket_reference() {
        let bad = SAMPLE.replace("\"bucket\":\"b256\"", "\"bucket\":\"zzz\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
