//! Iteration timeline assembly: compose kernel launches, dense update
//! GEMMs, result merges, and framework overheads into one training
//! iteration's simulated time.

use super::kernel_cost::KernelCost;
use super::model::GpuModel;

/// Cost of a dense GEMM `[m,k] @ [k,n]` on the vector pipeline (the
/// Update/MLP phase — identical across strategies, so it is modeled on the
/// same fp32 path for everyone).
pub fn gemm_us(m: usize, k: usize, n: usize, gpu: &GpuModel) -> f64 {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let bytes = ((m * k + k * n + m * n) * 4) as f64;
    gpu.launch_us + gpu.fp32_us(flops).max(gpu.stream_us(bytes))
}

/// Cost of an elementwise op over `elems` f32 values (bias/ReLU/etc.).
pub fn elementwise_us(elems: usize, gpu: &GpuModel) -> f64 {
    let bytes = (elems * 8) as f64; // read + write
    gpu.launch_us + gpu.stream_us(bytes)
}

/// Cost of merging partial aggregate results (PCGCN-style block-level
/// accumulation): one extra read+write of the output per merge.
pub fn merge_us(rows: usize, f: usize, gpu: &GpuModel) -> f64 {
    gpu.launch_us + gpu.stream_us((rows * f * 12) as f64) // 2 reads + 1 write
}

/// Accumulated cost of one training iteration.
#[derive(Debug, Clone, Default)]
pub struct IterationCost {
    pub aggregate_us: f64,
    pub update_us: f64,
    pub overhead_us: f64,
    pub l2_hits: u64,
    pub l2_accesses: u64,
    pub kernel_launches: usize,
}

impl IterationCost {
    pub fn add_kernel(&mut self, c: &KernelCost) {
        self.aggregate_us += c.time_us;
        self.l2_hits += c.l2_hits;
        self.l2_accesses += c.l2_accesses;
        self.kernel_launches += 1;
    }

    pub fn add_update(&mut self, us: f64) {
        self.update_us += us;
    }

    pub fn add_overhead(&mut self, us: f64) {
        self.overhead_us += us;
    }

    pub fn total_us(&self) -> f64 {
        self.aggregate_us + self.update_us + self.overhead_us
    }

    pub fn l2_hit_rate(&self) -> f64 {
        if self.l2_accesses == 0 {
            1.0
        } else {
            self.l2_hits as f64 / self.l2_accesses as f64
        }
    }

    /// Training uses forward + backward; the backward aggregate re-runs
    /// the same kernels on the transposed (symmetric) matrix and the
    /// update GEMMs roughly double. `scale(2.x)` models that uniformly so
    /// strategy *ratios* are preserved.
    pub fn scaled(&self, factor: f64) -> IterationCost {
        IterationCost {
            aggregate_us: self.aggregate_us * factor,
            update_us: self.update_us * factor,
            overhead_us: self.overhead_us * factor,
            ..*self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::model::A100;
    use crate::kernels::KernelKind;

    #[test]
    fn gemm_cost_scales() {
        let small = gemm_us(256, 32, 32, &A100);
        let big = gemm_us(4096, 512, 512, &A100);
        assert!(big > small * 10.0);
    }

    #[test]
    fn iteration_accumulates() {
        let mut it = IterationCost::default();
        it.add_kernel(&KernelCost::noop(KernelKind::Coo, &A100));
        it.add_kernel(&KernelCost::noop(KernelKind::CsrIntra, &A100));
        it.add_update(gemm_us(64, 8, 8, &A100));
        it.add_overhead(3.0);
        assert_eq!(it.kernel_launches, 2);
        assert!(it.total_us() > 2.0 * A100.launch_us + 3.0);
    }

    #[test]
    fn scaling_preserves_ratio() {
        let mut a = IterationCost::default();
        a.add_update(10.0);
        let mut b = IterationCost::default();
        b.add_update(20.0);
        let r0 = b.total_us() / a.total_us();
        let r1 = b.scaled(2.5).total_us() / a.scaled(2.5).total_us();
        assert!((r0 - r1).abs() < 1e-12);
    }
}
