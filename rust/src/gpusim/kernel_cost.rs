//! Analytic + trace-driven kernel cost model.
//!
//! For each kernel schedule the model combines a roofline (max of compute
//! and memory time) with launch overhead, an L2 replay for scattered
//! gathers, a load-imbalance penalty for vertex-parallel schedules, and an
//! atomic-update penalty for the edge-parallel schedule. The constants
//! live in [`super::model`]; the *shapes* this produces — the Fig. 2b
//! dense/CSR/COO crossovers, Fig. 3b's hit-rate/time tension, the Fig. 8
//! speedups — are the reproduction target (DESIGN.md Sec. 2).

use crate::graph::Csr;
use crate::kernels::KernelKind;

use super::cache::CacheSim;
use super::model::GpuModel;

const BYTES: f64 = 4.0;
/// Per-row loop bookkeeping for vertex-parallel CSR (cycles -> us via
/// clock); this is the O(V) term that makes COO win at extreme sparsity.
const ROW_OVERHEAD_CYCLES: f64 = 10.0;
/// Bytes per adjacency element in a packed MMA tile: the tile payload is
/// staged in half precision (bf16/fp16) for the tensor-core fragments.
const TILE_PAYLOAD_BYTES: f64 = 2.0;
/// Per-tile scheduling bookkeeping for the tile-sparse kernel (column-id
/// decode + fragment load/store issue), cycles -> us via clock.
const TILE_OVERHEAD_CYCLES: f64 = 20.0;

/// Feature-byte scaling for a top-k compressed feature operand at
/// density `rho = k/f`: each kept lane carries a 4-byte value plus a
/// 4-byte column index, so traffic is `2*rho` of the dense row until the
/// index overhead eats the savings (`rho >= 0.5`), where the kernel
/// falls back to dense rows. Exactly 1.0 at `rho = 1.0`, which keeps
/// dense-feature costs bit-identical to the density-blind model.
fn feat_bytes_factor(rho: f64) -> f64 {
    (2.0 * rho).min(1.0)
}

/// Cost breakdown of one kernel launch.
#[derive(Debug, Clone)]
pub struct KernelCost {
    pub kind: KernelKind,
    pub time_us: f64,
    pub compute_us: f64,
    pub memory_us: f64,
    pub launch_us: f64,
    pub flops: f64,
    pub bytes: f64,
    /// L2 transactions (hits, accesses) this kernel generated, at
    /// feature-row granularity.
    pub l2_hits: u64,
    pub l2_accesses: u64,
}

impl KernelCost {
    pub fn l2_hit_rate(&self) -> f64 {
        if self.l2_accesses == 0 {
            1.0
        } else {
            self.l2_hits as f64 / self.l2_accesses as f64
        }
    }

    fn finish(mut self, gpu: &GpuModel) -> KernelCost {
        self.launch_us = gpu.launch_us;
        self.time_us = self.launch_us + self.compute_us.max(self.memory_us);
        self
    }

    /// An empty-kernel cost (zero-size subgraph still pays the launch).
    pub fn noop(kind: KernelKind, gpu: &GpuModel) -> KernelCost {
        KernelCost {
            kind,
            time_us: gpu.launch_us,
            compute_us: 0.0,
            memory_us: 0.0,
            launch_us: gpu.launch_us,
            flops: 0.0,
            bytes: 0.0,
            l2_hits: 0,
            l2_accesses: 0,
        }
    }
}

/// Load-imbalance multiplier for vertex-parallel schedules: warps stall on
/// the longest row in the block. 1.0 for balanced graphs, grows with the
/// p99/mean degree ratio, capped (GNNAdvisor-style grouping bounds it).
fn imbalance_factor(a: &Csr) -> f64 {
    if a.n_rows == 0 || a.nnz() == 0 {
        return 1.0;
    }
    let max_deg = (0..a.n_rows)
        .map(|r| a.row_ptr[r + 1] - a.row_ptr[r])
        .max()
        .unwrap_or(0) as f64;
    let mean = a.nnz() as f64 / a.n_rows as f64;
    // warps stall on their longest row; sqrt damps the tail because only
    // a few warps contain the hubs
    (max_deg / mean.max(1e-9)).sqrt().clamp(1.0, 2.5)
}

/// Replay the per-edge source-feature gathers through an L2 model; returns
/// (hits, accesses). One access per edge at feature-row granularity.
fn replay_gathers(a: &Csr, f: usize, gpu: &GpuModel, l2: Option<&mut CacheSim>) -> (u64, u64) {
    let mut own;
    let l2 = match l2 {
        Some(l2) => l2,
        None => {
            own = CacheSim::for_feature_rows(gpu.l2_bytes, f * BYTES as usize);
            &mut own
        }
    };
    let before_h = l2.hits();
    let before_a = l2.accesses();
    for r in 0..a.n_rows {
        let (cols, _) = a.row(r);
        for &c in cols {
            l2.access(c as u64);
        }
    }
    (l2.hits() - before_h, l2.accesses() - before_a)
}

/// Vertex-parallel CSR over an arbitrary-sparsity matrix.
pub fn csr_inter_cost(a: &Csr, f: usize, gpu: &GpuModel) -> KernelCost {
    csr_inter_cost_full(a, f, gpu, None, None, 1.0)
}

/// Like [`csr_inter_cost`] but with the divergence factor overridden —
/// GNNAdvisor's neighbor grouping bounds warp imbalance near 1.
pub fn csr_inter_cost_with_imb(
    a: &Csr,
    f: usize,
    gpu: &GpuModel,
    imb_override: Option<f64>,
) -> KernelCost {
    csr_inter_cost_full(a, f, gpu, imb_override, None, 1.0)
}

/// Full-control variant: optional divergence override, an optional
/// pre-warmed shared L2 (back-to-back kernels in one iteration see each
/// other's residency — see [`subgraph_pair_cost`]), and the feature
/// density `rho = k/f` of a top-k compressed operand. Sparse features
/// shrink the per-edge gather (each source row carries `k` lanes) and
/// the multiply count, but NOT the topology stream or the dense output.
pub fn csr_inter_cost_full(
    a: &Csr,
    f: usize,
    gpu: &GpuModel,
    imb_override: Option<f64>,
    l2: Option<&mut CacheSim>,
    feat_density: f64,
) -> KernelCost {
    let e = a.nnz() as f64;
    let v = a.n_rows as f64;
    let rho = feat_density.clamp(0.0, 1.0);
    let fb = feat_bytes_factor(rho);
    let flops = 2.0 * e * f as f64 * rho;
    let (h, acc) = replay_gathers(a, f, gpu, l2);
    let row_bytes = f as f64 * BYTES;
    let miss_bytes = (acc - h) as f64 * row_bytes * fb;
    let hit_bytes = h as f64 * row_bytes * fb;
    let topo_bytes = (v + 1.0) * 4.0 + e * 8.0 + v * row_bytes; // rp + (col,val) + output
    // L2 hits are served at ~4x stream bandwidth; misses pay the gather
    // (non-coalesced) path. Degree skew divergence serializes the warp's
    // gathers, so the imbalance factor multiplies the miss path — this is
    // what lets balanced edge-parallel COO win at extreme sparsity
    // (Fig. 2b) while CSR dominates once the working set hits L2.
    let imb = imb_override.unwrap_or_else(|| imbalance_factor(a));
    let memory_us =
        gpu.stream_us(topo_bytes) + gpu.gather_us(miss_bytes) * imb + gpu.stream_us(hit_bytes) / 2.0;
    let compute_us = gpu.fp32_us(flops) * imb
        + v * ROW_OVERHEAD_CYCLES / (gpu.sm_count as f64 * 32.0) / (gpu.clock_ghz * 1e3);
    KernelCost {
        kind: KernelKind::CsrInter,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: topo_bytes + miss_bytes + hit_bytes,
        l2_hits: h,
        l2_accesses: acc,
    }
    .finish(gpu)
}

/// Community-resident CSR over a block-diagonal matrix: the feature tile
/// is staged once per community ("shared memory"), so per-edge gathers
/// generate no L2 traffic.
pub fn csr_intra_cost(a: &Csr, f: usize, community: usize, gpu: &GpuModel) -> KernelCost {
    csr_intra_cost_dims(a.n_rows, a.nnz(), f, community, gpu, 1.0)
}

/// [`csr_intra_cost`] from dimensions alone — a density *class* keeps
/// global row ids (empty rows outside its blocks), so its cost must be
/// priced on the class's real rows/nnz, not the container matrix's.
/// `feat_density` is the top-k feature density `rho = k/f`: it scales
/// the staged input tile and the multiply count; topology bytes and the
/// dense output row are unaffected.
pub fn csr_intra_cost_dims(
    rows: usize,
    nnz: usize,
    f: usize,
    community: usize,
    gpu: &GpuModel,
    feat_density: f64,
) -> KernelCost {
    let e = nnz as f64;
    let v = rows as f64;
    let rho = feat_density.clamp(0.0, 1.0);
    let flops = 2.0 * e * f as f64 * rho;
    let row_bytes = f as f64 * BYTES;
    // one streamed tile load per community + topology + output
    let tile_bytes = v * row_bytes * feat_bytes_factor(rho);
    let topo_bytes = (v + 1.0) * 4.0 + e * 8.0 + v * row_bytes;
    let memory_us = gpu.stream_us(tile_bytes + topo_bytes);
    // shared-memory operand access is near-register speed; mild multiplier
    let compute_us = gpu.fp32_us(flops) * 1.1
        + v * ROW_OVERHEAD_CYCLES / (gpu.sm_count as f64 * 32.0) / (gpu.clock_ghz * 1e3);
    // tile loads are the only L2 transactions: one per community row,
    // compulsory misses
    let accesses = (v / community.max(1) as f64).ceil() as u64 * community as u64;
    KernelCost {
        kind: KernelKind::CsrIntra,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: tile_bytes + topo_bytes,
        l2_hits: 0,
        l2_accesses: accesses.min(rows as u64),
    }
    .finish(gpu)
}

/// Edge-parallel COO: perfect balance, no O(V) term, but every edge pays
/// an atomic read-modify-write on the destination row.
pub fn coo_cost(a: &Csr, f: usize, gpu: &GpuModel) -> KernelCost {
    coo_cost_full(a, f, gpu, None, 1.0)
}

/// COO with an optional pre-warmed shared L2 and a top-k feature density
/// `rho = k/f`: gathered source rows, scattered accumulations, and the
/// atomic lane count all shrink with `rho` (each edge only touches the
/// source row's `k` live lanes); the edge list does not.
pub fn coo_cost_full(
    a: &Csr,
    f: usize,
    gpu: &GpuModel,
    l2: Option<&mut CacheSim>,
    feat_density: f64,
) -> KernelCost {
    let e = a.nnz() as f64;
    let rho = feat_density.clamp(0.0, 1.0);
    let fb = feat_bytes_factor(rho);
    let flops = 2.0 * e * f as f64 * rho;
    let (h, acc) = replay_gathers(a, f, gpu, l2);
    let row_bytes = f as f64 * BYTES;
    let miss_bytes = (acc - h) as f64 * row_bytes * fb;
    let hit_bytes = h as f64 * row_bytes * fb;
    let topo_bytes = e * 12.0; // (src, dst, val)
    // scattered atomic writes: destination rows travel the gather path on
    // L2 misses and the hit path when resident (same locality as reads)
    let hr = if acc == 0 { 0.0 } else { h as f64 / acc as f64 };
    let write_bytes = e * row_bytes * 0.5 * fb;
    let memory_us = gpu.stream_us(topo_bytes)
        + gpu.gather_us(miss_bytes)
        + gpu.stream_us(hit_bytes) / 2.0
        + gpu.gather_us(write_bytes * (1.0 - hr))
        + gpu.stream_us(write_bytes * hr) / 2.0;
    // atomic serialization grows with destination collisions (~E/V): at
    // extreme sparsity atomics are nearly free — the regime the paper says
    // COO is "more appropriate" for — and at high density hot rows
    // serialize.
    let collisions = (e / a.n_rows.max(1) as f64).clamp(0.1, 4.0);
    let atomic_us = e * gpu.atomic_ns * 1e-3 * collisions * (f as f64 * rho / 32.0).max(1.0);
    let compute_us = gpu.fp32_us(flops) + atomic_us;
    KernelCost {
        kind: KernelKind::Coo,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: topo_bytes + miss_bytes + hit_bytes + write_bytes,
        l2_hits: h,
        l2_accesses: acc,
    }
    .finish(gpu)
}

/// Dense block-diagonal batched GEMM on the dense engine. A ragged tail
/// block is padded to a full `c x c` tile (the packing pads with zeros),
/// so the block count rounds UP.
pub fn dense_block_cost(n: usize, community: usize, f: usize, gpu: &GpuModel) -> KernelCost {
    dense_block_cost_dims(n.div_ceil(community.max(1)), n, community, f, gpu)
}

/// [`dense_block_cost`] from dimensions alone: `blocks` dense tiles
/// covering `rows` real rows — the form a density class is priced in.
pub fn dense_block_cost_dims(
    blocks: usize,
    rows: usize,
    community: usize,
    f: usize,
    gpu: &GpuModel,
) -> KernelCost {
    let b = blocks as f64;
    let c = community as f64;
    let flops = b * c * c * f as f64 * 2.0;
    let bytes = b * c * c * BYTES + rows as f64 * f as f64 * BYTES * 2.0; // A blocks + X + Y
    let memory_us = gpu.stream_us(bytes);
    let compute_us = gpu.dense_us(flops);
    KernelCost {
        kind: KernelKind::DenseBlock,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes,
        l2_hits: 0,
        l2_accesses: blocks.max(1) as u64,
    }
    .finish(gpu)
}

/// Full dense adjacency GEMM (Fig. 2b's "Dense" curve).
pub fn dense_full_cost(n: usize, f: usize, gpu: &GpuModel) -> KernelCost {
    let nn = n as f64;
    let flops = nn * nn * f as f64 * 2.0;
    let bytes = nn * nn * BYTES + nn * f as f64 * BYTES * 2.0;
    KernelCost {
        kind: KernelKind::DenseFull,
        time_us: 0.0,
        compute_us: gpu.dense_us(flops),
        memory_us: gpu.stream_us(bytes),
        launch_us: 0.0,
        flops,
        bytes,
        l2_hits: 0,
        l2_accesses: n.max(1) as u64,
    }
    .finish(gpu)
}

/// Closed-form CSR cost with an ASSUMED L2 hit rate — used by Fig. 2b's
/// extrapolated high-density points, where materializing the 100M+-edge
/// CSR would not fit memory. At such densities the 19717-row feature
/// matrix trivially fits L2, so `hit_rate` ~ 1.
pub fn csr_cost_analytic(v: usize, nnz: usize, f: usize, hit_rate: f64, gpu: &GpuModel) -> KernelCost {
    let e = nnz as f64;
    let vv = v as f64;
    let row_bytes = f as f64 * BYTES;
    let flops = 2.0 * e * f as f64;
    let miss_bytes = e * (1.0 - hit_rate) * row_bytes;
    let hit_bytes = e * hit_rate * row_bytes;
    let topo_bytes = (vv + 1.0) * 4.0 + e * 8.0 + vv * row_bytes;
    let memory_us =
        gpu.stream_us(topo_bytes) + gpu.gather_us(miss_bytes) + gpu.stream_us(hit_bytes) / 2.0;
    let compute_us = gpu.fp32_us(flops);
    KernelCost {
        kind: KernelKind::CsrInter,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: topo_bytes + miss_bytes + hit_bytes,
        l2_hits: (e * hit_rate) as u64,
        l2_accesses: nnz as u64,
    }
    .finish(gpu)
}

/// Closed-form COO twin of [`csr_cost_analytic`].
pub fn coo_cost_analytic(nnz: usize, f: usize, hit_rate: f64, gpu: &GpuModel) -> KernelCost {
    let e = nnz as f64;
    let row_bytes = f as f64 * BYTES;
    let flops = 2.0 * e * f as f64;
    let miss_bytes = e * (1.0 - hit_rate) * row_bytes;
    let hit_bytes = e * hit_rate * row_bytes;
    let topo_bytes = e * 12.0;
    let write_bytes = e * row_bytes;
    let memory_us = gpu.stream_us(topo_bytes)
        + gpu.gather_us(miss_bytes)
        + gpu.stream_us(hit_bytes) / 2.0
        + gpu.gather_us(write_bytes) * 0.5;
    let atomic_us = e * gpu.atomic_ns * 1e-3 * 4.0 * (f as f64 / 32.0).max(1.0);
    KernelCost {
        kind: KernelKind::Coo,
        time_us: 0.0,
        compute_us: gpu.fp32_us(flops) + atomic_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: topo_bytes + miss_bytes + hit_bytes + write_bytes,
        l2_hits: (e * hit_rate) as u64,
        l2_accesses: nnz as u64,
    }
    .finish(gpu)
}

/// Closed-form COO cost for a block-diagonal density class: every gather
/// stays inside its community tile, so the assumed L2 hit rate is the
/// tile-reuse bound `1 - rows/nnz` (one compulsory miss per resident
/// feature row, everything else hits).
pub fn coo_class_cost(
    rows: usize,
    nnz: usize,
    f: usize,
    gpu: &GpuModel,
    feat_density: f64,
) -> KernelCost {
    let e = nnz as f64;
    let rho = feat_density.clamp(0.0, 1.0);
    let fb = feat_bytes_factor(rho);
    let hr = (1.0 - rows as f64 / e.max(1.0)).clamp(0.0, 0.98);
    let row_bytes = f as f64 * BYTES;
    let flops = 2.0 * e * f as f64 * rho;
    let miss_bytes = e * (1.0 - hr) * row_bytes * fb;
    let hit_bytes = e * hr * row_bytes * fb;
    let topo_bytes = e * 12.0; // (src, dst, val)
    let write_bytes = e * row_bytes * 0.5 * fb;
    let memory_us = gpu.stream_us(topo_bytes)
        + gpu.gather_us(miss_bytes)
        + gpu.stream_us(hit_bytes) / 2.0
        + gpu.gather_us(write_bytes * (1.0 - hr))
        + gpu.stream_us(write_bytes * hr) / 2.0;
    let collisions = (e / rows.max(1) as f64).clamp(0.1, 4.0);
    let atomic_us = e * gpu.atomic_ns * 1e-3 * collisions * (f as f64 * rho / 32.0).max(1.0);
    KernelCost {
        kind: KernelKind::Coo,
        time_us: 0.0,
        compute_us: gpu.fp32_us(flops) + atomic_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: topo_bytes + miss_bytes + hit_bytes + write_bytes,
        l2_hits: (e * hr) as u64,
        l2_accesses: nnz as u64,
    }
    .finish(gpu)
}

/// Expected occupied `16x16` tile count for a block-diagonal class under
/// SGT-style column compaction (`kernels::tile`): per 16-row strip the
/// distinct occupied columns condense into ceil(distinct/16) dense tiles.
/// Closed form over `(blocks, nnz, community)` via the coupon-collector
/// expectation of distinct columns, so threshold sweeps can price
/// admissibility without materializing any class matrix. Deterministic —
/// the sweep and the `adaptgear check` cost audit share it.
pub fn est_occupied_tiles(blocks: usize, nnz: usize, community: usize) -> f64 {
    if nnz == 0 {
        return 0.0;
    }
    let c = community.max(1) as f64;
    let t = crate::kernels::tile::MMA_TILE as f64;
    let strips = (c / t).ceil();
    let nb = blocks.max(1) as f64;
    let nnz_strip = nnz as f64 / (nb * strips);
    // expected distinct columns hit by nnz_strip uniform draws over c
    let distinct = c * (1.0 - (1.0 - 1.0 / c).powf(nnz_strip));
    // a non-empty strip occupies at least one tile
    let tiles_strip = (distinct / t).max(nnz_strip.min(1.0));
    nb * strips * tiles_strip
}

/// Tile-sparse (tensor-core) cost over a block-diagonal density class:
/// `occupied` non-empty `16x16` tiles each pay one MMA fragment
/// (`2*16*16*f` flops at the half-precision rate), a half-precision
/// payload plus per-tile column index, and per-tile scheduling overhead.
/// Features are staged once per class like the other intra schedules.
/// `occupied = None` prices on [`est_occupied_tiles`]; the planner passes
/// the exact extraction count when one is available ([`CostCtx::tile`]).
pub fn tile_sparse_cost_dims(
    blocks: usize,
    rows: usize,
    nnz: usize,
    f: usize,
    community: usize,
    gpu: &GpuModel,
    occupied: Option<usize>,
) -> KernelCost {
    let t = crate::kernels::tile::MMA_TILE as f64;
    let occ = occupied
        .map(|o| o as f64)
        .unwrap_or_else(|| est_occupied_tiles(blocks, nnz, community));
    let flops = occ * 2.0 * t * t * f as f64;
    // per tile: bf16 payload + 16 column ids (u32) + strip row base (u32)
    let tile_bytes = occ * (t * t * TILE_PAYLOAD_BYTES + t * 4.0 + 4.0);
    let stage_bytes = rows as f64 * f as f64 * BYTES * 2.0; // X + Y
    let memory_us = gpu.stream_us(tile_bytes + stage_bytes);
    let compute_us = gpu.mma_us(flops)
        + occ * TILE_OVERHEAD_CYCLES / (gpu.sm_count as f64 * 32.0) / (gpu.clock_ghz * 1e3);
    KernelCost {
        kind: KernelKind::TileSparse,
        time_us: 0.0,
        compute_us,
        memory_us,
        launch_us: 0.0,
        flops,
        bytes: tile_bytes + stage_bytes,
        l2_hits: 0,
        l2_accesses: occ.ceil() as u64,
    }
    .finish(gpu)
}

/// Dimensions of one intra density class, for class-level pricing.
#[derive(Debug, Clone, Copy)]
pub struct ClassDims {
    pub kind: KernelKind,
    /// Diagonal blocks in the class.
    pub blocks: usize,
    /// Real rows covered by those blocks.
    pub rows: usize,
    pub nnz: usize,
}

/// Everything class-level pricing depends on, in one struct — the
/// positional `(dims, f, community, gpu)` list grew a parameter with
/// every kernel, and TileSparse's tile geometry would have been a fifth.
/// Build with [`CostCtx::new`]; add the exact occupied-tile count via
/// [`CostCtx::with_tile`] when an extraction is on hand.
#[derive(Debug, Clone, Copy)]
pub struct CostCtx<'a> {
    pub dims: ClassDims,
    /// Aggregate feature width this launch runs at.
    pub feat_dim: usize,
    /// Community (block) size of the decomposition.
    pub community: usize,
    pub gpu: &'a GpuModel,
    /// Exact occupied `16x16` tile count for TileSparse pricing; `None`
    /// falls back to the [`est_occupied_tiles`] closed form. Ignored by
    /// every other kernel.
    pub tile: Option<usize>,
    /// Feature density `rho = k/f` of a top-k compressed operand; 1.0
    /// (dense features) reproduces the density-blind costs bit-exactly.
    /// The sparse schedules (CsrIntra/Coo) shrink gathers and multiplies
    /// with `rho`; the dense engines (DenseBlock/TileSparse) traverse
    /// every lane and are invariant in it.
    pub feat_density: f64,
}

impl<'a> CostCtx<'a> {
    pub fn new(
        dims: ClassDims,
        feat_dim: usize,
        community: usize,
        gpu: &'a GpuModel,
    ) -> CostCtx<'a> {
        CostCtx { dims, feat_dim, community, gpu, tile: None, feat_density: 1.0 }
    }

    /// Price TileSparse on an exact occupied-tile count instead of the
    /// analytic estimate.
    pub fn with_tile(mut self, occupied: usize) -> CostCtx<'a> {
        self.tile = Some(occupied);
        self
    }

    /// Price the class at a top-k feature density `rho = k/f`.
    pub fn with_feat_density(mut self, rho: f64) -> CostCtx<'a> {
        self.feat_density = rho;
        self
    }
}

/// Cost of one launch over an intra density class (closed form, so
/// threshold sweeps can price thousands of candidate splits).
pub fn class_kernel_cost(ctx: &CostCtx) -> KernelCost {
    let (class, f, community, gpu) = (&ctx.dims, ctx.feat_dim, ctx.community, ctx.gpu);
    let rho = ctx.feat_density;
    match class.kind {
        KernelKind::CsrIntra => csr_intra_cost_dims(class.rows, class.nnz, f, community, gpu, rho),
        // dense engines traverse every lane — invariant in feat_density
        KernelKind::DenseBlock => {
            dense_block_cost_dims(class.blocks, class.rows, community, f, gpu)
        }
        KernelKind::Coo => coo_class_cost(class.rows, class.nnz, f, gpu, rho),
        KernelKind::TileSparse => {
            tile_sparse_cost_dims(class.blocks, class.rows, class.nnz, f, community, gpu, ctx.tile)
        }
        other => panic!("{other} is not an intra class candidate"),
    }
}

/// The hybrid pricing rule: the intra side of a plan costs the SUM over
/// its density classes — each class is one kernel launch, so a split
/// must buy back its extra `launch_us` in format savings to win.
pub fn hybrid_intra_cost(classes: &[CostCtx]) -> f64 {
    classes.iter().map(|c| class_kernel_cost(c).time_us).sum()
}

/// Joint cost of a subgraph kernel pair in one iteration: the intra
/// kernel streams every community tile through L2 first, so the inter
/// kernel's scattered gathers start from a warm cache — exactly what
/// back-to-back launches see on hardware. Without this, splitting a graph
/// would be charged twice for the residency a fused kernel builds once.
pub fn subgraph_pair_cost(
    intra_kind: KernelKind,
    inter_kind: KernelKind,
    intra: &Csr,
    inter: &Csr,
    f: usize,
    community: usize,
    gpu: &GpuModel,
) -> (KernelCost, KernelCost) {
    let intra_cost = match intra_kind {
        KernelKind::CsrIntra => csr_intra_cost(intra, f, community, gpu),
        KernelKind::DenseBlock => dense_block_cost(intra.n_rows, community, f, gpu),
        other => panic!("{other} is not an intra candidate"),
    };
    let mut l2 = CacheSim::for_feature_rows(gpu.l2_bytes, (f * BYTES as usize).max(1));
    for r in 0..intra.n_rows {
        l2.access(r as u64); // tile residency left behind by the intra kernel
    }
    l2.reset_counters();
    let inter_cost = if inter.nnz() == 0 {
        KernelCost::noop(inter_kind, gpu)
    } else {
        match inter_kind {
            // AdaptGear's inter kernel is hand-tuned like GNNAdvisor's
            // (CTA->row-block mapping, shared-memory topology): bounded
            // divergence, same 1.15 as the GNNA baseline.
            KernelKind::CsrInter => {
                csr_inter_cost_full(inter, f, gpu, Some(1.15), Some(&mut l2), 1.0)
            }
            KernelKind::Coo => coo_cost_full(inter, f, gpu, Some(&mut l2), 1.0),
            other => panic!("{other} is not an inter candidate"),
        }
    };
    (intra_cost, inter_cost)
}

/// Cost of one aggregate launch for `kind` over `matrix` with dense
/// features — [`kernel_cost_density`] at `feat_density = 1.0`.
pub fn kernel_cost(
    kind: KernelKind,
    matrix: &Csr,
    f: usize,
    community: usize,
    gpu: &GpuModel,
) -> KernelCost {
    kernel_cost_density(kind, matrix, f, community, gpu, 1.0)
}

/// Cost of one aggregate launch for `kind` over `matrix` at a top-k
/// feature density `rho = k/f`. The sparse schedules (CSR/COO) price
/// gathers, scatters, and multiplies on the `k` live lanes per source
/// row; the dense engines cannot skip lanes and ignore `rho`.
pub fn kernel_cost_density(
    kind: KernelKind,
    matrix: &Csr,
    f: usize,
    community: usize,
    gpu: &GpuModel,
    feat_density: f64,
) -> KernelCost {
    if matrix.nnz() == 0 && !matches!(kind, KernelKind::DenseBlock | KernelKind::DenseFull) {
        return KernelCost::noop(kind, gpu);
    }
    match kind {
        KernelKind::CsrInter => csr_inter_cost_full(matrix, f, gpu, None, None, feat_density),
        KernelKind::CsrIntra => {
            csr_intra_cost_dims(matrix.n_rows, matrix.nnz(), f, community, gpu, feat_density)
        }
        KernelKind::Coo => coo_cost_full(matrix, f, gpu, None, feat_density),
        KernelKind::DenseBlock => dense_block_cost(matrix.n_rows, community, f, gpu),
        KernelKind::DenseFull => dense_full_cost(matrix.n_rows, f, gpu),
        KernelKind::TileSparse => tile_sparse_cost_dims(
            matrix.n_rows.div_ceil(community.max(1)),
            matrix.n_rows,
            matrix.nnz(),
            f,
            community,
            gpu,
            None,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{erdos_renyi, planted_partition, rmat};
    use crate::gpusim::model::{A100, V100};
    use crate::util::rng::Rng;

    fn whole(n: usize, density: f64, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        Csr::adjacency(&erdos_renyi(n, density, &mut rng))
    }

    #[test]
    fn fig2b_crossover_dense_wins_high_density() {
        let n = 512;
        let f = 32;
        let a = whole(n, 0.6, 1);
        let dense = dense_full_cost(n, f, &A100);
        let csr = csr_inter_cost(&a, f, &A100);
        let coo = coo_cost(&a, f, &A100);
        assert!(dense.time_us < csr.time_us, "dense {} vs csr {}", dense.time_us, csr.time_us);
        assert!(dense.time_us < coo.time_us);
    }

    #[test]
    fn fig2b_crossover_csr_wins_mid_density() {
        let n = 2048;
        let f = 32;
        let a = whole(n, 0.01, 2);
        let dense = dense_full_cost(n, f, &A100);
        let csr = csr_inter_cost(&a, f, &A100);
        assert!(csr.time_us < dense.time_us, "csr {} vs dense {}", csr.time_us, dense.time_us);
    }

    #[test]
    fn fig2b_crossover_coo_wins_extreme_sparsity() {
        // E << V: CSR pays O(V) row overhead, COO pays only O(E)
        let n = 65536;
        let f = 32;
        let mut rng = Rng::new(3);
        let g = rmat(n, 2000, &mut rng);
        let a = Csr::adjacency(&g);
        let csr = csr_inter_cost(&a, f, &A100);
        let coo = coo_cost(&a, f, &A100);
        assert!(coo.time_us < csr.time_us, "coo {} vs csr {}", coo.time_us, csr.time_us);
    }

    #[test]
    fn intra_kernel_beats_inter_kernel_on_block_diagonal() {
        let mut rng = Rng::new(4);
        let g = planted_partition(4096, 16, 0.55, 0.0, &mut rng);
        let (intra, _) = Csr::gcn_normalized(&g).split_block_diagonal(16);
        let as_inter = csr_inter_cost(&intra, 32, &A100);
        let as_intra = csr_intra_cost(&intra, 32, 16, &A100);
        assert!(
            as_intra.time_us < as_inter.time_us,
            "intra {} vs inter {}",
            as_intra.time_us,
            as_inter.time_us
        );
    }

    #[test]
    fn intra_hit_rate_exceeds_scattered() {
        let mut rng = Rng::new(5);
        // feature width large => few rows fit in L2 => scattered misses
        let g = erdos_renyi(30000, 0.0005, &mut rng);
        let a = Csr::adjacency(&g);
        let scattered = csr_inter_cost(&a, 1024, &V100);
        assert!(scattered.l2_hit_rate() < 0.9);
    }

    #[test]
    fn a100_dense_much_faster_than_v100() {
        let c = dense_block_cost(4096, 16, 64, &A100);
        let v = dense_block_cost(4096, 16, 64, &V100);
        assert!(c.compute_us < v.compute_us);
    }

    #[test]
    fn empty_subgraph_costs_one_launch() {
        let a = Csr::from_triplets(64, 64, vec![]);
        let c = kernel_cost(KernelKind::Coo, &a, 32, 16, &A100);
        assert_eq!(c.time_us, A100.launch_us);
    }

    #[test]
    fn class_costs_agree_with_whole_matrix_costs() {
        // a single class covering the whole intra part must price exactly
        // like the whole-matrix cost functions
        let mut rng = Rng::new(8);
        let g = planted_partition(1024, 16, 0.4, 0.01, &mut rng);
        let (intra, _) = Csr::gcn_normalized(&g).split_block_diagonal(16);
        let whole = ClassDims {
            kind: KernelKind::CsrIntra,
            blocks: 64,
            rows: intra.n_rows,
            nnz: intra.nnz(),
        };
        let a = class_kernel_cost(&CostCtx::new(whole, 32, 16, &A100)).time_us;
        let b = csr_intra_cost(&intra, 32, 16, &A100).time_us;
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        let dense = ClassDims { kind: KernelKind::DenseBlock, ..whole };
        let c = class_kernel_cost(&CostCtx::new(dense, 32, 16, &A100)).time_us;
        let d = dense_block_cost(intra.n_rows, 16, 32, &A100).time_us;
        assert!((c - d).abs() < 1e-9, "{c} vs {d}");
    }

    #[test]
    fn hybrid_sum_includes_one_launch_per_class() {
        let a = ClassDims { kind: KernelKind::DenseBlock, blocks: 8, rows: 128, nnz: 2000 };
        let b = ClassDims { kind: KernelKind::CsrIntra, blocks: 56, rows: 896, nnz: 1500 };
        let two = hybrid_intra_cost(&[
            CostCtx::new(a, 32, 16, &A100),
            CostCtx::new(b, 32, 16, &A100),
        ]);
        let ca = class_kernel_cost(&CostCtx::new(a, 32, 16, &A100)).time_us;
        let cb = class_kernel_cost(&CostCtx::new(b, 32, 16, &A100)).time_us;
        assert!((two - (ca + cb)).abs() < 1e-9);
        assert!(two > 2.0 * A100.launch_us, "each class pays its launch");
    }

    /// Mean class cost at a synthetic `(blocks, density)` point — the
    /// regime tests below probe the intra candidate surface with it.
    fn class_us(kind: KernelKind, blocks: usize, c: usize, density: f64, f: usize) -> f64 {
        let rows = blocks * c;
        let nnz = (blocks as f64 * (c * c) as f64 * density).round() as usize;
        let dims = ClassDims { kind, blocks, rows, nnz };
        class_kernel_cost(&CostCtx::new(dims, f, c, &A100)).time_us
    }

    #[test]
    fn tile_sparse_wins_mid_density_class() {
        // the regime the tentpole targets: blocks too sparse for the
        // padded batched GEMM, too dense for the 8-byte-per-edge CSR
        for &c in &[16usize, 64] {
            for &d in &[0.35, 0.5] {
                let tile = class_us(KernelKind::TileSparse, 1000, c, d, 32);
                let csr = class_us(KernelKind::CsrIntra, 1000, c, d, 32);
                let dense = class_us(KernelKind::DenseBlock, 1000, c, d, 32);
                assert!(tile < csr, "c={c} d={d}: tile {tile} vs csr {csr}");
                assert!(tile < dense, "c={c} d={d}: tile {tile} vs dense {dense}");
            }
        }
    }

    #[test]
    fn csr_intra_wins_sparse_class_coo_wins_extreme() {
        // the pre-existing sweet spots survive the new candidate
        let csr = class_us(KernelKind::CsrIntra, 1000, 64, 0.05, 32);
        let tile = class_us(KernelKind::TileSparse, 1000, 64, 0.05, 32);
        let coo = class_us(KernelKind::Coo, 1000, 64, 0.05, 32);
        assert!(csr < tile && csr < coo, "csr {csr} vs tile {tile} / coo {coo}");
        let coo2 = class_us(KernelKind::Coo, 1000, 16, 0.01, 32);
        let csr2 = class_us(KernelKind::CsrIntra, 1000, 16, 0.01, 32);
        assert!(coo2 < csr2, "coo {coo2} vs csr {csr2}");
    }

    #[test]
    fn exact_tile_count_overrides_estimate() {
        let dims = ClassDims { kind: KernelKind::TileSparse, blocks: 100, rows: 1600, nnz: 40000 };
        let est = class_kernel_cost(&CostCtx::new(dims, 32, 16, &A100));
        let exact = class_kernel_cost(&CostCtx::new(dims, 32, 16, &A100).with_tile(1));
        assert!(exact.time_us < est.time_us, "1 tile must undercut the estimate");
        assert_eq!(exact.l2_accesses, 1);
    }

    #[test]
    fn est_occupied_tiles_is_monotone_and_bounded() {
        let lo = est_occupied_tiles(100, 1000, 64);
        let hi = est_occupied_tiles(100, 100000, 64);
        assert!(lo < hi, "more nnz -> more occupied tiles");
        // full blocks saturate at the geometric tile grid
        let full = est_occupied_tiles(100, 100 * 64 * 64, 64);
        assert!(full <= 100.0 * 4.0 * 4.0 + 1e-6, "{full}");
        assert_eq!(est_occupied_tiles(100, 0, 64), 0.0);
    }

    #[test]
    fn ragged_dense_block_cost_rounds_blocks_up() {
        let exact = dense_block_cost(64, 16, 32, &A100);
        let ragged = dense_block_cost(65, 16, 32, &A100);
        assert!(ragged.flops > exact.flops, "tail block must be priced");
    }

    #[test]
    fn costs_scale_with_edges() {
        let small = whole(1024, 0.005, 6);
        let big = whole(1024, 0.05, 7);
        let cs = csr_inter_cost(&small, 32, &A100);
        let cb = csr_inter_cost(&big, 32, &A100);
        assert!(cb.time_us > cs.time_us);
        assert!(cb.flops > cs.flops * 5.0);
    }

    const INTRA_KINDS: [KernelKind; 4] = [
        KernelKind::CsrIntra,
        KernelKind::DenseBlock,
        KernelKind::Coo,
        KernelKind::TileSparse,
    ];

    #[test]
    fn feat_density_one_reproduces_density_blind_costs_exactly() {
        // the density path at rho = 1.0 must be BIT-identical to the
        // pre-density model, so dense-feature plans re-derive byte-equal
        let dims = ClassDims { kind: KernelKind::CsrIntra, blocks: 200, rows: 3200, nnz: 60000 };
        for kind in INTRA_KINDS {
            let d = ClassDims { kind, ..dims };
            let blind = class_kernel_cost(&CostCtx::new(d, 64, 16, &A100));
            let one = class_kernel_cost(&CostCtx::new(d, 64, 16, &A100).with_feat_density(1.0));
            assert_eq!(blind.time_us, one.time_us, "{kind}");
            assert_eq!(blind.flops, one.flops, "{kind}");
            assert_eq!(blind.bytes, one.bytes, "{kind}");
        }
        let m = whole(2048, 0.01, 40);
        for kind in [KernelKind::CsrInter, KernelKind::CsrIntra, KernelKind::Coo] {
            let blind = kernel_cost(kind, &m, 64, 16, &A100);
            let one = kernel_cost_density(kind, &m, 64, 16, &A100, 1.0);
            assert_eq!(blind.time_us, one.time_us, "{kind}");
            assert_eq!(blind.bytes, one.bytes, "{kind}");
        }
        // the scaling factor itself is exactly 1 at rho = 1
        assert_eq!(feat_bytes_factor(1.0), 1.0);
    }

    #[test]
    fn class_costs_monotone_nonincreasing_as_density_drops() {
        // lower feature density never costs more, for EVERY class — the
        // dense engines are invariant (weakly monotone), the sparse
        // schedules strictly shrink
        let grid = [0.05, 0.125, 0.25, 0.4, 0.5, 0.75, 1.0];
        for kind in INTRA_KINDS {
            for &(blocks, c, density) in &[(1000usize, 16usize, 0.05), (200, 64, 0.4)] {
                let rows = blocks * c;
                let nnz = (blocks as f64 * (c * c) as f64 * density).round() as usize;
                let dims = ClassDims { kind, blocks, rows, nnz };
                for w in grid.windows(2) {
                    let lo = class_kernel_cost(
                        &CostCtx::new(dims, 256, c, &A100).with_feat_density(w[0]),
                    );
                    let hi = class_kernel_cost(
                        &CostCtx::new(dims, 256, c, &A100).with_feat_density(w[1]),
                    );
                    assert!(
                        lo.time_us <= hi.time_us + 1e-12,
                        "{kind} rho {} -> {}: {} vs {}",
                        w[0],
                        w[1],
                        lo.time_us,
                        hi.time_us
                    );
                }
            }
        }
        let m = whole(2048, 0.01, 41);
        for kind in [KernelKind::CsrInter, KernelKind::Coo] {
            for w in grid.windows(2) {
                let lo = kernel_cost_density(kind, &m, 256, 16, &A100, w[0]);
                let hi = kernel_cost_density(kind, &m, 256, 16, &A100, w[1]);
                assert!(lo.time_us <= hi.time_us + 1e-12, "{kind} rho {} -> {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn sparse_features_cheapen_sparse_kernels_at_wide_f() {
        // the acceptance regime: F = 256, k = F/8 => rho = 0.125. The
        // CSR/COO schedules must get strictly cheaper; the dense engines
        // must not move at all.
        let rho = 0.125;
        for kind in [KernelKind::CsrIntra, KernelKind::Coo] {
            let dims = ClassDims { kind, blocks: 1000, rows: 16000, nnz: 12800 };
            let dense = class_kernel_cost(&CostCtx::new(dims, 256, 16, &A100));
            let sparse =
                class_kernel_cost(&CostCtx::new(dims, 256, 16, &A100).with_feat_density(rho));
            assert!(
                sparse.time_us < dense.time_us,
                "{kind}: sparse {} vs dense {}",
                sparse.time_us,
                dense.time_us
            );
        }
        for kind in [KernelKind::DenseBlock, KernelKind::TileSparse] {
            let dims = ClassDims { kind, blocks: 1000, rows: 16000, nnz: 12800 };
            let dense = class_kernel_cost(&CostCtx::new(dims, 256, 16, &A100));
            let sparse =
                class_kernel_cost(&CostCtx::new(dims, 256, 16, &A100).with_feat_density(rho));
            assert_eq!(sparse.time_us, dense.time_us, "{kind} must ignore feat_density");
        }
        let m = whole(4096, 0.005, 42);
        let dense = kernel_cost_density(KernelKind::CsrInter, &m, 256, 16, &A100, 1.0);
        let sparse = kernel_cost_density(KernelKind::CsrInter, &m, 256, 16, &A100, rho);
        assert!(sparse.time_us < dense.time_us, "inter: {} vs {}", sparse.time_us, dense.time_us);
    }
}
