//! GPU hardware models (Table 3's two testbeds).
//!
//! No GPU exists in this environment, so kernel "time" for the figures
//! comes from an analytic roofline + simulated L2 model (DESIGN.md Sec. 2).
//! Constants below are public datasheet numbers for the Tesla V100 and the
//! Ampere A100; the *relative* behaviour (who wins at which density, V100
//! vs A100 gaps) is what the reproduction validates, not absolute time.

/// One GPU configuration.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub name: &'static str,
    pub sm_count: usize,
    pub clock_ghz: f64,
    /// L2 capacity in bytes (V100 6 MiB, A100 40 MiB).
    pub l2_bytes: usize,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Effective bandwidth fraction for non-coalesced (gather) traffic.
    pub gather_efficiency: f64,
    /// FP32 vector throughput, TFLOP/s (CUDA cores).
    pub fp32_tflops: f64,
    /// Dense-engine throughput for the dense-block kernel, TFLOP/s:
    /// A100 rides TF32 tensor cores, the V100 falls back to CUDA cores
    /// for f32 (paper Sec. 3.2, "Dense-based kernel").
    pub dense_tflops: f64,
    /// Half-precision MMA throughput, TFLOP/s — the tile-GEMM rate the
    /// TileSparse kernel's 16x16 fragments execute at (fp16 tensor cores
    /// on the V100, bf16 on the A100).
    pub mma_tflops: f64,
    /// Kernel launch overhead, microseconds.
    pub launch_us: f64,
    /// Extra per-edge atomic-update cost, nanoseconds (COO kernel).
    pub atomic_ns: f64,
    /// Fixed per-operator framework overhead, microseconds (used by the
    /// DGL/PyG baseline strategies).
    pub framework_op_us: f64,
}

/// Tesla V100 (80 SMs) — Table 3, left column.
pub const V100: GpuModel = GpuModel {
    name: "V100",
    sm_count: 80,
    clock_ghz: 1.53,
    l2_bytes: 6 * 1024 * 1024,
    mem_bw_gbps: 900.0,
    gather_efficiency: 0.25,
    fp32_tflops: 15.7,
    dense_tflops: 15.7, // no f32 tensor-core path before Ampere
    mma_tflops: 125.0,  // fp16 tensor cores
    launch_us: 6.0,
    atomic_ns: 0.25,
    framework_op_us: 7.0,
};

/// Ampere A100 (108 SMs) — Table 3, right column.
pub const A100: GpuModel = GpuModel {
    name: "A100",
    sm_count: 108,
    clock_ghz: 1.41,
    l2_bytes: 40 * 1024 * 1024,
    mem_bw_gbps: 1555.0,
    gather_efficiency: 0.28,
    fp32_tflops: 19.5,
    dense_tflops: 156.0, // TF32 tensor cores
    mma_tflops: 312.0,   // bf16 tensor cores
    launch_us: 5.0,
    atomic_ns: 0.15,
    framework_op_us: 6.0,
};

/// Canonical string dispatch — CLI parsing and plan deserialization both
/// come through here (`"a100".parse::<&'static GpuModel>()`).
impl std::str::FromStr for &'static GpuModel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<&'static GpuModel, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "v100" => Ok(&V100),
            "a100" => Ok(&A100),
            other => Err(anyhow::anyhow!("unknown GPU {other:?} (expected a100|v100)")),
        }
    }
}

impl GpuModel {
    /// Thin wrapper over the canonical [`FromStr`](std::str::FromStr) path.
    pub fn by_name(name: &str) -> Option<&'static GpuModel> {
        name.parse().ok()
    }

    /// Time to stream `bytes` at full (coalesced) bandwidth, microseconds.
    pub fn stream_us(&self, bytes: f64) -> f64 {
        bytes / (self.mem_bw_gbps * 1e3)
    }

    /// Time to gather `bytes` with scattered accesses that *miss* L2.
    pub fn gather_us(&self, bytes: f64) -> f64 {
        bytes / (self.mem_bw_gbps * 1e3 * self.gather_efficiency)
    }

    /// Time for `flops` on the vector pipeline, microseconds.
    pub fn fp32_us(&self, flops: f64) -> f64 {
        flops / (self.fp32_tflops * 1e6)
    }

    /// Time for `flops` on the dense engine, microseconds.
    pub fn dense_us(&self, flops: f64) -> f64 {
        flops / (self.dense_tflops * 1e6)
    }

    /// Time for `flops` on the half-precision MMA pipeline, microseconds.
    pub fn mma_us(&self, flops: f64) -> f64 {
        flops / (self.mma_tflops * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuModel::by_name("a100").unwrap().sm_count, 108);
        assert_eq!(GpuModel::by_name("V100").unwrap().sm_count, 80);
        assert!(GpuModel::by_name("h100").is_none());
    }

    #[test]
    fn a100_dense_engine_dominates_v100() {
        // the architectural fact the paper leans on for the dense kernel
        assert!(A100.dense_tflops / V100.dense_tflops > 5.0);
    }

    #[test]
    fn unit_conversions() {
        // 1555 GB/s -> 1 GB in ~643 us
        let us = A100.stream_us(1e9);
        assert!((us - 643.0).abs() < 2.0, "{us}");
        // 156 TFLOPs -> 1 GFLOP in ~6.4 us
        let us = A100.dense_us(1e9);
        assert!((us - 6.41).abs() < 0.1, "{us}");
    }

    #[test]
    fn mma_faster_than_dense_engine() {
        // the headroom the TileSparse kernel banks on: half-precision
        // fragments run ~2x the TF32 dense rate on Ampere, ~8x the CUDA
        // cores on Volta
        assert!(A100.mma_us(1e9) < A100.dense_us(1e9));
        assert!(V100.mma_us(1e9) < V100.fp32_us(1e9));
    }

    #[test]
    fn gather_slower_than_stream() {
        assert!(V100.gather_us(1e6) > V100.stream_us(1e6) * 3.0);
    }
}
