//! GPU cost simulator — the performance surface standing in for the
//! paper's V100/A100 testbeds (DESIGN.md Sec. 1-2).
//!
//! Numerics run for real through PJRT; *time* for the evaluation figures
//! comes from this module: hardware models ([`model`]), a set-associative
//! L2 simulator ([`cache`]), per-kernel roofline costs with trace-driven
//! gather modeling ([`kernel_cost`]), and iteration assembly
//! ([`timeline`]).

pub mod cache;
pub mod kernel_cost;
pub mod model;
pub mod timeline;

pub use cache::CacheSim;
pub use kernel_cost::{
    class_kernel_cost, hybrid_intra_cost, kernel_cost, kernel_cost_density, ClassDims, CostCtx,
    KernelCost,
};
pub use model::{GpuModel, A100, V100};
pub use timeline::{elementwise_us, gemm_us, merge_us, IterationCost};
