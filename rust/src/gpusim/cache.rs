//! Set-associative LRU cache simulator — the stand-in for nsight's L2
//! hit-rate counter (Fig. 3b).
//!
//! The figure pipeline simulates at *feature-row* granularity: one cache
//! block per vertex feature row. This keeps full-dataset replays cheap
//! (one access per edge) while preserving the locality contrast the paper
//! measures — community-resident kernels re-touch the same few rows, so
//! their hit rate soars; scattered inter-community gathers thrash.

/// Set-associative LRU cache over abstract block keys.
#[derive(Debug, Clone)]
pub struct CacheSim {
    ways: usize,
    sets: Vec<Vec<u64>>, // per-set MRU-first key list
    hits: u64,
    misses: u64,
}

impl CacheSim {
    /// `capacity_blocks` total blocks, `ways`-associative.
    pub fn new(capacity_blocks: usize, ways: usize) -> CacheSim {
        let ways = ways.max(1);
        let n_sets = (capacity_blocks / ways).max(1);
        CacheSim { ways, sets: vec![Vec::new(); n_sets], hits: 0, misses: 0 }
    }

    /// L2 configured for feature rows of `row_bytes` each.
    pub fn for_feature_rows(l2_bytes: usize, row_bytes: usize) -> CacheSim {
        CacheSim::new((l2_bytes / row_bytes.max(1)).max(1), 16)
    }

    /// Touch a block; returns true on hit.
    pub fn access(&mut self, key: u64) -> bool {
        let set_idx = (key as usize) % self.sets.len();
        let set = &mut self.sets[set_idx];
        if let Some(pos) = set.iter().position(|&k| k == key) {
            set.remove(pos);
            set.insert(0, key);
            self.hits += 1;
            true
        } else {
            if set.len() == self.ways {
                set.pop();
            }
            set.insert(0, key);
            self.misses += 1;
            false
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = CacheSim::new(64, 4);
        assert!(!c.access(1));
        assert!(c.access(1));
        assert!(c.access(1));
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_eviction() {
        // direct-mapped single set of 2 ways: 3 distinct keys thrash
        let mut c = CacheSim::new(2, 2);
        for _ in 0..10 {
            c.access(0);
            c.access(1);
            c.access(2);
        }
        assert!(c.hit_rate() < 0.1, "{}", c.hit_rate());
    }

    #[test]
    fn lru_keeps_hot_key() {
        let mut c = CacheSim::new(2, 2);
        c.access(7);
        c.access(8);
        c.access(7); // 7 is MRU
        c.access(9); // evicts 8
        assert!(c.access(7), "hot key evicted");
    }

    #[test]
    fn working_set_within_capacity_hits_fully() {
        let mut c = CacheSim::new(128, 8);
        for _ in 0..4 {
            for k in 0..64u64 {
                c.access(k);
            }
        }
        // first sweep misses, the rest hit
        assert!(c.hit_rate() > 0.7, "{}", c.hit_rate());
    }

    #[test]
    fn feature_row_constructor() {
        let c = CacheSim::for_feature_rows(40 * 1024 * 1024, 128);
        assert!(c.sets.len() > 1000);
    }
}
