//! The serving event loop and its channel topology.
//!
//! PJRT handles are not `Send`, so the [`Engine`] can never migrate off
//! the coordinator thread. The design is therefore a **single-owner event
//! loop**: producer threads hold a cloneable [`ServeClient`] and submit
//! [`Request`]s over a bounded `std::sync::mpsc` channel; the coordinator
//! thread runs [`ServeSession::run`], which coalesces requests with the
//! [`MicroBatcher`], applies their feature perturbations to the target
//! deployment's state, executes **one** forward artifact per
//! (batch, deployment) group, and answers every request in the group over
//! its per-request reply channel.
//!
//! ```text
//!  client threads                 coordinator thread (owns Engine)
//!  ┌────────────┐  mpsc::sync   ┌──────────┐   ┌─────────────────┐
//!  │ ServeClient├──────────────▶│ batcher  ├──▶│ forward artifact │
//!  │  (clone)   │◀──────────────┤ + replies│   │  (1 per batch)   │
//!  └────────────┘  per-request  └──────────┘   └─────────────────┘
//!                  reply channel
//! ```
//!
//! Shutdown is by disconnection: when every `ServeClient` clone is
//! dropped, `recv` reports the channel closed, the loop flushes the last
//! partial batch, and `run` returns the [`SloReport`].
//!
//! The channel also carries the **control plane**: a streaming replan
//! ships its [`PlanSwap`] through [`ServeClient::swap_plan`], which
//! enqueues it in-band with the traffic. The event loop's handling is
//! the linearization point of the live-swap protocol (DESIGN.md
//! Sec. 12): the open micro-batch is closed and executed on the OLD
//! plan — the queue is never drained or rejected — then the
//! deployment's plan/graph/operands swap atomically and every later
//! request sees the new plan.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::trainer;
use crate::obs::{counter, span};
use crate::plan::Fingerprint;
use crate::runtime::Engine;

use super::admission::Admission;
use super::batcher::MicroBatcher;
use super::metrics::{SloMetrics, SloReport, Stage};
use super::registry::{ModelRegistry, PlanSwap};

/// Serving-loop knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Requests coalesced into one forward execution, at most.
    pub max_batch: usize,
    /// Longest a request may sit in an open batch before it is forced out.
    pub max_wait: Duration,
    /// Admission bound on in-flight requests (queued + batched + executing).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

/// One feature-perturbation inference request: bump feature `feature` of
/// vertex `vertex` by `delta`, then classify `vertex` under fresh logits.
#[derive(Debug)]
pub struct Request {
    pub deployment: String,
    pub vertex: usize,
    pub feature: usize,
    pub delta: f32,
    enqueued: Instant,
    /// Set by the event loop when the request enters the open batch
    /// (queue-wait / batch-wait boundary for the stage split).
    batched_at: Option<Instant>,
    reply: mpsc::Sender<Reply>,
}

/// Successful answer to one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// Argmax class of the perturbed vertex under the new logits.
    pub class: i32,
    /// Enqueue -> reply, as observed by the server.
    pub latency: Duration,
    /// How many requests shared this forward execution.
    pub batch_size: usize,
}

pub type Reply = Result<Response, String>;

/// What flows over the serve channel: data-plane requests interleaved
/// with control-plane plan swaps, so ordering between them is exactly
/// submission order.
enum Msg {
    Request(Request),
    Swap(SwapCommand),
}

/// Install a re-planned graph/plan into a live deployment.
struct SwapCommand {
    deployment: String,
    /// Boxed: a `PlanSwap` carries a full decomposition + packed
    /// operands, far larger than a `Request`.
    swap: Box<PlanSwap>,
    ack: mpsc::Sender<Result<SwapReceipt, String>>,
}

/// The event loop's acknowledgement of an applied plan swap.
#[derive(Debug, Clone)]
pub struct SwapReceipt {
    pub deployment: String,
    /// Fingerprint now serving (the new plan's).
    pub fingerprint: Fingerprint,
    /// Requests that sat in the open micro-batch when the swap arrived —
    /// executed on the OLD plan just before the swap applied.
    pub flushed: usize,
}

/// Client-side submission failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control rejected the request (system at capacity).
    Shed,
    /// The serving loop has shut down.
    Closed,
    /// The server answered with an error (unknown deployment, PJRT
    /// failure, ...).
    Remote(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed => write!(f, "request shed by admission control"),
            ServeError::Closed => write!(f, "serving loop is closed"),
            ServeError::Remote(msg) => write!(f, "server error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Cloneable producer handle; safe to move across threads.
#[derive(Clone)]
pub struct ServeClient {
    tx: mpsc::SyncSender<Msg>,
    admission: Arc<Admission>,
}

impl ServeClient {
    /// Submit without blocking for the answer; returns the reply channel.
    pub fn submit(
        &self,
        deployment: &str,
        vertex: usize,
        feature: usize,
        delta: f32,
    ) -> Result<mpsc::Receiver<Reply>, ServeError> {
        if !self.admission.try_admit() {
            return Err(ServeError::Shed);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            deployment: deployment.to_string(),
            vertex,
            feature,
            delta,
            enqueued: Instant::now(),
            batched_at: None,
            reply: reply_tx,
        };
        match self.tx.send(Msg::Request(req)) {
            Ok(()) => Ok(reply_rx),
            Err(_) => {
                self.admission.release();
                Err(ServeError::Closed)
            }
        }
    }

    /// Ship a re-planned graph to the event loop and block until it is
    /// serving (or rejected). Control plane: bypasses admission — a
    /// saturated queue must not be able to starve a plan swap — and the
    /// swap still orders in-band behind every request submitted before
    /// it, which all finish on the old plan.
    pub fn swap_plan(
        &self,
        deployment: &str,
        swap: PlanSwap,
    ) -> Result<SwapReceipt, ServeError> {
        let (ack_tx, ack_rx) = mpsc::channel();
        let cmd = SwapCommand {
            deployment: deployment.to_string(),
            swap: Box::new(swap),
            ack: ack_tx,
        };
        if self.tx.send(Msg::Swap(cmd)).is_err() {
            return Err(ServeError::Closed);
        }
        match ack_rx.recv() {
            Ok(Ok(receipt)) => Ok(receipt),
            Ok(Err(msg)) => Err(ServeError::Remote(msg)),
            Err(_) => Err(ServeError::Closed),
        }
    }

    /// Closed-loop convenience: submit and block until the answer.
    pub fn call(
        &self,
        deployment: &str,
        vertex: usize,
        feature: usize,
        delta: f32,
    ) -> Result<Response, ServeError> {
        let rx = self.submit(deployment, vertex, feature, delta)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(ServeError::Remote(msg)),
            Err(_) => Err(ServeError::Closed),
        }
    }

    /// Shared admission view (for monitoring from producer threads).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }
}

/// The coordinator-thread serving loop. Owns the request receiver and the
/// metrics; borrows the engine and registry so callers keep deployment
/// state (and can serve again) after the session ends.
pub struct ServeSession<'a> {
    engine: &'a Engine,
    registry: &'a mut ModelRegistry,
    cfg: ServeConfig,
    admission: Arc<Admission>,
    rx: mpsc::Receiver<Msg>,
    metrics: SloMetrics,
}

impl<'a> ServeSession<'a> {
    /// Build a session plus the client handle that feeds it. Drop every
    /// client clone to end [`ServeSession::run`].
    pub fn new(
        engine: &'a Engine,
        registry: &'a mut ModelRegistry,
        cfg: ServeConfig,
    ) -> (ServeSession<'a>, ServeClient) {
        let admission = Arc::new(Admission::new(cfg.queue_depth));
        let (tx, rx) = mpsc::sync_channel(cfg.queue_depth);
        let session = ServeSession {
            engine,
            registry,
            cfg,
            admission: admission.clone(),
            rx,
            metrics: SloMetrics::new(),
        };
        (session, ServeClient { tx, admission })
    }

    /// Drive the event loop until every [`ServeClient`] is dropped, then
    /// flush the final partial batch and report.
    ///
    /// Hard `Err` means the loop itself is broken (poisoned engine state);
    /// per-request failures are answered over the reply channel instead.
    pub fn run(mut self) -> Result<SloReport> {
        let started = Instant::now();
        let mut batcher: MicroBatcher<Request> =
            MicroBatcher::new(self.cfg.max_batch, self.cfg.max_wait);
        loop {
            // Sleep until the next request or the open batch's deadline.
            let msg = match batcher.deadline() {
                Some(deadline) => {
                    let timeout = deadline.saturating_duration_since(Instant::now());
                    match self.rx.recv_timeout(timeout) {
                        Ok(req) => Some(req),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                None => match self.rx.recv() {
                    Ok(req) => Some(req),
                    Err(_) => break,
                },
            };
            let now = Instant::now();
            let ready = match msg {
                Some(Msg::Request(mut req)) => {
                    // Queue wait: submit -> picked up by the event loop.
                    self.metrics.record_stage(Stage::Queue, now.duration_since(req.enqueued));
                    req.batched_at = Some(now);
                    batcher.push(req, now)
                }
                Some(Msg::Swap(cmd)) => {
                    // Linearization point: the open batch closes and runs
                    // on the OLD plan (nothing is drained or rejected),
                    // then the deployment swaps. Requests behind the swap
                    // in the channel see the new plan.
                    let flushed = batcher.flush();
                    let count = flushed.as_ref().map_or(0, Vec::len);
                    if let Some(batch) = flushed {
                        self.execute(batch);
                    }
                    self.apply_swap(cmd, count);
                    None
                }
                None => batcher.poll(now),
            };
            if let Some(batch) = ready {
                self.execute(batch);
            }
        }
        if let Some(batch) = batcher.flush() {
            self.execute(batch);
        }
        let wall = started.elapsed().as_secs_f64();
        Ok(self
            .metrics
            .report(wall, self.admission.offered(), self.admission.shed()))
    }

    /// Execute one closed batch: group by deployment, one forward each.
    fn execute(&mut self, batch: Vec<Request>) {
        // Batch wait: entered the open batch -> the batch closed.
        let closed = Instant::now();
        for req in &batch {
            if let Some(at) = req.batched_at {
                self.metrics.record_stage(Stage::Batch, closed.duration_since(at));
            }
        }
        let mut groups: BTreeMap<String, Vec<Request>> = BTreeMap::new();
        for req in batch {
            groups.entry(req.deployment.clone()).or_default().push(req);
        }
        for (name, group) in groups {
            self.execute_group(&name, group);
        }
    }

    fn execute_group(&mut self, name: &str, group: Vec<Request>) {
        let (n, f_data) = match self.registry.get(name) {
            Ok(dep) => (dep.n, dep.f_data),
            Err(e) => {
                self.fail_group(group, &format!("{e:#}"));
                return;
            }
        };
        // Bounds-check up front: an out-of-range request gets an error
        // reply, never a clamped answer for a vertex it didn't ask about.
        let (valid, invalid): (Vec<Request>, Vec<Request>) = group
            .into_iter()
            .partition(|r| r.vertex < n && r.feature < f_data);
        if !invalid.is_empty() {
            self.fail_group(
                invalid,
                &format!("vertex or feature index out of range (n={n}, f={f_data})"),
            );
        }
        if valid.is_empty() {
            return;
        }
        let size = valid.len();
        let dep = self.registry.get_mut(name).expect("deployment vanished mid-batch");
        // Apply every perturbation in the batch to the deployment's
        // feature state, then amortize ONE forward over the whole group.
        for req in &valid {
            dep.x[req.vertex * dep.f_data + req.feature] += req.delta;
        }
        // Hybrid-aware forward over the operands packed at deploy time:
        // the hot path packs only the mutated feature matrix — never the
        // topology (deploy_planned did that once via plan_forward_operands).
        let logits = trainer::forward_packed_timed(
            self.engine,
            &dep.fwd_name,
            &dep.fwd_bucket,
            &dep.params,
            &dep.graph_ops,
            &dep.x,
            dep.f_data,
        );
        match logits {
            Ok((logits, timing)) => {
                self.metrics.record_forward(size);
                // Pack/execute are shared by the whole group; recording
                // them per request keeps stage counts comparable to the
                // per-request latency percentiles.
                for _ in 0..size {
                    self.metrics
                        .record_stage(Stage::Pack, Duration::from_secs_f64(timing.pack_secs));
                    self.metrics.record_stage(
                        Stage::Execute,
                        Duration::from_secs_f64(timing.execute_secs),
                    );
                }
                for req in valid {
                    let class = dep.classify(&logits, req.vertex);
                    let latency = req.enqueued.elapsed();
                    self.metrics.record_reply(latency);
                    // A client that gave up on its reply is not an error.
                    let _ = req.reply.send(Ok(Response { class, latency, batch_size: size }));
                    self.admission.release();
                }
            }
            Err(e) => {
                // Roll the batch's perturbations back so a client retry
                // after a transient PJRT failure does not double-apply.
                for req in &valid {
                    dep.x[req.vertex * dep.f_data + req.feature] -= req.delta;
                }
                self.fail_group(valid, &format!("forward failed: {e:#}"));
            }
        }
    }

    /// Apply a control-plane swap and acknowledge the sender. Failures
    /// (unknown deployment, payload/graph mismatch) leave the deployment
    /// serving its old plan and travel back over the ack channel.
    fn apply_swap(&mut self, cmd: SwapCommand, flushed: usize) {
        let SwapCommand { deployment, swap, ack } = cmd;
        let mut sp = span("serve.swap");
        sp.attr_str("deployment", &deployment);
        let result = self
            .registry
            .get_mut(&deployment)
            .and_then(|dep| dep.apply_swap(*swap))
            .map(|fingerprint| {
                counter("serve.swap.applied").inc();
                SwapReceipt { deployment: deployment.clone(), fingerprint, flushed }
            })
            .map_err(|e| format!("{e:#}"));
        // A swapper that gave up on its ack is not an error.
        let _ = ack.send(result);
    }

    fn fail_group(&mut self, group: Vec<Request>, msg: &str) {
        for req in group {
            self.metrics.record_error(req.enqueued.elapsed());
            let _ = req.reply.send(Err(msg.to_string()));
            self.admission.release();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.max_batch > 1);
        assert!(cfg.queue_depth >= cfg.max_batch);
        assert!(cfg.max_wait > Duration::ZERO);
    }

    #[test]
    fn serve_error_display() {
        assert!(ServeError::Shed.to_string().contains("shed"));
        assert!(ServeError::Closed.to_string().contains("closed"));
        assert!(ServeError::Remote("boom".into()).to_string().contains("boom"));
    }
}
