//! Model registry: named deployments and the state they own.
//!
//! A [`Deployment`] is one servable (dataset, model-kind, strategy)
//! triple: its decomposed graph, trained parameters, the [`GearPlan`]
//! that chose its kernels, and — because serving requests *mutate*
//! features — the current permuted feature/label state.
//! [`ModelRegistry::deploy`] plans through a [`CachedPlanner`] over the
//! artifacts-dir [`PlanStore`], so a second deployment of the same
//! (dataset, model) shape is served its kernel decision from disk and
//! spends **zero** monitor iterations; [`ModelRegistry::deploy_planned`]
//! accepts any planner; [`ModelRegistry::insert`] is the pure
//! bookkeeping half, unit-testable without artifacts or a PJRT client.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::{apply_perm, pipeline, trainer, ModelKind, Strategy, TrainConfig};
use crate::graph::datasets::DatasetSpec;
use crate::gpusim::A100;
use crate::kernels::KernelPair;
use crate::partition::Decomposition;
use crate::plan::{
    CachedPlanner, Fingerprint, GearPlan, MonitorPlanner, PlanRequest, PlanStore, Planner,
};
use crate::runtime::{BucketInfo, Engine, Tensor};

/// What to deploy: the identity of a servable model plus its training
/// budget. `name` is the registry key clients address requests to.
#[derive(Debug, Clone)]
pub struct DeploymentSpec {
    pub name: String,
    pub dataset: &'static DatasetSpec,
    pub model: ModelKind,
    pub strategy: Strategy,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Dataset scale override; `None` auto-scales to the AOT buckets.
    pub scale: Option<f64>,
}

impl DeploymentSpec {
    /// Default deployment: AdaptGear strategy, a short training budget.
    pub fn new(
        name: impl Into<String>,
        dataset: &'static DatasetSpec,
        model: ModelKind,
    ) -> DeploymentSpec {
        DeploymentSpec {
            name: name.into(),
            dataset,
            model,
            strategy: Strategy::AdaptGear,
            steps: 60,
            lr: 0.05,
            seed: 0,
            scale: None,
        }
    }
}

/// A live deployment: everything the event loop needs to answer requests.
#[derive(Debug)]
pub struct Deployment {
    pub name: String,
    pub model: ModelKind,
    pub strategy: Strategy,
    pub d: Decomposition,
    /// Permuted feature state `[n, f_data]` — mutated by served
    /// perturbation requests (the graph topology stays static).
    pub x: Vec<f32>,
    pub labels: Vec<i32>,
    pub f_data: usize,
    /// Vertices in the (scaled) served graph.
    pub n: usize,
    /// The kernel decision this deployment executes — including whether
    /// it was served from the plan cache (`plan.provenance.cached`, in
    /// which case `plan.monitor_iters == 0`).
    pub plan: GearPlan,
    pub params: Vec<Tensor>,
    /// Forward artifact this deployment executes.
    pub fwd_name: String,
    /// AOT bucket the forward executes in.
    pub fwd_bucket: BucketInfo,
    /// Static graph operands, packed ONCE at deploy time
    /// (`trainer::plan_forward_operands`) — the serving hot path must
    /// never re-split or re-pack topology per micro-batch.
    pub graph_ops: Vec<Tensor>,
    /// Padded vertex count of the AOT bucket (logits row stride divisor).
    pub bucket_vertices: usize,
    pub classes: usize,
    pub final_loss: f32,
    /// XLA compile time of the pre-warmed forward executable.
    pub warm_secs: f64,
}

impl Deployment {
    /// The kernel pair this deployment executes (mirrors
    /// `TrainReport::chosen` — single source of truth is the plan).
    pub fn chosen(&self) -> KernelPair {
        self.plan.chosen
    }

    /// The full per-class decision this deployment serves with — hybrid
    /// deployments carry two intra classes plus inter.
    pub fn assignment(&self) -> &crate::plan::GearAssignment {
        &self.plan.assignment
    }

    /// Argmax class for vertex `v` from a full-graph logits buffer.
    pub fn classify(&self, logits: &[f32], v: usize) -> i32 {
        let width = logits.len() / self.bucket_vertices.max(1);
        let row = &logits[v * width..v * width + self.classes.min(width)];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

/// Everything a live plan swap replaces, prepared OFF the serve thread
/// (stream re-planner + operand packing) so the event loop's only work
/// is validation and pointer swaps.
///
/// The decomposition must be in served (identity) order — appended
/// vertices extend the feature/label state via `new_rows`/`new_labels`,
/// existing rows are untouched, so in-flight feature perturbations
/// survive the swap.
#[derive(Debug)]
pub struct PlanSwap {
    pub plan: GearPlan,
    /// Mutated-graph decomposition, served order.
    pub d: Decomposition,
    /// Static graph operands packed for the new plan.
    pub graph_ops: Vec<Tensor>,
    pub fwd_name: String,
    pub fwd_bucket: BucketInfo,
    /// Feature rows for appended vertices, `[added, f_data]` row-major.
    pub new_rows: Vec<f32>,
    /// Labels for appended vertices.
    pub new_labels: Vec<i32>,
}

impl Deployment {
    /// Atomically install a re-planned graph + plan. Every check runs
    /// before ANY mutation, so a rejected swap leaves the deployment
    /// exactly as it was — the event loop keeps serving the old plan.
    pub fn apply_swap(&mut self, swap: PlanSwap) -> Result<Fingerprint> {
        let new_n = swap.d.graph.n;
        if new_n < self.n {
            bail!("swap shrinks {:?} from {} to {new_n} vertices", self.name, self.n);
        }
        let added = new_n - self.n;
        if swap.new_rows.len() != added * self.f_data {
            bail!(
                "swap for {:?} carries {} feature values for {added} new vertices (need {})",
                self.name,
                swap.new_rows.len(),
                added * self.f_data
            );
        }
        if swap.new_labels.len() != added {
            bail!(
                "swap for {:?} carries {} labels for {added} new vertices",
                self.name,
                swap.new_labels.len()
            );
        }
        swap.plan
            .validate(&swap.d, self.model)
            .with_context(|| format!("swap plan for {:?} does not match its graph", self.name))?;
        self.x.extend_from_slice(&swap.new_rows);
        self.labels.extend_from_slice(&swap.new_labels);
        self.n = new_n;
        self.d = swap.d;
        self.plan = swap.plan;
        self.graph_ops = swap.graph_ops;
        self.fwd_name = swap.fwd_name;
        self.bucket_vertices = swap.fwd_bucket.vertices;
        self.classes = swap.fwd_bucket.classes;
        self.fwd_bucket = swap.fwd_bucket;
        Ok(self.plan.fingerprint)
    }
}

/// Named deployments, keyed by `DeploymentSpec::name`.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    deployments: BTreeMap<String, Deployment>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Train + register a deployment through the default planner: a
    /// [`CachedPlanner`] over `<artifacts>/plans/` wrapping the sim-clock
    /// monitor. A warm cache skips monitoring entirely — redeploying the
    /// same (dataset, model) shape costs zero monitor iterations.
    pub fn deploy(&mut self, engine: &Engine, spec: DeploymentSpec) -> Result<&Deployment> {
        let mut planner = CachedPlanner::new(
            PlanStore::in_artifacts(&engine.manifest.dir),
            MonitorPlanner::sim(&A100, 3),
        );
        self.deploy_planned(engine, spec, &mut planner)
    }

    /// Train + register a deployment with an explicit planner: auto-scale
    /// the dataset to the AOT buckets, preprocess with the spec's
    /// strategy, plan, train through PJRT, and pre-warm the winning
    /// forward executable.
    pub fn deploy_planned(
        &mut self,
        engine: &Engine,
        spec: DeploymentSpec,
        planner: &mut dyn Planner,
    ) -> Result<&Deployment> {
        if self.deployments.contains_key(&spec.name) {
            bail!("deployment {:?} already exists", spec.name);
        }
        let cfg = TrainConfig {
            model: spec.model,
            steps: spec.steps,
            lr: spec.lr,
            seed: spec.seed,
        };
        let staged = pipeline::stage(
            &engine.manifest,
            spec.dataset,
            spec.model,
            spec.strategy,
            spec.scale,
            spec.seed,
        )
        .with_context(|| format!("staging deployment {:?}", spec.name))?;
        let (data, d) = (staged.data, staged.d);
        let req = PlanRequest::labeled(
            &d,
            spec.model,
            &staged.bucket,
            spec.dataset.name,
            staged.scale,
            spec.strategy.reorder(),
            spec.seed,
        );
        let plan = planner
            .plan(&req)
            .with_context(|| format!("planning deployment {:?}", spec.name))?;
        let f_data = engine
            .manifest
            .buckets
            .values()
            .map(|b| b.features)
            .max()
            .context("manifest has no buckets")?;
        let (x, labels) = apply_perm(&d.perm, &data.features(f_data), &data.labels(), f_data);
        let report = trainer::train(engine, &d, &x, f_data, &labels, &cfg, &plan)
            .with_context(|| format!("training deployment {:?}", spec.name))?;
        // Resolve the forward artifact and pack the static graph operands
        // ONCE — execute_group reuses them for every served batch.
        let (fwd_name, fwd_bucket, graph_ops) =
            trainer::plan_forward_operands(&engine.manifest, &d, &report.plan, spec.model)
                .with_context(|| format!("packing forward operands for {:?}", spec.name))?;
        let warm_secs = engine
            .warm(&fwd_name)
            .with_context(|| format!("warming forward executable for {:?}", spec.name))?;
        let n = d.graph.n;
        let final_loss = report.final_loss();
        self.insert(Deployment {
            name: spec.name,
            model: spec.model,
            strategy: spec.strategy,
            d,
            x,
            labels,
            f_data,
            n,
            plan: report.plan,
            params: report.params,
            bucket_vertices: fwd_bucket.vertices,
            classes: fwd_bucket.classes,
            fwd_name,
            fwd_bucket,
            graph_ops,
            final_loss,
            warm_secs,
        })
    }

    /// Register an already-built deployment; errors on a duplicate name.
    pub fn insert(&mut self, dep: Deployment) -> Result<&Deployment> {
        match self.deployments.entry(dep.name.clone()) {
            Entry::Occupied(_) => bail!("deployment {:?} already exists", dep.name),
            Entry::Vacant(slot) => Ok(slot.insert(dep)),
        }
    }

    pub fn get(&self, name: &str) -> Result<&Deployment> {
        self.deployments.get(name).ok_or_else(|| self.unknown(name))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Deployment> {
        if !self.deployments.contains_key(name) {
            return Err(self.unknown(name));
        }
        Ok(self.deployments.get_mut(name).unwrap())
    }

    fn unknown(&self, name: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "unknown deployment {name:?} (deployed: [{}])",
            self.names().join(", ")
        )
    }

    pub fn names(&self) -> Vec<&str> {
        self.deployments.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.deployments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deployments.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::gpusim::A100;
    use crate::partition::{Propagation, Reorder};
    use crate::plan::{Fingerprint, SimCostPlanner};
    use crate::runtime::BucketInfo;
    use crate::util::rng::Rng;

    /// A structurally valid deployment with no trained parameters — enough
    /// for registry bookkeeping tests without artifacts or a PJRT client.
    fn dummy(name: &str) -> Deployment {
        let mut rng = Rng::new(3);
        let g = planted_partition(64, 4, 0.5, 0.05, &mut rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 4, 0);
        let n = d.graph.n;
        let bucket = BucketInfo {
            name: "b64".to_string(),
            vertices: n,
            edges: 4096,
            features: 8,
            hidden: 8,
            classes: 4,
            blocks: 16,
        };
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        Deployment {
            name: name.to_string(),
            model: ModelKind::Gcn,
            strategy: Strategy::AdaptGear,
            d,
            x: vec![0.0; n * 8],
            labels: vec![0; n],
            f_data: 8,
            n,
            plan,
            params: Vec::new(),
            fwd_name: "fwd_dummy".to_string(),
            fwd_bucket: bucket,
            graph_ops: Vec::new(),
            bucket_vertices: n,
            classes: 4,
            final_loss: 0.0,
            warm_secs: 0.0,
        }
    }

    #[test]
    fn double_deploy_is_an_error() {
        let mut r = ModelRegistry::new();
        r.insert(dummy("citeseer-gcn")).unwrap();
        let err = r.insert(dummy("citeseer-gcn")).unwrap_err();
        assert!(err.to_string().contains("already exists"), "{err}");
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn unknown_model_is_an_error_listing_deployments() {
        let mut r = ModelRegistry::new();
        assert!(r.get("nope").is_err());
        r.insert(dummy("cora-gcn")).unwrap();
        let err = r.get_mut("nope").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown deployment"), "{msg}");
        assert!(msg.contains("cora-gcn"), "error should list live deployments: {msg}");
    }

    #[test]
    fn classify_takes_argmax_over_class_prefix() {
        let dep = dummy("m");
        // bucket_vertices = n, classes = 4; craft logits with stride 4
        let mut logits = vec![0.0f32; dep.bucket_vertices * 4];
        logits[2 * 4 + 3] = 9.0; // vertex 2 -> class 3
        assert_eq!(dep.classify(&logits, 2), 3);
        assert_eq!(dep.classify(&logits, 0), 0);
    }

    #[test]
    fn deployment_records_its_plan() {
        let dep = dummy("planned");
        assert_eq!(dep.plan.fingerprint, Fingerprint::of(&dep.d, ModelKind::Gcn));
        assert!(!dep.plan.provenance.cached);
    }

    /// A swap payload for `dep`: its graph with 4 appended vertices
    /// forming a clique, re-decomposed and re-planned at graph version 1.
    fn swap_for(dep: &Deployment) -> PlanSwap {
        use crate::stream::{CsrOverlay, DeltaLog, DeltaOp};
        let n0 = dep.n as u32;
        let mut overlay = CsrOverlay::new(dep.d.whole());
        let mut log = DeltaLog::new();
        overlay.apply(&log.append(DeltaOp::AddVertices { count: 4 })).unwrap();
        for u in n0..n0 + 4 {
            for v in (u + 1)..n0 + 4 {
                overlay.apply(&log.append(DeltaOp::InsertEdge { u, v, w: 0.5 })).unwrap();
            }
        }
        let d = Decomposition::from_propagation_ordered(&overlay.to_csr(), dep.d.community);
        let mut bucket = dep.fwd_bucket.clone();
        bucket.vertices = d.graph.n;
        bucket.blocks = d.graph.n.div_ceil(dep.d.community);
        let mut req = PlanRequest::new(&d, dep.model, &bucket);
        req.graph_version = 1;
        let plan = SimCostPlanner::new(&A100).plan(&req).unwrap();
        PlanSwap {
            plan,
            d,
            graph_ops: Vec::new(),
            fwd_name: "fwd_dummy_v1".to_string(),
            fwd_bucket: bucket,
            new_rows: vec![0.5; 4 * dep.f_data],
            new_labels: vec![1; 4],
        }
    }

    #[test]
    fn apply_swap_replaces_plan_and_extends_state() {
        let mut dep = dummy("swappable");
        let old_fp = dep.plan.fingerprint;
        let swap = swap_for(&dep);
        let expect = swap.plan.fingerprint;
        let fp = dep.apply_swap(swap).unwrap();
        assert_eq!(fp, expect);
        assert_ne!(fp, old_fp, "graph version is in the fingerprint");
        assert_eq!(dep.n, 68);
        assert_eq!(dep.x.len(), 68 * dep.f_data);
        assert_eq!(dep.labels.len(), 68);
        assert_eq!(dep.labels[67], 1);
        assert_eq!(dep.fwd_name, "fwd_dummy_v1");
        assert_eq!(dep.plan.graph_version, 1);
        assert!(dep.plan.validate(&dep.d, dep.model).is_ok());
    }

    #[test]
    fn apply_swap_rejects_bad_payloads_without_mutating() {
        let mut dep = dummy("guarded");
        let (n, fp, xlen) = (dep.n, dep.plan.fingerprint, dep.x.len());

        // wrong feature-row count for the appended vertices
        let mut bad = swap_for(&dep);
        bad.new_rows.pop();
        let err = dep.apply_swap(bad).unwrap_err();
        assert!(err.to_string().contains("feature values"), "{err}");

        // plan does not validate against the swap's decomposition
        let mut mismatched = swap_for(&dep);
        mismatched.plan = dep.plan.clone(); // old plan, new graph
        let err = dep.apply_swap(mismatched).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");

        // a shrinking swap is rejected outright
        let mut rng = Rng::new(9);
        let small_g = planted_partition(32, 4, 0.5, 0.05, &mut rng);
        let small =
            Decomposition::build(&small_g, Reorder::Identity, Propagation::GcnNormalized, 4, 0);
        let mut shrink = swap_for(&dep);
        shrink.d = small;
        let err = dep.apply_swap(shrink).unwrap_err();
        assert!(err.to_string().contains("shrinks"), "{err}");

        // every rejection left the deployment untouched
        assert_eq!((dep.n, dep.plan.fingerprint, dep.x.len()), (n, fp, xlen));
    }
}
