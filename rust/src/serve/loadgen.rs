//! Closed-loop synthetic load generator.
//!
//! Spawns `clients` producer threads, each holding a [`ServeClient`]
//! clone and playing a closed loop: submit one feature-perturbation
//! request, block for the answer, repeat. Offered concurrency therefore
//! equals the client count — the standard closed-loop model, where
//! micro-batch occupancy is bounded by how many clients are in flight
//! while the coordinator executes the previous batch.
//!
//! Shed requests are dropped (the whole point of load shedding) and
//! counted; they are NOT retried, so `answered + shed + failed == sent`.

use std::thread;

use super::session::{ServeClient, ServeError};
use crate::util::rng::Rng;

/// Load shape knobs.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent closed-loop clients (threads).
    pub clients: usize,
    pub seed: u64,
    /// Scale of the gaussian feature perturbation each request applies.
    pub delta_scale: f32,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig { requests: 500, clients: 32, seed: 99, delta_scale: 0.1 }
    }
}

/// Aggregated client-side outcome counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadGenSummary {
    pub sent: usize,
    pub answered: usize,
    pub shed: usize,
    /// Server-side error replies (unknown deployment, PJRT failure).
    pub failed: usize,
}

/// Running load generator; `join` blocks until every client finishes.
pub struct LoadGen {
    handles: Vec<thread::JoinHandle<LoadGenSummary>>,
}

impl LoadGen {
    pub fn join(self) -> LoadGenSummary {
        let mut total = LoadGenSummary::default();
        for h in self.handles {
            let s = h.join().expect("loadgen client thread panicked");
            total.sent += s.sent;
            total.answered += s.answered;
            total.shed += s.shed;
            total.failed += s.failed;
        }
        total
    }
}

/// Start the generator against `deployment`, perturbing random features
/// of random vertices in a `[n, f_data]` feature matrix. Takes ownership
/// of `client` and drops it once all clones are distributed, so the
/// serving loop shuts down exactly when the last client finishes.
pub fn spawn(
    client: ServeClient,
    deployment: String,
    n: usize,
    f_data: usize,
    cfg: LoadGenConfig,
) -> LoadGen {
    let clients = cfg.clients.max(1);
    let mut seed_rng = Rng::new(cfg.seed);
    let handles = (0..clients)
        .map(|k| {
            // requests split as evenly as possible across clients
            let share = cfg.requests / clients + usize::from(k < cfg.requests % clients);
            let client = client.clone();
            let deployment = deployment.clone();
            let mut rng = seed_rng.fork(k as u64);
            let delta_scale = cfg.delta_scale;
            thread::spawn(move || {
                let mut s = LoadGenSummary::default();
                for _ in 0..share {
                    let v = rng.usize_below(n.max(1));
                    let j = rng.usize_below(f_data.max(1));
                    let delta = rng.normal_f32() * delta_scale;
                    s.sent += 1;
                    match client.call(&deployment, v, j, delta) {
                        Ok(_) => s.answered += 1,
                        Err(ServeError::Shed) => s.shed += 1,
                        Err(ServeError::Remote(_)) => s.failed += 1,
                        Err(ServeError::Closed) => {
                            // server gone; nothing further will succeed
                            s.failed += 1;
                            break;
                        }
                    }
                }
                s
            })
        })
        .collect();
    // `client` (the original handle) drops here; only thread-held clones
    // keep the request channel open.
    LoadGen { handles }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_accounting_identity() {
        let s = LoadGenSummary { sent: 10, answered: 7, shed: 2, failed: 1 };
        assert_eq!(s.answered + s.shed + s.failed, s.sent);
    }

    #[test]
    fn default_config_matches_acceptance_shape() {
        let cfg = LoadGenConfig::default();
        assert_eq!(cfg.requests, 500);
        assert!(cfg.clients > 1, "closed-loop batching needs concurrency");
    }
}
