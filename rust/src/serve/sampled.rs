//! Sampled inference: classify target vertices without packing the whole
//! graph.
//!
//! A deployment's full graph must normally fit an AOT bucket. When it
//! does not — or when only a handful of vertices need fresh logits —
//! [`SampledInference`] samples the targets' receptive field out of the
//! deployment's propagation matrix, decomposes the batch, plans it
//! through the amortized [`BatchPlanner`] (profile hits skip the
//! threshold sweep), and executes ONE forward artifact sized to the
//! batch's bucket. The deployment's trained parameters are reused as-is,
//! which requires the batch bucket to share the deployment's
//! (features, hidden, classes) widths — a mismatch is an error, not a
//! silent quality drop.
//!
//! Under full fanouts the sampled logits for the targets equal the
//! full-graph forward's (the zero-padding/merging argument of DESIGN.md
//! Sec. 10); uniform fanouts trade exactness for a bounded batch size.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::apply_perm;
use crate::graph::Csr;
use crate::gpusim::A100;
use crate::kernels::pack::{pack_assignment, pack_features};
use crate::partition::Reorder;
use crate::plan::{BatchPlanner, PlanRequest, Planner, SimCostPlanner};
use crate::runtime::{Engine, Manifest, Tensor};
use crate::sample::{Fanout, NeighborSampler};
use crate::util::rng::Rng;

use super::registry::Deployment;

/// Reusable sampled-inference state: fanouts, the per-deployment
/// propagation cache, and the amortized batch planner.
pub struct SampledInference {
    fanouts: Vec<Fanout>,
    reorder: Reorder,
    rng: Rng,
    planner: BatchPlanner<SimCostPlanner>,
    /// Deployment name → its whole propagation matrix (built once; the
    /// decomposition stores intra/inter separately).
    props: HashMap<String, Csr>,
}

impl SampledInference {
    pub fn new(fanouts: Vec<Fanout>, seed: u64) -> SampledInference {
        SampledInference {
            fanouts,
            reorder: Reorder::Metis,
            rng: Rng::new(seed ^ 0x5e7e),
            planner: BatchPlanner::new(SimCostPlanner::new(&A100), &A100),
            props: HashMap::new(),
        }
    }

    /// Amortized-planner hit rate across every inference served so far.
    pub fn plan_hit_rate(&self) -> f64 {
        self.planner.hit_rate()
    }

    /// Classify `targets` (deployment-order vertex ids) through one
    /// sampled forward. Returns `(vertex, class)` per deduplicated
    /// target, in input order.
    pub fn infer(
        &mut self,
        engine: &Engine,
        dep: &Deployment,
        targets: &[u32],
    ) -> Result<Vec<(u32, i32)>> {
        if targets.is_empty() {
            return Ok(Vec::new());
        }
        if targets.iter().any(|&t| (t as usize) >= dep.n) {
            bail!("target vertex out of range (deployment {} has n={})", dep.name, dep.n);
        }
        let prop = self
            .props
            .entry(dep.name.clone())
            .or_insert_with(|| dep.d.whole());
        let sampler = NeighborSampler::new(prop, self.fanouts.clone())?;
        let batch = sampler.sample(targets, &mut self.rng);
        let bd = batch.decompose(self.reorder, dep.d.community, 0);

        let needed = bd.intra.nnz().max(bd.inter.nnz());
        let bucket = engine
            .manifest
            .fit_bucket(bd.graph.n, needed)
            .with_context(|| {
                format!(
                    "no AOT bucket fits the sampled batch (n={}, edges={needed}); \
                     lower the fanout or batch fewer targets",
                    bd.graph.n
                )
            })?
            .clone();
        let dep_widths = (
            dep.fwd_bucket.features,
            dep.fwd_bucket.hidden,
            dep.fwd_bucket.classes,
        );
        if (bucket.features, bucket.hidden, bucket.classes) != dep_widths {
            bail!(
                "batch bucket {} widths {:?} differ from deployment bucket {} widths {:?}; \
                 the trained parameters do not transfer",
                bucket.name,
                (bucket.features, bucket.hidden, bucket.classes),
                dep.fwd_bucket.name,
                dep_widths
            );
        }

        let req = PlanRequest::labeled(
            &bd,
            dep.model,
            &bucket,
            &format!("sampled:{}", dep.name),
            1.0,
            self.reorder,
            0,
        );
        let plan = self.planner.plan(&req).context("planning the sampled batch")?;

        let (intra_ops, inter_ops) =
            pack_assignment(&bd, &plan.assignment, &bucket).context("packing the sampled batch")?;
        let gx = batch.gather_features(&dep.x, dep.f_data);
        let zeros = vec![0i32; batch.n()];
        let (bx, _) = apply_perm(&bd.perm, &gx, &zeros, dep.f_data);

        let name = Manifest::fwd_name(
            dep.model.as_str(),
            plan.chosen.intra_str(),
            &plan.chosen.inter.to_string(),
            &bucket.name,
        );
        let mut args: Vec<Tensor> = dep.params.to_vec();
        args.extend(intra_ops);
        args.extend(inter_ops);
        args.push(pack_features(&bx, batch.n(), dep.f_data, &bucket)?);
        let out = engine.run(&name, &args)?;
        let logits: Vec<f32> = out[0].to_vec()?;

        let width = logits.len() / bucket.vertices.max(1);
        let span = bucket.classes.min(width);
        let rows = batch.target_rows(&bd);
        let mut result = Vec::with_capacity(rows.len());
        for (i, &r) in rows.iter().enumerate() {
            let row = &logits[r * width..r * width + span];
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(j, _)| j as i32)
                .unwrap_or(0);
            result.push((batch.targets()[i], class));
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample::parse_fanouts;

    #[test]
    fn construction_and_counters() {
        let s = SampledInference::new(parse_fanouts("5,5").unwrap(), 3);
        assert_eq!(s.plan_hit_rate(), 0.0);
        assert_eq!(s.fanouts.len(), 2);
        assert!(s.props.is_empty());
    }
}
