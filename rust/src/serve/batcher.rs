//! Micro-batching: coalesce queued requests into one artifact execution.
//!
//! The batcher is a pure state machine over injected `Instant`s so the
//! coalescing policy is unit-testable without threads or a PJRT engine.
//! A batch closes on whichever comes first:
//!
//! * **max-batch** — the pending set reaches `max_batch` (returned from
//!   [`MicroBatcher::push`]), or
//! * **max-wait** — the *oldest* pending request has waited `max_wait`
//!   (returned from [`MicroBatcher::poll`] once the deadline passes).
//!
//! The event loop sleeps on `recv_timeout` until [`MicroBatcher::deadline`]
//! and calls `poll` on wakeup, so an idle queue costs nothing and a lone
//! request is never delayed by more than `max_wait`.

use std::time::{Duration, Instant};

/// Coalescing policy state. `T` is the queued request type.
#[derive(Debug)]
pub struct MicroBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    pending: Vec<T>,
    /// Set when the first item of the open batch arrives.
    deadline: Option<Instant>,
}

impl<T> MicroBatcher<T> {
    /// `max_batch` is clamped to at least 1; `max_batch == 1` disables
    /// coalescing (every push closes a batch immediately).
    pub fn new(max_batch: usize, max_wait: Duration) -> MicroBatcher<T> {
        MicroBatcher {
            max_batch: max_batch.max(1),
            max_wait,
            pending: Vec::new(),
            deadline: None,
        }
    }

    /// Enqueue one item at time `now`; returns the closed batch when it
    /// reaches `max_batch`.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.deadline = Some(now + self.max_wait);
        }
        self.pending.push(item);
        if self.pending.len() >= self.max_batch {
            let batch = self.take();
            if batch.is_some() {
                crate::obs::counter("serve.batch.close_full").inc();
            }
            batch
        } else {
            None
        }
    }

    /// Close the open batch if its deadline has passed at time `now`.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        match self.deadline {
            Some(d) if now >= d => {
                let batch = self.take();
                if batch.is_some() {
                    crate::obs::counter("serve.batch.close_deadline").inc();
                }
                batch
            }
            _ => None,
        }
    }

    /// Close whatever is pending regardless of size or age (shutdown).
    pub fn flush(&mut self) -> Option<Vec<T>> {
        let batch = self.take();
        if batch.is_some() {
            crate::obs::counter("serve.batch.close_flush").inc();
        }
        batch
    }

    /// When the event loop must wake to honor max-wait; `None` while the
    /// batcher is empty (sleep indefinitely).
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    fn take(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            return None;
        }
        self.deadline = None;
        Some(std::mem::take(&mut self.pending))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_batch_closes_immediately() {
        let mut b = MicroBatcher::new(3, Duration::from_secs(60));
        let t0 = Instant::now();
        assert_eq!(b.push(1, t0), None);
        assert_eq!(b.push(2, t0), None);
        assert_eq!(b.push(3, t0), Some(vec![1, 2, 3]));
        // batch closed: pending cleared, deadline cleared
        assert!(b.is_empty());
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn max_wait_closes_partial_batch() {
        let wait = Duration::from_millis(5);
        let mut b = MicroBatcher::new(16, wait);
        let t0 = Instant::now();
        assert_eq!(b.push(7, t0), None);
        // before the deadline nothing closes
        assert_eq!(b.poll(t0), None);
        assert_eq!(b.poll(t0 + wait / 2), None);
        // at/after the deadline the undersized batch is released
        assert_eq!(b.poll(t0 + wait), Some(vec![7]));
        assert_eq!(b.poll(t0 + wait * 2), None, "closed batch does not re-fire");
    }

    #[test]
    fn deadline_is_anchored_to_oldest_item() {
        let wait = Duration::from_millis(10);
        let mut b = MicroBatcher::new(16, wait);
        let t0 = Instant::now();
        assert_eq!(b.push(1, t0), None);
        // later arrivals must not extend the oldest item's wait
        assert_eq!(b.push(2, t0 + Duration::from_millis(9)), None);
        assert_eq!(b.deadline(), Some(t0 + wait));
        assert_eq!(b.poll(t0 + wait), Some(vec![1, 2]));
    }

    #[test]
    fn flush_releases_pending() {
        let mut b = MicroBatcher::new(16, Duration::from_secs(60));
        assert_eq!(b.flush(), None::<Vec<u8>>);
        assert_eq!(b.push(9, Instant::now()), None);
        assert_eq!(b.flush(), Some(vec![9]));
    }

    #[test]
    fn close_causes_are_counted() {
        // Counters are process-global; other tests may bump them in
        // parallel, so assert on at-least deltas.
        let full = crate::obs::counter("serve.batch.close_full");
        let deadline = crate::obs::counter("serve.batch.close_deadline");
        let flush = crate::obs::counter("serve.batch.close_flush");
        let (f0, d0, l0) = (full.get(), deadline.get(), flush.get());
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(1, Duration::from_millis(1));
        assert!(b.push(1, t0).is_some());
        let mut b2 = MicroBatcher::new(4, Duration::from_millis(1));
        assert_eq!(b2.push(1, t0), None);
        assert!(b2.poll(t0 + Duration::from_millis(1)).is_some());
        assert_eq!(b2.push(2, t0), None);
        assert!(b2.flush().is_some());
        assert!(full.get() > f0);
        assert!(deadline.get() > d0);
        assert!(flush.get() > l0);
    }

    #[test]
    fn max_batch_one_disables_coalescing() {
        let mut b = MicroBatcher::new(1, Duration::from_secs(60));
        assert_eq!(b.push('a', Instant::now()), Some(vec!['a']));
        // zero clamps to one rather than never closing
        let mut z = MicroBatcher::new(0, Duration::from_secs(60));
        assert_eq!(z.push('b', Instant::now()), Some(vec!['b']));
    }
}
