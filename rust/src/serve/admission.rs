//! Admission control: bound the number of in-flight requests and shed
//! load beyond capacity instead of letting queues grow without bound.
//!
//! The controller is shared (`Arc`) between producer threads, which call
//! [`Admission::try_admit`] before sending, and the coordinator event
//! loop, which calls [`Admission::release`] once a request has been
//! answered. "Depth" therefore counts requests anywhere in the system —
//! channel, batcher, or executing — which is the quantity an SLO cares
//! about (queueing delay is part of latency).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Shared admission state; counters are monotonic except `depth`.
#[derive(Debug)]
pub struct Admission {
    capacity: usize,
    depth: AtomicUsize,
    admitted: AtomicUsize,
    shed: AtomicUsize,
}

impl Admission {
    /// `capacity` is clamped to at least 1 so a misconfigured controller
    /// degrades to serial admission rather than shedding everything.
    pub fn new(capacity: usize) -> Admission {
        Admission {
            capacity: capacity.max(1),
            depth: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
        }
    }

    /// Try to take an in-flight slot. On `false` the request is shed and
    /// the caller must NOT send it; the rejection is already counted.
    pub fn try_admit(&self) -> bool {
        let won = self
            .depth
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |d| {
                (d < self.capacity).then_some(d + 1)
            })
            .is_ok();
        if won {
            self.admitted.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
        }
        won
    }

    /// Return an in-flight slot (request answered or dropped server-side).
    pub fn release(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "release without matching admit");
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently in the system (queued, batched, or executing).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Total requests ever admitted.
    pub fn admitted(&self) -> usize {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total requests ever shed.
    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    /// Total requests ever offered (admitted + shed).
    pub fn offered(&self) -> usize {
        self.admitted() + self.shed()
    }

    /// Fraction of offered load that was shed; 0.0 before any traffic.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            0.0
        } else {
            self.shed() as f64 / offered as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sheds_at_capacity() {
        let a = Admission::new(2);
        assert!(a.try_admit());
        assert!(a.try_admit());
        assert!(!a.try_admit(), "third concurrent request must shed");
        assert_eq!(a.depth(), 2);
        assert_eq!(a.admitted(), 2);
        assert_eq!(a.shed(), 1);
        assert_eq!(a.offered(), 3);
        assert!((a.shed_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn release_reopens_capacity() {
        let a = Admission::new(1);
        assert!(a.try_admit());
        assert!(!a.try_admit());
        a.release();
        assert_eq!(a.depth(), 0);
        assert!(a.try_admit(), "freed slot is admittable again");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let a = Admission::new(0);
        assert_eq!(a.capacity(), 1);
        assert!(a.try_admit());
        assert!(!a.try_admit());
    }

    #[test]
    fn empty_controller_has_zero_shed_rate() {
        assert_eq!(Admission::new(8).shed_rate(), 0.0);
    }

    #[test]
    fn concurrent_admits_never_exceed_capacity() {
        use std::sync::Arc;
        let a = Arc::new(Admission::new(4));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    let mut taken = 0usize;
                    for _ in 0..100 {
                        if a.try_admit() {
                            taken += 1;
                            assert!(a.depth() <= 4);
                            a.release();
                        }
                    }
                    taken
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(a.depth(), 0);
        assert_eq!(a.admitted(), total);
        assert_eq!(a.offered(), 800);
    }
}
