//! Production inference serving on top of the adaptive kernel stack.
//!
//! The paper motivates AdaptGear with real-time graph analysis (Sec. 1);
//! this subsystem is the runtime that turns the trained artifact stack
//! into a service: throughput scales with *batched artifact executions*
//! instead of per-request PJRT calls.
//!
//! * [`registry`] — named (dataset, model-kind, strategy) deployments,
//!   each owning its trained parameters, the [`crate::plan::GearPlan`]
//!   that chose its kernels (served from the persistent plan cache on
//!   redeploy), and the mutable permuted feature/label state requests
//!   perturb.
//! * [`batcher`] — micro-batching: coalesce requests into one forward
//!   execution per tick (max-batch / max-wait policy).
//! * [`admission`] — bounded in-flight depth with load shedding.
//! * [`session`] — the single-owner PJRT event loop (PJRT handles are not
//!   `Send`) fed by `std::sync::mpsc` channels from producer threads. The
//!   same channel carries [`registry::PlanSwap`] control messages, so a
//!   streaming replan swaps into a live deployment in submission order
//!   without draining the request queue.
//! * [`metrics`] — SLO accounting: p50/p95/p99 latency, throughput, shed
//!   rate, and the batch-occupancy histogram.
//! * [`loadgen`] — closed-loop synthetic load for the `serve` subcommand,
//!   the serve bench, and the integration tests.
//! * [`sampled`] — sampled inference for target nodes on graphs too
//!   large to pack whole: one forward over the targets' sampled
//!   receptive field, planned through the amortized batch planner.
//!
//! See `rust/DESIGN.md` (Serving subsystem) for the channel topology and
//! SLO semantics. Entry points: the `serve` subcommand in `main.rs` and
//! the `serve_inference` example, both thin clients of this module.

pub mod admission;
pub mod batcher;
pub mod loadgen;
pub mod metrics;
pub mod registry;
pub mod sampled;
pub mod session;

pub use admission::Admission;
pub use batcher::MicroBatcher;
pub use loadgen::{LoadGen, LoadGenConfig, LoadGenSummary};
pub use metrics::{SloMetrics, SloReport, Stage, StageStats};
pub use registry::{Deployment, DeploymentSpec, ModelRegistry, PlanSwap};
pub use sampled::SampledInference;
pub use session::{
    Request, Response, ServeClient, ServeConfig, ServeError, ServeSession, SwapReceipt,
};
