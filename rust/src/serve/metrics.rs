//! SLO metrics: per-request latency percentiles, throughput, shed rate,
//! and the batch-occupancy histogram that shows whether micro-batching is
//! actually amortizing artifact executions.
//!
//! Recording is single-threaded (the coordinator event loop owns the
//! collector); [`SloMetrics::report`] folds in the admission counters at
//! shutdown to produce an immutable [`SloReport`].

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats;

/// Mutable collector owned by the serve event loop.
#[derive(Debug, Default)]
pub struct SloMetrics {
    latencies_ms: Vec<f64>,
    /// batch size -> number of forward executions at that occupancy
    occupancy: BTreeMap<usize, usize>,
    forward_calls: usize,
    served: usize,
    errors: usize,
}

impl SloMetrics {
    pub fn new() -> SloMetrics {
        SloMetrics::default()
    }

    /// One request answered successfully; `latency` is enqueue -> reply.
    pub fn record_reply(&mut self, latency: Duration) {
        self.served += 1;
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    /// One request answered with an error (still counts toward depth
    /// release, not toward latency percentiles).
    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// One forward artifact execution serving `occupancy` requests.
    pub fn record_forward(&mut self, occupancy: usize) {
        self.forward_calls += 1;
        *self.occupancy.entry(occupancy).or_insert(0) += 1;
    }

    pub fn served(&self) -> usize {
        self.served
    }

    pub fn forward_calls(&self) -> usize {
        self.forward_calls
    }

    /// Freeze into a report. `wall_secs` is the serving-loop wall time;
    /// `offered`/`shed` come from the admission controller.
    pub fn report(&self, wall_secs: f64, offered: usize, shed: usize) -> SloReport {
        let batched: usize = self.occupancy.iter().map(|(size, count)| size * count).sum();
        SloReport {
            offered,
            shed,
            served: self.served,
            errors: self.errors,
            forward_calls: self.forward_calls,
            wall_secs,
            p50_ms: stats::percentile(&self.latencies_ms, 50.0),
            p95_ms: stats::percentile(&self.latencies_ms, 95.0),
            p99_ms: stats::percentile(&self.latencies_ms, 99.0),
            max_ms: if self.latencies_ms.is_empty() { 0.0 } else { stats::max(&self.latencies_ms) },
            throughput_rps: if wall_secs > 0.0 { self.served as f64 / wall_secs } else { 0.0 },
            mean_occupancy: if self.forward_calls > 0 {
                batched as f64 / self.forward_calls as f64
            } else {
                0.0
            },
            shed_rate: if offered > 0 { shed as f64 / offered as f64 } else { 0.0 },
            occupancy: self.occupancy.clone(),
        }
    }
}

/// Immutable end-of-run SLO summary.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub offered: usize,
    pub shed: usize,
    pub served: usize,
    pub errors: usize,
    pub forward_calls: usize,
    pub wall_secs: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub throughput_rps: f64,
    /// Mean requests amortized per forward execution (1.0 = no batching).
    pub mean_occupancy: f64,
    pub shed_rate: f64,
    pub occupancy: BTreeMap<usize, usize>,
}

impl SloReport {
    /// Multi-line human-readable summary (the `serve` subcommand output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} of {} offered in {:.2}s ({} shed, {} errors)\n",
            self.served, self.offered, self.wall_secs, self.shed, self.errors
        ));
        out.push_str(&format!(
            "latency    p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | max {:.2} ms\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        ));
        out.push_str(&format!(
            "throughput {:.1} req/s | shed rate {:.2}%\n",
            self.throughput_rps,
            self.shed_rate * 100.0
        ));
        out.push_str(&format!(
            "batching   {} forward calls for {} requests (mean occupancy {:.2})\n",
            self.forward_calls, self.served, self.mean_occupancy
        ));
        out.push_str("occupancy  ");
        let peak = self.occupancy.values().copied().max().unwrap_or(0).max(1);
        for (size, count) in &self.occupancy {
            let bar = "#".repeat((count * 20).div_ceil(peak));
            out.push_str(&format!("\n  {size:>4} reqs/batch x{count:<5} {bar}"));
        }
        out.push('\n');
        out
    }

    /// JSON encoding for `BENCH_serve.json` and downstream tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered", Json::num(self.offered as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("served", Json::num(self.served as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("forward_calls", Json::num(self.forward_calls as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("mean_occupancy", Json::num(self.mean_occupancy)),
            ("shed_rate", Json::num(self.shed_rate)),
            (
                "occupancy",
                Json::Arr(
                    self.occupancy
                        .iter()
                        .map(|(size, count)| {
                            Json::obj(vec![
                                ("batch", Json::num(*size as f64)),
                                ("count", Json::num(*count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let mut m = SloMetrics::new();
        for ms in [1.0, 2.0, 3.0, 4.0] {
            m.record_reply(Duration::from_secs_f64(ms / 1e3));
        }
        m.record_forward(3);
        m.record_forward(1);
        m.record_error();
        let r = m.report(2.0, 6, 1);
        assert_eq!(r.served, 4);
        assert_eq!(r.errors, 1);
        assert_eq!(r.forward_calls, 2);
        assert_eq!(r.throughput_rps, 2.0);
        assert!((r.mean_occupancy - 2.0).abs() < 1e-12);
        assert!((r.shed_rate - 1.0 / 6.0).abs() < 1e-12);
        assert!((r.p50_ms - 2.5).abs() < 1e-9);
        assert_eq!(r.max_ms, 4.0);
        assert_eq!(r.occupancy.get(&3), Some(&1));
    }

    #[test]
    fn empty_collector_reports_zeros() {
        let r = SloMetrics::new().report(0.0, 0, 0);
        assert_eq!(r.served, 0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.max_ms, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.mean_occupancy, 0.0);
        assert_eq!(r.shed_rate, 0.0);
    }

    #[test]
    fn json_roundtrips_through_writer() {
        let mut m = SloMetrics::new();
        m.record_reply(Duration::from_millis(2));
        m.record_forward(1);
        let text = crate::util::json::write(&m.report(1.0, 1, 0).to_json());
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("served").as_usize(), Some(1));
        assert_eq!(parsed.get("occupancy").idx(0).get("batch").as_usize(), Some(1));
    }
}
