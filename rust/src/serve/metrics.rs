//! SLO metrics: per-request latency percentiles, throughput, shed rate,
//! the four-way per-stage latency split (queue wait / batch wait /
//! feature pack / execute), and the batch-occupancy histogram that shows
//! whether micro-batching is actually amortizing artifact executions.
//!
//! Latency series are bounded: every collection keeps an exact
//! count/sum/max but samples its percentile basis through a fixed-size
//! [`Reservoir`] ([`DEFAULT_RESERVOIR_CAP`] slots), so a long loadgen
//! run cannot grow collector memory without bound.
//!
//! Recording is single-threaded (the coordinator event loop owns the
//! collector); [`SloMetrics::report`] folds in the admission counters at
//! shutdown to produce an immutable [`SloReport`].

use std::collections::BTreeMap;
use std::time::Duration;

use crate::obs::{Reservoir, DEFAULT_RESERVOIR_CAP};
use crate::util::json::Json;
use crate::util::stats;

/// The serving pipeline stages a request's latency decomposes into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Channel time: submit -> picked up by the event loop.
    Queue,
    /// Batcher time: picked up -> the micro-batch closed.
    Batch,
    /// Feature packing inside the forward call.
    Pack,
    /// Artifact execution inside the forward call.
    Execute,
}

impl Stage {
    pub const ALL: [Stage; 4] = [Stage::Queue, Stage::Batch, Stage::Pack, Stage::Execute];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Batch => "batch",
            Stage::Pack => "pack",
            Stage::Execute => "execute",
        }
    }
}

/// Bounded latency series: exact count/sum/max, reservoir-sampled
/// percentile basis.
#[derive(Debug)]
struct Series {
    res: Reservoir,
    count: usize,
    sum_ms: f64,
    max_ms: f64,
}

impl Series {
    fn new(seed: u64) -> Series {
        Series {
            res: Reservoir::new(DEFAULT_RESERVOIR_CAP, seed),
            count: 0,
            sum_ms: 0.0,
            max_ms: 0.0,
        }
    }

    fn record(&mut self, ms: f64) {
        self.count += 1;
        self.sum_ms += ms;
        self.max_ms = self.max_ms.max(ms);
        self.res.push(ms);
    }

    fn stats(&self) -> StageStats {
        let ps = stats::percentiles(self.res.samples(), &[50.0, 99.0]);
        StageStats {
            count: self.count,
            mean_ms: if self.count > 0 { self.sum_ms / self.count as f64 } else { 0.0 },
            p50_ms: ps[0],
            p99_ms: ps[1],
            max_ms: self.max_ms,
        }
    }
}

/// Mutable collector owned by the serve event loop.
#[derive(Debug)]
pub struct SloMetrics {
    latencies: Series,
    error_latencies: Series,
    stages: [Series; 4],
    /// batch size -> number of forward executions at that occupancy
    occupancy: BTreeMap<usize, usize>,
    forward_calls: usize,
    served: usize,
    errors: usize,
}

impl Default for SloMetrics {
    fn default() -> Self {
        SloMetrics {
            latencies: Series::new(0x510_0),
            error_latencies: Series::new(0x510_1),
            stages: [
                Series::new(0x510_2),
                Series::new(0x510_3),
                Series::new(0x510_4),
                Series::new(0x510_5),
            ],
            occupancy: BTreeMap::new(),
            forward_calls: 0,
            served: 0,
            errors: 0,
        }
    }
}

impl SloMetrics {
    pub fn new() -> SloMetrics {
        SloMetrics::default()
    }

    /// One request answered successfully; `latency` is enqueue -> reply.
    pub fn record_reply(&mut self, latency: Duration) {
        self.served += 1;
        self.latencies.record(latency.as_secs_f64() * 1e3);
    }

    /// One request answered with an error. Error latencies land in their
    /// own histogram — a fast-fail storm must not flatter the success
    /// percentiles.
    pub fn record_error(&mut self, latency: Duration) {
        self.errors += 1;
        self.error_latencies.record(latency.as_secs_f64() * 1e3);
    }

    /// One request's time in `stage` of the serving pipeline.
    pub fn record_stage(&mut self, stage: Stage, dur: Duration) {
        let idx = Stage::ALL.iter().position(|s| *s == stage).unwrap();
        self.stages[idx].record(dur.as_secs_f64() * 1e3);
    }

    /// One forward artifact execution serving `occupancy` requests.
    pub fn record_forward(&mut self, occupancy: usize) {
        self.forward_calls += 1;
        *self.occupancy.entry(occupancy).or_insert(0) += 1;
    }

    pub fn served(&self) -> usize {
        self.served
    }

    pub fn forward_calls(&self) -> usize {
        self.forward_calls
    }

    /// Freeze into a report. `wall_secs` is the serving-loop wall time;
    /// `offered`/`shed` come from the admission controller.
    pub fn report(&self, wall_secs: f64, offered: usize, shed: usize) -> SloReport {
        let batched: usize = self.occupancy.iter().map(|(size, count)| size * count).sum();
        let ps = stats::percentiles(self.latencies.res.samples(), &[50.0, 95.0, 99.0]);
        SloReport {
            offered,
            shed,
            served: self.served,
            errors: self.errors,
            forward_calls: self.forward_calls,
            wall_secs,
            p50_ms: ps[0],
            p95_ms: ps[1],
            p99_ms: ps[2],
            max_ms: self.latencies.max_ms,
            throughput_rps: if wall_secs > 0.0 { self.served as f64 / wall_secs } else { 0.0 },
            mean_occupancy: if self.forward_calls > 0 {
                batched as f64 / self.forward_calls as f64
            } else {
                0.0
            },
            shed_rate: if offered > 0 { shed as f64 / offered as f64 } else { 0.0 },
            stages: [
                self.stages[0].stats(),
                self.stages[1].stats(),
                self.stages[2].stats(),
                self.stages[3].stats(),
            ],
            error_ms: self.error_latencies.stats(),
            occupancy: self.occupancy.clone(),
        }
    }
}

/// Summary of one latency series (a pipeline stage or the error stream).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageStats {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl StageStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ])
    }
}

/// Immutable end-of-run SLO summary.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub offered: usize,
    pub shed: usize,
    pub served: usize,
    pub errors: usize,
    pub forward_calls: usize,
    pub wall_secs: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub throughput_rps: f64,
    /// Mean requests amortized per forward execution (1.0 = no batching).
    pub mean_occupancy: f64,
    pub shed_rate: f64,
    /// Per-stage latency split, indexed like [`Stage::ALL`]
    /// (queue / batch / pack / execute).
    pub stages: [StageStats; 4],
    /// Latency distribution of errored requests (separate from the
    /// success percentiles above).
    pub error_ms: StageStats,
    pub occupancy: BTreeMap<usize, usize>,
}

impl SloReport {
    /// Stats for one named pipeline stage.
    pub fn stage(&self, stage: Stage) -> &StageStats {
        &self.stages[Stage::ALL.iter().position(|s| *s == stage).unwrap()]
    }

    /// Multi-line human-readable summary (the `serve` subcommand output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} of {} offered in {:.2}s ({} shed, {} errors)\n",
            self.served, self.offered, self.wall_secs, self.shed, self.errors
        ));
        out.push_str(&format!(
            "latency    p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | max {:.2} ms\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        ));
        out.push_str("stages    ");
        for (stage, st) in Stage::ALL.iter().zip(self.stages.iter()) {
            out.push_str(&format!(" {} p50 {:.2} ms |", stage.name(), st.p50_ms));
        }
        out.pop();
        out.push('\n');
        if self.errors > 0 {
            out.push_str(&format!(
                "errors     {} requests | p50 {:.2} ms | p99 {:.2} ms | max {:.2} ms\n",
                self.error_ms.count, self.error_ms.p50_ms, self.error_ms.p99_ms,
                self.error_ms.max_ms
            ));
        }
        out.push_str(&format!(
            "throughput {:.1} req/s | shed rate {:.2}%\n",
            self.throughput_rps,
            self.shed_rate * 100.0
        ));
        out.push_str(&format!(
            "batching   {} forward calls for {} requests (mean occupancy {:.2})\n",
            self.forward_calls, self.served, self.mean_occupancy
        ));
        out.push_str("occupancy  ");
        let peak = self.occupancy.values().copied().max().unwrap_or(0).max(1);
        for (size, count) in &self.occupancy {
            let bar = "#".repeat((count * 20).div_ceil(peak));
            out.push_str(&format!("\n  {size:>4} reqs/batch x{count:<5} {bar}"));
        }
        out.push('\n');
        out
    }

    /// JSON encoding for `BENCH_serve.json` and downstream tooling.
    pub fn to_json(&self) -> Json {
        let stages = Json::Obj(
            Stage::ALL
                .iter()
                .zip(self.stages.iter())
                .map(|(stage, st)| (stage.name().to_string(), st.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("offered", Json::num(self.offered as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("served", Json::num(self.served as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("forward_calls", Json::num(self.forward_calls as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("mean_occupancy", Json::num(self.mean_occupancy)),
            ("shed_rate", Json::num(self.shed_rate)),
            ("stages", stages),
            ("error_latency", self.error_ms.to_json()),
            (
                "occupancy",
                Json::Arr(
                    self.occupancy
                        .iter()
                        .map(|(size, count)| {
                            Json::obj(vec![
                                ("batch", Json::num(*size as f64)),
                                ("count", Json::num(*count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let mut m = SloMetrics::new();
        for ms in [1.0, 2.0, 3.0, 4.0] {
            m.record_reply(Duration::from_secs_f64(ms / 1e3));
        }
        m.record_forward(3);
        m.record_forward(1);
        m.record_error(Duration::from_millis(9));
        let r = m.report(2.0, 6, 1);
        assert_eq!(r.served, 4);
        assert_eq!(r.errors, 1);
        assert_eq!(r.forward_calls, 2);
        assert_eq!(r.throughput_rps, 2.0);
        assert!((r.mean_occupancy - 2.0).abs() < 1e-12);
        assert!((r.shed_rate - 1.0 / 6.0).abs() < 1e-12);
        assert!((r.p50_ms - 2.5).abs() < 1e-9);
        assert_eq!(r.max_ms, 4.0);
        assert_eq!(r.occupancy.get(&3), Some(&1));
        // error latencies live in their own histogram
        assert_eq!(r.error_ms.count, 1);
        assert!((r.error_ms.max_ms - 9.0).abs() < 1e-9);
        // ... and never leak into the success percentiles
        assert!(r.max_ms < 9.0);
    }

    #[test]
    fn stage_split_is_per_stage() {
        let mut m = SloMetrics::new();
        m.record_stage(Stage::Queue, Duration::from_millis(1));
        m.record_stage(Stage::Queue, Duration::from_millis(3));
        m.record_stage(Stage::Batch, Duration::from_millis(2));
        m.record_stage(Stage::Pack, Duration::from_millis(4));
        m.record_stage(Stage::Execute, Duration::from_millis(8));
        let r = m.report(1.0, 0, 0);
        assert_eq!(r.stage(Stage::Queue).count, 2);
        assert!((r.stage(Stage::Queue).mean_ms - 2.0).abs() < 1e-9);
        assert!((r.stage(Stage::Queue).max_ms - 3.0).abs() < 1e-9);
        assert_eq!(r.stage(Stage::Batch).count, 1);
        assert!((r.stage(Stage::Pack).p50_ms - 4.0).abs() < 1e-9);
        assert!((r.stage(Stage::Execute).max_ms - 8.0).abs() < 1e-9);
        // the render shows the four-way split on one line
        let text = r.render();
        assert!(text.contains("queue p50"));
        assert!(text.contains("execute p50"));
    }

    #[test]
    fn latency_memory_stays_bounded_under_load() {
        let mut m = SloMetrics::new();
        for i in 0..3 * DEFAULT_RESERVOIR_CAP {
            m.record_reply(Duration::from_secs_f64(1e-3 + (i % 100) as f64 * 1e-5));
        }
        assert!(m.latencies.res.len() <= DEFAULT_RESERVOIR_CAP);
        let r = m.report(1.0, 0, 0);
        assert_eq!(r.served, 3 * DEFAULT_RESERVOIR_CAP);
        // percentiles stay inside the observed value range
        assert!(r.p50_ms >= 1.0 && r.p50_ms <= 2.0, "p50 {}", r.p50_ms);
        assert!(r.p99_ms >= 1.0 && r.p99_ms <= 2.0, "p99 {}", r.p99_ms);
        assert!(r.max_ms <= 2.0);
    }

    #[test]
    fn empty_collector_reports_zeros() {
        let r = SloMetrics::new().report(0.0, 0, 0);
        assert_eq!(r.served, 0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.max_ms, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.mean_occupancy, 0.0);
        assert_eq!(r.shed_rate, 0.0);
        assert_eq!(r.error_ms.count, 0);
        for stage in Stage::ALL {
            assert_eq!(r.stage(stage).count, 0);
            assert_eq!(r.stage(stage).p50_ms, 0.0);
        }
    }

    #[test]
    fn json_roundtrips_through_writer() {
        let mut m = SloMetrics::new();
        m.record_reply(Duration::from_millis(2));
        m.record_forward(1);
        m.record_stage(Stage::Execute, Duration::from_millis(1));
        let text = crate::util::json::write(&m.report(1.0, 1, 0).to_json());
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("served").as_usize(), Some(1));
        assert_eq!(parsed.get("occupancy").idx(0).get("batch").as_usize(), Some(1));
        assert_eq!(parsed.get("stages").get("execute").get("count").as_usize(), Some(1));
        assert_eq!(parsed.get("error_latency").get("count").as_usize(), Some(0));
    }
}
