//! Small statistics helpers shared by the bench harness and the figures.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean — the aggregation the paper uses for speedups; 0.0 for
/// empty input. All inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0,100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    percentiles(xs, &[q])[0]
}

/// Several linear-interpolated percentiles from one sort — the shape
/// every latency report needs (p50/p90/p99 off the same samples).
/// Empty input yields 0.0 for every quantile.
pub fn percentiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; qs.len()];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter()
        .map(|&q| {
            let pos = q / 100.0 * (v.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                v[lo]
            } else {
                v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
            }
        })
        .collect()
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_speedups_is_scale_invariant() {
        // geomean(a/b) == geomean(a) / geomean(b)
        let a = [2.0, 8.0, 3.0];
        let b = [1.0, 2.0, 6.0];
        let ratios: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x / y).collect();
        assert!((geomean(&ratios) - geomean(&a) / geomean(&b)).abs() < 1e-12);
    }

    #[test]
    fn percentile_basic() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_empty_input_is_zero() {
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[], q), 0.0);
        }
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn percentile_single_sample_is_that_sample() {
        let xs = [7.25];
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, q), 7.25, "q={q}");
        }
    }

    #[test]
    fn percentiles_matches_single_percentile() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        let qs = [0.0, 25.0, 50.0, 95.0, 100.0];
        let many = percentiles(&xs, &qs);
        for (i, &q) in qs.iter().enumerate() {
            assert_eq!(many[i], percentile(&xs, q), "q={q}");
        }
        assert_eq!(percentiles(&[], &qs), vec![0.0; qs.len()]);
    }

    #[test]
    fn stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.5];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.5);
    }
}
