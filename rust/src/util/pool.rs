//! Scoped parallel-map substrate (no `rayon` offline).
//!
//! The figure benches sweep 15 datasets × several strategies; on multi-core
//! hosts `par_map` fans the work across scoped threads, on this session's
//! single-core box it degrades gracefully to a serial loop with no thread
//! overhead.

/// Number of worker threads to use (respects `ADAPTGEAR_THREADS`).
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("ADAPTGEAR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel map preserving input order.
///
/// Splits `items` into `worker_count()` contiguous chunks and processes
/// each on a scoped thread. `f` must be `Sync` (called concurrently).
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let workers = worker_count();
    if workers <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let chunk = n.div_ceil(workers);
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);

    // Pair each item with its destination index, chunk, and scatter.
    let mut indexed: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let mut chunks: Vec<Vec<(usize, T)>> = Vec::new();
    while !indexed.is_empty() {
        let take = chunk.min(indexed.len());
        chunks.push(indexed.drain(..take).collect());
    }

    let slot_refs: Vec<&mut Option<U>> = slots.iter_mut().collect();
    // Distribute mutable slot references chunk-wise.
    let mut slot_iter = slot_refs.into_iter();
    let mut chunk_slots: Vec<Vec<&mut Option<U>>> = Vec::new();
    for c in &chunks {
        chunk_slots.push((&mut slot_iter).take(c.len()).collect());
    }

    std::thread::scope(|scope| {
        let f = &f;
        for (chunk, mut outs) in chunks.into_iter().zip(chunk_slots) {
            scope.spawn(move || {
                for ((_, item), out) in chunk.into_iter().zip(outs.iter_mut()) {
                    **out = Some(f(item));
                }
            });
        }
    });

    slots.into_iter().map(|s| s.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = par_map((0..100).collect(), |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item() {
        assert_eq!(par_map(vec![7], |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn heavier_closure() {
        let out = par_map((0..32u64).collect(), |x| {
            (0..1000).fold(x, |acc, i| acc.wrapping_mul(31).wrapping_add(i))
        });
        assert_eq!(out.len(), 32);
    }
}
