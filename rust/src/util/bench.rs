//! Micro-benchmark measurement substrate (no `criterion` offline).
//!
//! Criterion-style flow: warmup, then timed samples until a time or
//! iteration budget is reached; reports mean/median/p95 and flags noisy
//! runs. Used by every target under `rust/benches/`.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }
    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples)
    }
    /// Coefficient of variation — rough noise indicator.
    pub fn cv(&self) -> f64 {
        let m = self.mean_s();
        if m == 0.0 {
            0.0
        } else {
            self.stddev_s() / m
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median {:>12} mean {:>12} p95 (n={}{})",
            self.name,
            fmt_duration(self.median_s()),
            fmt_duration(self.mean_s()),
            fmt_duration(self.p95_s()),
            self.samples.len(),
            if self.cv() > 0.15 { ", NOISY" } else { "" },
        )
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Benchmark runner with a global time budget per measurement.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(750),
            min_samples: 5,
            max_samples: 200,
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(0),
            budget: Duration::from_millis(200),
            min_samples: 3,
            max_samples: 25,
        }
    }

    /// Measure `f`, returning per-iteration timing samples.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Sampling.
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (samples.len() < self.min_samples)
            || (b0.elapsed() < self.budget && samples.len() < self.max_samples)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement { name: name.to_string(), samples }
    }

    /// Measure and print the one-line report (the common call).
    pub fn bench<F: FnMut()>(&self, name: &str, f: F) -> Measurement {
        let m = self.run(name, f);
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_min_samples() {
        let b = Bench { budget: Duration::from_millis(1), min_samples: 7, ..Default::default() };
        let m = b.run("noop", || {});
        assert!(m.samples.len() >= 7);
    }

    #[test]
    fn respects_max_samples() {
        let b = Bench {
            warmup: Duration::ZERO,
            budget: Duration::from_secs(5),
            min_samples: 1,
            max_samples: 10,
        };
        let m = b.run("noop", || {});
        assert!(m.samples.len() <= 10);
    }

    #[test]
    fn timing_is_positive_and_ordered() {
        let b = Bench::quick();
        let fast = b.run("fast", || {
            std::hint::black_box(1 + 1);
        });
        let slow = b.run("slow", || {
            let mut x = 0u64;
            for i in 0..200_000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert!(slow.median_s() > fast.median_s());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500us");
        assert!(fmt_duration(5e-9).ends_with("ns"));
    }
}
