//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown flags are collected so commands can reject them with a helpful
//! message.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// All option keys + boolean flags, for unknown-argument validation.
    pub fn known_keys(&self) -> Vec<&str> {
        self.options
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--dataset", "cora", "--steps=100", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("dataset"), Some("cora"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "gcn"), "gcn");
        assert_eq!(a.get_f64("lr", 0.01), 0.01);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "val"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["--x", "-3"]);
        assert_eq!(a.get_f64("x", 0.0), -3.0);
    }
}
