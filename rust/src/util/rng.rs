//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding and xoshiro256++ for the main stream — the same
//! generators the `rand` ecosystem uses, reimplemented so graph generation
//! (RMAT, planted-partition) is reproducible from a single `u64` seed
//! across every figure bench.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-thread / per-dataset use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for graph generation; bound << 2^64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.usize_below(i + 1);
            slice.swap(i, j);
        }
    }

    /// `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(23);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
