//! Minimal JSON parser/writer substrate.
//!
//! The offline crate set has no `serde`/`serde_json`, so AdaptGear carries
//! its own small, strict JSON implementation: enough for the artifact
//! manifest (`artifacts/manifest.json`), benchmark result files, and config
//! files. Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index lookup; `Json::Null` when out of bounds.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

/// Parse error with byte offset for debugging malformed manifests.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), offset: self.pos })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                msg: "eof in \\u escape".into(),
                                offset: self.pos,
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    msg: "bad hex in \\u escape".into(),
                                    offset: self.pos,
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the sequence through
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    self.pos = end;
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => out.push('\u{fffd}'),
                    }
                }
                None => return self.err("eof in string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a value to compact JSON text.
pub fn write(value: &Json) -> String {
    let mut out = String::new();
    write_into(value, &mut out);
    out
}

fn write_into(value: &Json, out: &mut String) {
    match value {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            // JSON has no NaN/Infinity literal: `{n}` would emit a bare
            // `NaN` that no parser (ours included) reads back. A
            // non-finite number reaching serialization is a writer bug
            // upstream — fail here with the field-free context we have
            // rather than persist an unreadable artifact.
            assert!(n.is_finite(), "cannot serialize non-finite number {n} as JSON");
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "non-finite")]
    fn refuses_to_write_nan() {
        write(&Json::Num(f64::NAN));
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn refuses_to_write_infinity() {
        write(&Json::obj(vec![("w", Json::Num(f64::INFINITY))]));
    }

    #[test]
    fn overflowing_literal_still_parses_as_infinity() {
        // Rust's f64 parser saturates `1e999` to +inf, so non-finite
        // values CAN still enter through `parse` from foreign writers —
        // that ingress path is what lint AG003 audits semantically.
        assert_eq!(parse("1e999").unwrap(), Json::Num(f64::INFINITY));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true,"g":-1.5}"#,
            r#"[[],{},"",0]"#,
        ];
        for case in cases {
            let v = parse(case).unwrap();
            let text = write(&v);
            assert_eq!(parse(&text).unwrap(), v, "case {case}");
        }
    }

    #[test]
    fn writes_special_chars() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
