//! Infrastructure substrates the offline crate set does not provide:
//! JSON, CLI parsing, PRNG, parallel map, micro-benchmarking, property
//! testing, and shared statistics.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
