//! Mini property-testing substrate (no `proptest` offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop::check("csr roundtrip", 200, |rng| {
//!     let g = Graph::rmat(rng.usize_below(512) + 16, 4, rng);
//!     prop::require(g.to_csr().to_coo().edge_count() == g.edge_count(), "edges preserved")
//! });
//! ```

use super::rng::Rng;

/// Result of one property case: Ok(()) or a failure message.
pub type CaseResult = Result<(), String>;

/// Build a failure unless `cond` holds.
pub fn require(cond: bool, msg: &str) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two f64s are within tolerance.
pub fn require_close(a: f64, b: f64, tol: f64, msg: &str) -> CaseResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random instances of `property`. Panics (test failure) with
/// the seed of the first failing case.
pub fn check<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    // Honour ADAPTGEAR_PROP_SEED for deterministic replay of one case.
    if let Ok(seed) = std::env::var("ADAPTGEAR_PROP_SEED") {
        let seed: u64 = seed.parse().expect("ADAPTGEAR_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with ADAPTGEAR_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("u64 below bound", 50, |rng| {
            let b = rng.below(100) + 1;
            require(rng.below(b) < b, "below() out of range")
        });
    }

    #[test]
    #[should_panic(expected = "replay with ADAPTGEAR_PROP_SEED=")]
    fn failing_property_names_seed() {
        check("always fails", 3, |_rng| Err("nope".into()));
    }

    #[test]
    fn require_close_tolerances() {
        assert!(require_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(require_close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
