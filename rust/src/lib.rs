//! # AdaptGear
//!
//! Reproduction of *AdaptGear: Accelerating GNN Training via Adaptive
//! Subgraph-Level Kernels on GPUs* (Zhou et al., CF '23) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 1** (`python/compile/kernels/`): density-specialized Pallas
//!   aggregation kernels (CSR inter, CSR intra, COO scatter, dense block).
//! * **Layer 2** (`python/compile/model.py`): GCN/GIN forward + fused
//!   training step, AOT-lowered to HLO text per kernel combination.
//! * **Layer 3** (this crate): the paper's system contribution — graph
//!   decomposition, subgraph-level kernel mapping, and the feedback-driven
//!   adaptive selector — plus every substrate it needs (graph formats,
//!   METIS-like partitioner, GPU cost simulator, PJRT runtime), the
//!   [`plan`] subsystem that makes the kernel decision a first-class,
//!   cacheable artifact (`GearPlan` + pluggable planners + on-disk
//!   `PlanStore`), the [`serve`] inference-serving runtime (model
//!   registry, micro-batching, admission control, SLO metrics) layered on
//!   top, the [`sample`] subsystem (layer-wise neighbor sampling for
//!   mini-batch training and sampled inference, with a profile-keyed
//!   amortized batch planner in [`plan`]), and the [`bench`] subsystem —
//!   fixed-workload suites emitting schema-versioned `BENCH_*.json`
//!   reports with a baseline comparator that gates perf regressions in
//!   CI — all observable through [`obs`], the unified tracing/metrics
//!   layer (spans with Chrome-trace export, a global metrics registry,
//!   and persisted plan-decision provenance). The [`stream`] subsystem
//!   (Sec. 12) makes served graphs mutable: a versioned delta log and
//!   CSR overlay, a per-block density-drift tracker, and an online
//!   re-planner that swaps refreshed plans into live deployments. The
//!   [`check`] subsystem (Sec. 13) statically audits everything the
//!   others persist: `adaptgear check` runs an analyzer registry with
//!   stable `AG*` lint codes over plans, delta logs, traces, and bench
//!   reports, and every artifact writer re-runs its own analyzer as a
//!   debug-build assertion.
//!
//! See `rust/DESIGN.md` for the full architecture inventory, including
//! the plan lifecycle (Sec. 7), the serving subsystem's channel
//! topology and SLO semantics, and the benchmarking/CI contract (Sec. 9).

// Crate-wide lint posture (DESIGN.md Sec. 13): no unsafe anywhere —
// this crate is pure data-structure + orchestration code, and the FFI
// boundary lives behind the `xla` dependency — and the debug/leak
// macros stay out of committed code. `ci.sh` enforces the rest via
// `cargo clippy --all-targets -- -D warnings`.
#![forbid(unsafe_code)]
#![warn(clippy::dbg_macro)]
#![warn(clippy::todo)]
#![warn(clippy::unimplemented)]
#![warn(clippy::mem_forget)]

pub mod bench;
pub mod check;
pub mod coordinator;
pub mod graph;
pub mod gpusim;
pub mod kernels;
pub mod obs;
pub mod partition;
pub mod plan;
pub mod runtime;
pub mod sample;
pub mod serve;
pub mod stream;
pub mod util;
