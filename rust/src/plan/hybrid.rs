//! The hybrid threshold sweep — AdaptGear's per-block density routing.
//!
//! The paper's headline speedup comes from choosing kernels *per
//! subgraph by density*; one global intra kernel leaves either sparsity
//! benefit (dense blocks on a CSR schedule) or hardware efficiency
//! (near-empty blocks on the batched GEMM) on the table. This module
//! sweeps every representable density threshold over the intra block
//! diagonal and prices each candidate split as the **sum over classes**
//! (`gpusim::kernel_cost::class_kernel_cost`): the dense class on the
//! cheapest admissible `Role::DenseClass` kernel (`DenseBlock` or the
//! tile-sparse MMA schedule), the sparse class on the cheapest
//! `Role::SparseClass` kernel (`CsrIntra`/`Coo`), plus the inter kernel
//! — every candidate set comes from the `kernels::spec::candidates`
//! registry. Every class is one launch, so a split must buy back its
//! extra `launch_us` in format savings — small graphs therefore stay
//! uniform and the sweep degrades to the legacy single-pair decision.
//!
//! The sweep is closed-form over `(blocks, rows, nnz)` prefix sums of the
//! density-sorted block list, so thousands of candidate thresholds cost
//! microseconds — cheap enough for every planner to run it.
//!
//! Pricing note: the sweep prices the paper's N-launch hybrid execution
//! (one launch per class). The current AOT artifact contract exposes two
//! operand slots, so `kernels::pack::pack_assignment` lowers a split by
//! merging the sparse class into the inter launch — that lowering pays
//! NO extra launch, so charging one here makes the sweep *conservative*:
//! it can keep a borderline graph uniform, but a split it does choose is
//! at least as good as priced under either execution shape.

use crate::gpusim::kernel_cost::{class_kernel_cost, est_occupied_tiles, ClassDims, CostCtx};
use crate::gpusim::{kernel_cost_density, GpuModel};
use crate::graph::Csr;
use crate::kernels::{candidates, KernelKind, Role};
use crate::partition::BlockProfile;

use crate::obs;

use super::{
    CandidateThreshold, ClassAssignment, ClassCandidates, GearAssignment, SubgraphClass,
    SweepProvenance, ALL_DENSE_THRESHOLD, ALL_SPARSE_THRESHOLD,
};

/// Interior candidates / edge-cap rejections recorded verbatim in
/// provenance; beyond this only the counts are kept (a 32k-block sweep
/// must not inflate the plan file).
const PROVENANCE_CANDIDATE_CAP: usize = 4;

/// Outcome of one threshold sweep.
#[derive(Debug, Clone)]
pub struct HybridDecision {
    pub assignment: GearAssignment,
    /// Total simulated aggregate cost of the chosen classes + inter (us).
    pub total_us: f64,
    /// Uniform all-`DenseBlock` baseline (intra + inter, us).
    pub all_dense_us: f64,
    /// Uniform all-`CsrIntra` baseline (intra + inter, us).
    pub all_sparse_us: f64,
}

/// Sweep candidate thresholds over `profile` and return the cheapest
/// class assignment. `edge_cap` is the AOT bucket's edge capacity: a
/// hybrid split folds its sparse class into the inter operand at pack
/// time, so splits whose `sparse nnz + inter nnz` exceed the cap are
/// inadmissible (the uniform extremes always are admissible — staging
/// already fitted both whole subgraphs). `tile_cap` is the bucket's
/// tile-grid capacity (`kernels::tile::tile_capacity`): a dense class
/// whose estimated occupied-tile count exceeds it cannot pack, so
/// `TileSparse` is excluded from that class's pricing (pass `usize::MAX`
/// when no AOT bucket constrains the plan).
pub fn sweep(
    profile: &BlockProfile,
    inter: &Csr,
    widths: &[usize],
    edge_cap: usize,
    tile_cap: usize,
    gpu: &'static GpuModel,
) -> HybridDecision {
    sweep_with_density(profile, inter, widths, edge_cap, tile_cap, gpu, 1.0)
}

/// [`sweep`] at an assumed top-k feature density `rho = k/f`: every class
/// candidate and the inter kernel are priced on both topology AND feature
/// density, so the argmin can flip toward the gather-bound CSR/COO
/// schedules once the operand rows compress (the dense engines cannot
/// skip lanes and keep their dense-feature price).
pub fn sweep_with_density(
    profile: &BlockProfile,
    inter: &Csr,
    widths: &[usize],
    edge_cap: usize,
    tile_cap: usize,
    gpu: &'static GpuModel,
    feat_density: f64,
) -> HybridDecision {
    let community = profile.community;
    let nb = profile.len();
    let mut sweep_span = obs::span("plan.sweep");
    sweep_span.attr_num("blocks", nb as f64);
    sweep_span.attr_num("inter_nnz", inter.nnz() as f64);
    sweep_span.attr_num("feat_density", feat_density);
    let mean_class = |kind: KernelKind, blocks: usize, rows: usize, nnz: usize| -> f64 {
        let dims = ClassDims { kind, blocks, rows, nnz };
        widths
            .iter()
            .map(|&w| {
                class_kernel_cost(
                    &CostCtx::new(dims, w, community, gpu).with_feat_density(feat_density),
                )
                .time_us
            })
            .sum::<f64>()
            / widths.len().max(1) as f64
    };
    // Occupancy admissibility: the same deterministic estimate the
    // checker re-derives (AG028); exact counts only exist at pack time.
    let tile_ok = |blocks: usize, nnz: usize| -> bool {
        est_occupied_tiles(blocks, nnz, community) <= tile_cap as f64
    };

    // Inter winner on the same mean-width basis the planners use.
    let inter_cost = |kind: KernelKind| -> f64 {
        widths
            .iter()
            .map(|&w| kernel_cost_density(kind, inter, w, community, gpu, feat_density).time_us)
            .sum::<f64>()
            / widths.len().max(1) as f64
    };
    let inter_kernel = candidates(Role::Inter)
        .iter()
        .copied()
        .min_by(|&a, &b| inter_cost(a).partial_cmp(&inter_cost(b)).unwrap())
        .unwrap_or(KernelKind::CsrInter);
    let inter_us = inter_cost(inter_kernel);

    // Blocks sorted by density, densest first; prefix sums over the order.
    let mut order: Vec<usize> = (0..nb).collect();
    order.sort_by(|&a, &b| {
        profile
            .density(b)
            .partial_cmp(&profile.density(a))
            .unwrap()
            .then(a.cmp(&b))
    });
    let densities: Vec<f64> = order.iter().map(|&b| profile.density(b)).collect();
    let mut rows_pfx = vec![0usize; nb + 1];
    let mut nnz_pfx = vec![0usize; nb + 1];
    for (i, &b) in order.iter().enumerate() {
        let (rows, nnz) = profile.blocks[b];
        rows_pfx[i + 1] = rows_pfx[i] + rows;
        nnz_pfx[i + 1] = nnz_pfx[i] + nnz;
    }
    let (total_rows, total_nnz) = (rows_pfx[nb], nnz_pfx[nb]);

    let sparse_best = |blocks: usize, rows: usize, nnz: usize| -> (KernelKind, f64) {
        candidates(Role::SparseClass)
            .iter()
            .copied()
            .map(|k| (k, mean_class(k, blocks, rows, nnz)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    };
    // Dense-class argmin over the registry, with the tile-capacity veto
    // (`DenseBlock` is always admissible, so the set is never empty).
    let dense_best = |blocks: usize, rows: usize, nnz: usize| -> (KernelKind, f64) {
        candidates(Role::DenseClass)
            .iter()
            .copied()
            .filter(|&k| k != KernelKind::TileSparse || tile_ok(blocks, nnz))
            .map(|k| (k, mean_class(k, blocks, rows, nnz)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    };

    // Uniform extremes first (always admissible; CsrIntra is the only
    // sparse-class kernel executable in the intra artifact slot, so the
    // all-sparse uniform candidate is pinned to it).
    let all_sparse_us = mean_class(KernelKind::CsrIntra, nb, total_rows, total_nnz);
    let all_dense_us = mean_class(KernelKind::DenseBlock, nb, total_rows, total_nnz);

    #[derive(Clone)]
    struct Candidate {
        k: usize,
        threshold: f64,
        dense: Option<(KernelKind, f64)>,
        sparse: Option<(KernelKind, f64)>,
        total: f64,
    }
    let mut best = Candidate {
        k: 0,
        threshold: ALL_SPARSE_THRESHOLD,
        dense: None,
        sparse: Some((KernelKind::CsrIntra, all_sparse_us)),
        total: all_sparse_us,
    };
    let all_dense = Candidate {
        k: nb,
        threshold: ALL_DENSE_THRESHOLD,
        dense: Some((KernelKind::DenseBlock, all_dense_us)),
        sparse: None,
        total: all_dense_us,
    };
    if all_dense.total < best.total {
        best = all_dense;
    }

    // Interior splits: only at strict density boundaries (a threshold
    // must reproduce the exact block set when the trainer re-splits).
    // Provenance bookkeeping rides the same walk: every priced split,
    // every edge-cap veto, every tie skip.
    let mut priced: Vec<(f64, f64)> = Vec::new(); // (threshold, total incl. inter)
    let mut vetoed: Vec<f64> = Vec::new();
    let mut skipped_ties = 0usize;
    for k in 1..nb {
        if densities[k - 1] <= densities[k] {
            skipped_ties += 1;
            continue; // tie: not representable by a >= threshold
        }
        let threshold = (densities[k - 1] + densities[k]) / 2.0;
        let sparse_nnz = total_nnz - nnz_pfx[k];
        if sparse_nnz + inter.nnz() > edge_cap {
            vetoed.push(threshold);
            continue; // merged inter operand would overflow the bucket
        }
        let (dk, dense_us) = dense_best(k, rows_pfx[k], nnz_pfx[k]);
        let (sk, sparse_us) =
            sparse_best(nb - k, total_rows - rows_pfx[k], sparse_nnz);
        let total = dense_us + sparse_us;
        priced.push((threshold, total + inter_us));
        if total < best.total {
            best = Candidate {
                k,
                threshold,
                dense: Some((dk, dense_us)),
                sparse: Some((sk, sparse_us)),
                total,
            };
        }
    }

    // Materialize the winning candidate as a class assignment.
    let mut classes = Vec::new();
    if let Some((kernel, time_us)) = best.dense {
        classes.push(ClassAssignment {
            class: SubgraphClass::DenseIntra,
            kernel,
            blocks: best.k,
            rows: rows_pfx[best.k],
            nnz: nnz_pfx[best.k],
            time_us,
        });
    }
    if let Some((kernel, time_us)) = best.sparse {
        classes.push(ClassAssignment {
            class: SubgraphClass::SparseIntra,
            // a lone sparse class must run in the intra artifact slot
            kernel: if best.k == 0 { KernelKind::CsrIntra } else { kernel },
            blocks: nb - best.k,
            rows: total_rows - rows_pfx[best.k],
            nnz: total_nnz - nnz_pfx[best.k],
            time_us,
        });
    }
    classes.push(ClassAssignment {
        class: SubgraphClass::Inter,
        kernel: inter_kernel,
        blocks: 0,
        rows: inter.n_rows,
        nnz: inter.nnz(),
        time_us: inter_us,
    });

    // Per-class candidate costs at the winning split: every registry
    // kernel the class's role admits, priced on its exact dimensions.
    // The tile-capacity veto applies here too, so the recorded map is
    // exactly the set the checker may audit the argmin over (AG027).
    let class_costs = classes
        .iter()
        .map(|c| {
            let costs = match c.class {
                SubgraphClass::Inter => candidates(Role::Inter)
                    .iter()
                    .map(|&k| (k.as_str().to_string(), inter_cost(k)))
                    .collect(),
                intra => {
                    let role = if intra == SubgraphClass::DenseIntra {
                        Role::DenseClass
                    } else {
                        Role::SparseClass
                    };
                    candidates(role)
                        .iter()
                        .copied()
                        .filter(|&k| k != KernelKind::TileSparse || tile_ok(c.blocks, c.nnz))
                        .map(|k| (k.as_str().to_string(), mean_class(k, c.blocks, c.rows, c.nnz)))
                        .collect()
                }
            };
            ClassCandidates { class: c.class, costs }
        })
        .collect();

    // Candidate threshold record: both uniform extremes always, the
    // winner, then the best runner-up splits and a sample of vetoes.
    let label = |thr: f64, uniform: &str| -> String {
        if thr == best.threshold { "chosen".to_string() } else { uniform.to_string() }
    };
    let mut candidates = vec![
        CandidateThreshold {
            threshold: ALL_SPARSE_THRESHOLD,
            total_us: Some(all_sparse_us + inter_us),
            outcome: label(ALL_SPARSE_THRESHOLD, "uniform_sparse"),
        },
        CandidateThreshold {
            threshold: ALL_DENSE_THRESHOLD,
            total_us: Some(all_dense_us + inter_us),
            outcome: label(ALL_DENSE_THRESHOLD, "uniform_dense"),
        },
    ];
    let evaluated = priced.len();
    if best.k > 0 && best.k < nb {
        candidates.push(CandidateThreshold {
            threshold: best.threshold,
            total_us: Some(best.total + inter_us),
            outcome: "chosen".to_string(),
        });
    }
    priced.retain(|&(thr, _)| thr != best.threshold);
    priced.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for &(threshold, total_us) in priced.iter().take(PROVENANCE_CANDIDATE_CAP) {
        candidates.push(CandidateThreshold {
            threshold,
            total_us: Some(total_us),
            outcome: "considered".to_string(),
        });
    }
    for &threshold in vetoed.iter().take(PROVENANCE_CANDIDATE_CAP) {
        candidates.push(CandidateThreshold {
            threshold,
            total_us: None,
            outcome: "rejected_edge_cap".to_string(),
        });
    }
    let provenance = SweepProvenance {
        threshold: best.threshold,
        class_costs,
        candidates,
        evaluated,
        rejected_edge_cap: vetoed.len(),
        skipped_ties,
    };

    sweep_span.attr_num("threshold", best.threshold);
    sweep_span.attr_bool("hybrid", best.k > 0 && best.k < nb);
    HybridDecision {
        assignment: GearAssignment {
            threshold: best.threshold,
            classes,
            provenance: Some(provenance),
        },
        total_us: best.total + inter_us,
        all_dense_us: all_dense_us + inter_us,
        all_sparse_us: all_sparse_us + inter_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::A100;
    use crate::graph::generate::planted_partition_mixed;
    use crate::partition::{Decomposition, Propagation, Reorder};
    use crate::util::rng::Rng;

    /// Fabricate a profile without building a huge graph: `dense` blocks
    /// of `community` rows at `dense_nnz` each plus `sparse` blocks at
    /// `sparse_nnz`.
    fn fake_profile(
        community: usize,
        dense: usize,
        dense_nnz: usize,
        sparse: usize,
        sparse_nnz: usize,
    ) -> BlockProfile {
        let mut blocks = vec![(community, dense_nnz); dense];
        blocks.extend(vec![(community, sparse_nnz); sparse]);
        BlockProfile { community, blocks }
    }

    fn small_inter() -> Csr {
        // a handful of off-diagonal entries; the inter term is a shared
        // constant across all sweep candidates
        Csr::from_triplets(64, 64, vec![(0, 20, 1.0), (40, 3, 0.5), (17, 60, 0.25)])
    }

    #[test]
    fn mixed_profile_goes_hybrid_and_beats_both_uniforms() {
        // The acceptance shape on the analytic surface: a large mixed
        // graph (1/3 near-dense blocks at ~0.95, 2/3 near-empty) must
        // split, route the tile-sparse MMA schedule on the dense class
        // (it strictly beats the padded batched GEMM once tiles compact)
        // plus a sparse kernel, and price strictly below BOTH
        // single-kernel plans.
        let profile = fake_profile(16, 10922, 244, 21846, 20);
        let d = sweep(&profile, &small_inter(), &[32, 32], usize::MAX, usize::MAX, &A100);
        assert!(d.assignment.is_hybrid(), "mixed profile must split");
        assert_eq!(
            d.assignment.kernel_for(SubgraphClass::DenseIntra),
            Some(KernelKind::TileSparse)
        );
        let sparse = d.assignment.kernel_for(SubgraphClass::SparseIntra).unwrap();
        assert!(candidates(Role::SparseClass).contains(&sparse));
        assert!(
            d.total_us < d.all_dense_us && d.total_us < d.all_sparse_us,
            "hybrid {:.1}us must beat all-dense {:.1}us and all-csr {:.1}us",
            d.total_us,
            d.all_dense_us,
            d.all_sparse_us
        );
        assert_eq!(d.assignment.intra_kernels().len(), 2);
        // threshold reproduces the exact split
        let labels = profile.classify(d.assignment.threshold);
        let dense_count = labels
            .iter()
            .filter(|&&l| l == crate::partition::DensityClass::Dense)
            .count();
        assert_eq!(dense_count, 10922);
    }

    #[test]
    fn small_graphs_stay_uniform() {
        // launch overhead dwarfs format savings at tiny scale: one class
        let profile = fake_profile(16, 4, 200, 12, 18);
        let d = sweep(&profile, &small_inter(), &[32, 32], usize::MAX, usize::MAX, &A100);
        assert!(!d.assignment.is_hybrid(), "tiny graph must not split");
        assert_eq!(d.assignment.intra_classes().count(), 1);
        let pair = d.assignment.executed_pair().unwrap();
        assert!(crate::kernels::INTRA_CANDIDATES.contains(&pair.intra.unwrap()));
    }

    #[test]
    fn sweep_records_provenance() {
        let profile = fake_profile(16, 10922, 244, 21846, 20);
        let d = sweep(&profile, &small_inter(), &[32, 32], usize::MAX, usize::MAX, &A100);
        let p = d.assignment.provenance.as_ref().expect("sweep attaches provenance");
        assert_eq!(p.threshold, d.assignment.threshold);
        let chosen: Vec<_> = p.candidates.iter().filter(|c| c.outcome == "chosen").collect();
        assert_eq!(chosen.len(), 1, "exactly one winning candidate");
        assert!((chosen[0].total_us.unwrap() - d.total_us).abs() < 1e-9);
        assert!(p.candidates.iter().any(|c| c.outcome == "uniform_dense"));
        assert!(p.candidates.iter().any(|c| c.outcome == "uniform_sparse"));
        // every executed class has candidate costs including its kernel,
        // plus at least one priced alternative
        for c in &d.assignment.classes {
            let cc = p.class_costs.iter().find(|cc| cc.class == c.class).unwrap();
            assert!(cc.costs.contains_key(c.kernel.as_str()), "{:?}", c.class);
            assert!(cc.costs.len() >= 2, "{:?} needs alternatives", c.class);
        }

        // vetoed splits are counted and sampled with the reason
        let capped = sweep(&profile, &small_inter(), &[32, 32], 1000, usize::MAX, &A100);
        let cp = capped.assignment.provenance.as_ref().unwrap();
        assert!(cp.rejected_edge_cap > 0);
        assert!(cp
            .candidates
            .iter()
            .any(|c| c.outcome == "rejected_edge_cap" && c.total_us.is_none()));
    }

    #[test]
    fn edge_cap_vetoes_unmergeable_splits() {
        let profile = fake_profile(16, 10922, 244, 21846, 20);
        // sparse class nnz ~ 436920; a cap below that + inter nnz forces
        // the sweep back to a uniform plan
        let capped = sweep(&profile, &small_inter(), &[32, 32], 1000, usize::MAX, &A100);
        assert!(!capped.assignment.is_hybrid(), "cap must veto the split");
    }

    #[test]
    fn uniform_extremes_match_class_totals() {
        let profile = fake_profile(16, 8, 100, 8, 10);
        let d = sweep(&profile, &small_inter(), &[32], usize::MAX, usize::MAX, &A100);
        // whichever side won, its class totals cover the whole diagonal
        let blocks: usize = d.assignment.intra_classes().map(|c| c.blocks).sum();
        assert_eq!(blocks, 16);
        let nnz: usize = d.assignment.intra_classes().map(|c| c.nnz).sum();
        assert_eq!(nnz, 8 * 100 + 8 * 10);
        assert!((d.assignment.total_cost_us() - d.total_us).abs() < 1e-9);
    }

    #[test]
    fn real_mixed_graph_splits_at_scale() {
        // End-to-end over a real mixed planted graph with the structure
        // ALREADY aligned to blocks (no reorder needed). Community 64 at
        // 131072 vertices puts the per-class format savings (~20 MB of
        // topology each way) well past the extra launch, so the split
        // must happen and must beat both uniforms.
        let mut rng = Rng::new(3);
        let n = 131072;
        let g = planted_partition_mixed(n, 64, 0.95, 0.005, 3, 0.3 / n as f64, &mut rng);
        let d = Decomposition::build(&g, Reorder::Identity, Propagation::GcnNormalized, 64, 0);
        let profile = d.intra_block_profile();
        let decision = sweep(&profile, &d.inter, &[32, 32], usize::MAX, usize::MAX, &A100);
        assert_eq!(
            decision.assignment.kernel_for(SubgraphClass::DenseIntra),
            Some(KernelKind::TileSparse),
            "near-full 64-wide blocks compact into cheap tiles"
        );
        assert!(
            decision.assignment.is_hybrid(),
            "aligned mixed graph must split (total {:.1} vs dense {:.1} / sparse {:.1})",
            decision.total_us,
            decision.all_dense_us,
            decision.all_sparse_us
        );
        assert!(decision.total_us < decision.all_dense_us);
        assert!(decision.total_us < decision.all_sparse_us);
        // the trainer's re-split at the recorded threshold reproduces the
        // recorded classes exactly
        let split = d.split_intra(decision.assignment.threshold);
        assert_eq!(split.classes.len(), 2);
        for class in &split.classes {
            let label = class.label;
            let rec = decision
                .assignment
                .intra_classes()
                .find(|c| GearAssignment::density_label(c.class) == Some(label))
                .unwrap();
            assert_eq!(class.blocks.len(), rec.blocks);
            assert_eq!(class.matrix.nnz(), rec.nnz);
        }
    }

    #[test]
    fn sparse_features_never_raise_the_sweep_total() {
        // the density-aware sweep at rho < 1 must price at or below the
        // dense-feature sweep (per-candidate costs are monotone in rho,
        // and the argmin can only improve), and rho = 1.0 must reproduce
        // the density-blind sweep bit-exactly
        let profile = fake_profile(16, 10922, 244, 21846, 20);
        let dense =
            sweep(&profile, &small_inter(), &[256, 256], usize::MAX, usize::MAX, &A100);
        let one = sweep_with_density(
            &profile,
            &small_inter(),
            &[256, 256],
            usize::MAX,
            usize::MAX,
            &A100,
            1.0,
        );
        assert_eq!(dense.total_us, one.total_us, "rho=1.0 must be bit-identical");
        assert_eq!(dense.assignment.threshold, one.assignment.threshold);
        let sparse = sweep_with_density(
            &profile,
            &small_inter(),
            &[256, 256],
            usize::MAX,
            usize::MAX,
            &A100,
            0.125,
        );
        assert!(
            sparse.total_us <= dense.total_us,
            "sparse features must not cost more: {} vs {}",
            sparse.total_us,
            dense.total_us
        );
        assert!(
            sparse.all_sparse_us < dense.all_sparse_us,
            "the CSR uniform baseline must strictly cheapen at rho=1/8"
        );
    }

    #[test]
    fn tile_capacity_veto_falls_back_to_dense_block() {
        // Same mixed profile that routes TileSparse with an open grid: a
        // bucket reserving zero tile slots must veto it, and the veto has
        // to reach the provenance so the checker audits the argmin over
        // exactly the admissible set.
        let profile = fake_profile(16, 10922, 244, 21846, 20);
        let open = sweep(&profile, &small_inter(), &[32, 32], usize::MAX, usize::MAX, &A100);
        assert_eq!(
            open.assignment.kernel_for(SubgraphClass::DenseIntra),
            Some(KernelKind::TileSparse)
        );
        let capped = sweep(&profile, &small_inter(), &[32, 32], usize::MAX, 0, &A100);
        assert!(capped.assignment.is_hybrid(), "veto reroutes, it must not unsplit");
        assert_eq!(
            capped.assignment.kernel_for(SubgraphClass::DenseIntra),
            Some(KernelKind::DenseBlock)
        );
        let p = capped.assignment.provenance.as_ref().unwrap();
        let dc = p
            .class_costs
            .iter()
            .find(|cc| cc.class == SubgraphClass::DenseIntra)
            .unwrap();
        assert!(
            !dc.costs.contains_key(KernelKind::TileSparse.as_str()),
            "vetoed kernel must not be recorded as a candidate"
        );
        let oc = open
            .assignment
            .provenance
            .as_ref()
            .unwrap()
            .class_costs
            .iter()
            .find(|cc| cc.class == SubgraphClass::DenseIntra)
            .unwrap();
        assert!(oc.costs.contains_key(KernelKind::TileSparse.as_str()));
    }
}
