//! First-class kernel planning.
//!
//! AdaptGear's core contribution is choosing density-specialized kernels
//! per subgraph; this module makes that choice a serializable **plan**
//! instead of a transient side effect of training. A [`GearPlan`] records
//! everything the decision depends on (graph [`Fingerprint`], scale,
//! community, reorder), the decision itself (a [`GearAssignment`] — the
//! density threshold plus one `(subgraph class, kernel)` entry per
//! executed part, with the two-slot [`KernelPair`] lowering in `chosen`),
//! the projected [`IterationCost`], and provenance — and roundtrips
//! through `util::json`. The per-class split itself is decided by the
//! [`hybrid`] threshold sweep, which every planner runs.
//!
//! Plans are produced by [`Planner`] implementations:
//!
//! * [`SimCostPlanner`] — deterministic gpusim costs, no monitoring.
//! * [`MonitorPlanner`] — the paper's Sec. 3.3 feedback loop (sim or
//!   PJRT wall clock) via `coordinator::selector::select`.
//! * [`CachedPlanner`] — a [`PlanStore`] on disk keyed by fingerprint,
//!   delegating to an inner planner on miss; a cache hit costs zero
//!   monitor iterations.
//! * [`BatchPlanner`] — amortized mini-batch planning: an in-memory
//!   cache keyed by density *profile* ([`BatchProfile`]) instead of
//!   exact topology, for sampled subgraphs that never recur exactly
//!   (see [`batch`] and DESIGN.md Sec. 10).
//!
//! Consumers: `coordinator::trainer::train` executes a plan,
//! `coordinator::pipeline::Run` builds one end to end,
//! `serve::ModelRegistry::deploy` plans through `CachedPlanner`, and the
//! `adaptgear plan` subcommand computes/prints/persists them.

pub mod batch;
pub mod fingerprint;
pub mod hybrid;
pub mod planners;
pub mod store;

pub use batch::{
    adapt_decision, coarse_log2, plan_from_decision, BatchPlanner, BatchProfile, PlanDecision,
};
pub use fingerprint::Fingerprint;
pub use hybrid::HybridDecision;
pub use planners::{best_adaptive_pair, CachedPlanner, MonitorPlanner, SimCostPlanner};
pub use store::PlanStore;

use std::collections::BTreeMap;
use std::str::FromStr;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::ModelKind;
use crate::gpusim::IterationCost;
use crate::kernels::{candidates, KernelKind, KernelPair, Role as KernelRole};
use crate::partition::{Decomposition, DensityClass, Reorder};
use crate::runtime::BucketInfo;
use crate::util::json::Json;

/// Timing source for monitoring-based planners.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Clock {
    /// Deterministic gpusim surface (figure benches; no GPU here).
    Sim,
    /// Real PJRT wall time of the kernel-only artifacts.
    Wall,
}

impl Clock {
    pub fn as_str(&self) -> &'static str {
        match self {
            Clock::Sim => "sim",
            Clock::Wall => "wall",
        }
    }
}

impl FromStr for Clock {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Clock, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(Clock::Sim),
            "wall" => Ok(Clock::Wall),
            other => Err(anyhow!("unknown clock {other:?} (expected sim|wall)")),
        }
    }
}

/// Everything a planner needs to decide kernels for one decomposed graph.
pub struct PlanRequest<'a> {
    pub d: &'a Decomposition,
    pub model: ModelKind,
    /// AOT bucket the padded graph fits (widths come from here).
    pub bucket: &'a BucketInfo,
    /// Provenance labels — not part of the cache key.
    pub dataset: String,
    pub scale: f64,
    pub reorder: Reorder,
    pub seed: u64,
    /// Monotonic streaming graph version (0 for frozen graphs). Part of
    /// the fingerprint: a re-planned mutation never collides with the
    /// pre-mutation plan in the store.
    pub graph_version: u64,
    /// Assumed top-k feature density `rho = k/f` the plan prices kernels
    /// at (1.0 = dense features). Part of the fingerprint: the cost
    /// argmin depends on it, so a density-blind cached plan must re-key.
    pub feat_density: f64,
}

impl<'a> PlanRequest<'a> {
    pub fn new(d: &'a Decomposition, model: ModelKind, bucket: &'a BucketInfo) -> PlanRequest<'a> {
        PlanRequest {
            d,
            model,
            bucket,
            dataset: String::new(),
            scale: 1.0,
            reorder: Reorder::Metis,
            seed: 0,
            graph_version: 0,
            feat_density: 1.0,
        }
    }

    /// [`PlanRequest::new`] plus the provenance labels in one call — the
    /// pipeline, registry, CLI, and examples all thread the same four.
    pub fn labeled(
        d: &'a Decomposition,
        model: ModelKind,
        bucket: &'a BucketInfo,
        dataset: &str,
        scale: f64,
        reorder: Reorder,
        seed: u64,
    ) -> PlanRequest<'a> {
        PlanRequest {
            d,
            model,
            bucket,
            dataset: dataset.to_string(),
            scale,
            reorder,
            seed,
            graph_version: 0,
            feat_density: 1.0,
        }
    }

    /// Aggregate widths the selector monitors (matches the AOT kernel-only
    /// artifacts, which are lowered at the bucket's feature and hidden
    /// widths).
    pub fn widths(&self) -> [usize; 2] {
        [self.bucket.features, self.bucket.hidden]
    }

    pub fn fingerprint(&self) -> Fingerprint {
        Fingerprint::of_full(self.d, self.model, self.graph_version, self.feat_density)
    }
}

/// Which part of the decomposed propagation a class assignment covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubgraphClass {
    /// Diagonal blocks at or above the density threshold.
    DenseIntra,
    /// Diagonal blocks below the density threshold.
    SparseIntra,
    /// The off-diagonal remainder.
    Inter,
}

impl SubgraphClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            SubgraphClass::DenseIntra => "dense_intra",
            SubgraphClass::SparseIntra => "sparse_intra",
            SubgraphClass::Inter => "inter",
        }
    }

    pub fn is_intra(&self) -> bool {
        !matches!(self, SubgraphClass::Inter)
    }
}

impl FromStr for SubgraphClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SubgraphClass, Self::Err> {
        match s {
            "dense_intra" => Ok(SubgraphClass::DenseIntra),
            "sparse_intra" => Ok(SubgraphClass::SparseIntra),
            "inter" => Ok(SubgraphClass::Inter),
            other => Err(anyhow!(
                "unknown subgraph class {other:?} (expected dense_intra|sparse_intra|inter)"
            )),
        }
    }
}

/// One executed class of a plan: which slice of the graph it covers and
/// which kernel runs it, plus the planner's cost basis for the slice.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassAssignment {
    pub class: SubgraphClass,
    pub kernel: KernelKind,
    /// Diagonal blocks covered (0 for the inter class).
    pub blocks: usize,
    /// Real rows covered.
    pub rows: usize,
    pub nnz: usize,
    /// Planner's mean simulated/measured launch time for this class (us).
    pub time_us: f64,
}

/// The decision a [`GearPlan`] executes: a density threshold over the
/// intra block diagonal plus one `(subgraph class, kernel)` assignment
/// per executed part. Uniform plans carry one intra class; hybrid plans
/// carry two (dense-first). This is the list that replaced the single
/// intra/inter [`KernelPair`] end to end; [`GearPlan::chosen`] is its
/// two-slot artifact lowering.
#[derive(Debug, Clone, PartialEq)]
pub struct GearAssignment {
    /// Block density (`nnz / rows^2`) at or above which a diagonal block
    /// joins the dense class. [`ALL_DENSE_THRESHOLD`] /
    /// [`ALL_SPARSE_THRESHOLD`] encode the uniform extremes.
    pub threshold: f64,
    /// Intra classes first (dense before sparse), inter last.
    pub classes: Vec<ClassAssignment>,
    /// How the sweep reached this decision (`None` on plans adapted from
    /// a cached decision, and on plan files written before provenance
    /// existed — old cache entries must keep loading).
    pub provenance: Option<SweepProvenance>,
}

/// Decision provenance recorded by the hybrid threshold sweep: the
/// candidate kernel costs per class at the winning split, the candidate
/// thresholds the sweep weighed (capped sample), and why rejected
/// splits lost. Persisted inside the plan JSON and printed by
/// `adaptgear plan --explain`.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepProvenance {
    /// Threshold the decision executes (mirrors the assignment's).
    pub threshold: f64,
    /// Per-class candidate costs at the winning split (us), every
    /// eligible kernel priced on that class's dimensions.
    pub class_costs: Vec<ClassCandidates>,
    /// Capped candidate list: both uniform extremes, the winner, the
    /// best admissible alternatives, and a sample of vetoed splits.
    pub candidates: Vec<CandidateThreshold>,
    /// Interior splits the sweep priced (uniform extremes excluded).
    pub evaluated: usize,
    /// Splits vetoed because `sparse nnz + inter nnz` overflowed the
    /// bucket's edge capacity.
    pub rejected_edge_cap: usize,
    /// Block boundaries skipped as density ties (no representable
    /// threshold separates equal densities).
    pub skipped_ties: usize,
}

/// Candidate kernel costs for one class of the winning split.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassCandidates {
    pub class: SubgraphClass,
    /// Kernel name -> mean cost over the monitored widths (us).
    pub costs: BTreeMap<String, f64>,
}

/// One threshold the sweep considered and what happened to it.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateThreshold {
    pub threshold: f64,
    /// Total classes + inter cost (us); `None` when the split was
    /// vetoed before pricing.
    pub total_us: Option<f64>,
    /// `chosen` | `uniform_dense` | `uniform_sparse` | `considered` |
    /// `rejected_edge_cap`.
    pub outcome: String,
}

impl SweepProvenance {
    pub fn to_json(&self) -> Json {
        let class_costs = Json::Arr(
            self.class_costs
                .iter()
                .map(|cc| {
                    Json::obj(vec![
                        ("class", Json::str(cc.class.as_str())),
                        (
                            "costs",
                            Json::Obj(
                                cc.costs
                                    .iter()
                                    .map(|(k, v)| (k.clone(), Json::num(*v)))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let candidates = Json::Arr(
            self.candidates
                .iter()
                .map(|c| {
                    let mut fields = vec![
                        ("outcome", Json::str(c.outcome.clone())),
                        ("threshold", Json::num(c.threshold)),
                    ];
                    if let Some(t) = c.total_us {
                        fields.push(("total_us", Json::num(t)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        Json::obj(vec![
            ("threshold", Json::num(self.threshold)),
            ("class_costs", class_costs),
            ("candidates", candidates),
            ("evaluated", Json::num(self.evaluated as f64)),
            ("rejected_edge_cap", Json::num(self.rejected_edge_cap as f64)),
            ("skipped_ties", Json::num(self.skipped_ties as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SweepProvenance> {
        let threshold = v
            .get("threshold")
            .as_f64()
            .ok_or_else(|| anyhow!("provenance missing threshold"))?;
        let mut class_costs = Vec::new();
        for cc in v.get("class_costs").as_arr().unwrap_or(&[]) {
            let class: SubgraphClass = cc
                .get("class")
                .as_str()
                .ok_or_else(|| anyhow!("provenance class_costs entry missing class"))?
                .parse()?;
            let mut costs = BTreeMap::new();
            if let Some(map) = cc.get("costs").as_obj() {
                for (k, t) in map {
                    let t = t
                        .as_f64()
                        .ok_or_else(|| anyhow!("bad provenance cost for {k}"))?;
                    costs.insert(k.clone(), t);
                }
            }
            class_costs.push(ClassCandidates { class, costs });
        }
        let mut candidates = Vec::new();
        for c in v.get("candidates").as_arr().unwrap_or(&[]) {
            candidates.push(CandidateThreshold {
                threshold: c
                    .get("threshold")
                    .as_f64()
                    .ok_or_else(|| anyhow!("provenance candidate missing threshold"))?,
                total_us: c.get("total_us").as_f64(),
                outcome: c
                    .get("outcome")
                    .as_str()
                    .ok_or_else(|| anyhow!("provenance candidate missing outcome"))?
                    .to_string(),
            });
        }
        Ok(SweepProvenance {
            threshold,
            class_costs,
            candidates,
            evaluated: v.get("evaluated").as_usize().unwrap_or(0),
            rejected_edge_cap: v.get("rejected_edge_cap").as_usize().unwrap_or(0),
            skipped_ties: v.get("skipped_ties").as_usize().unwrap_or(0),
        })
    }

    /// Multi-line rendering for `plan --explain`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "sweep: {} interior splits priced, {} vetoed by edge cap, {} tie boundaries skipped\n",
            self.evaluated, self.rejected_edge_cap, self.skipped_ties
        ));
        out.push_str("candidate thresholds:\n");
        for c in &self.candidates {
            match c.total_us {
                Some(t) => out.push_str(&format!(
                    "  thr {:>7.4} -> {:>10.1}us  [{}]\n",
                    c.threshold, t, c.outcome
                )),
                None => out.push_str(&format!(
                    "  thr {:>7.4} -> {:>12}  [{}]\n",
                    c.threshold, "-", c.outcome
                )),
            }
        }
        out.push_str("per-class candidate costs at the winning split:\n");
        for cc in &self.class_costs {
            out.push_str(&format!("  {}:\n", cc.class.as_str()));
            for (kernel, us) in &cc.costs {
                out.push_str(&format!("    {kernel:<12} {us:>10.1}us\n"));
            }
        }
        out
    }
}

/// Threshold that puts every block in the dense class.
pub const ALL_DENSE_THRESHOLD: f64 = 0.0;
/// Threshold that puts every block in the sparse class (block densities
/// never exceed 1.0).
pub const ALL_SPARSE_THRESHOLD: f64 = 2.0;

impl GearAssignment {
    /// A single-intra-class assignment — the legacy `(intra, inter)` pair
    /// expressed in class form. `intra_stats`/`inter_stats` are
    /// `(blocks, rows, nnz, time_us)` for the respective parts.
    pub fn uniform(
        pair: KernelPair,
        intra_stats: (usize, usize, usize, f64),
        inter_stats: (usize, usize, f64),
    ) -> GearAssignment {
        let intra_kernel = pair
            .intra
            .expect("uniform assignments require an intra kernel (full-graph plans have no assignment)");
        let (threshold, class) = if candidates(KernelRole::DenseClass).contains(&intra_kernel) {
            (ALL_DENSE_THRESHOLD, SubgraphClass::DenseIntra)
        } else {
            (ALL_SPARSE_THRESHOLD, SubgraphClass::SparseIntra)
        };
        let (blocks, rows, nnz, time_us) = intra_stats;
        let (inter_rows, inter_nnz, inter_time_us) = inter_stats;
        GearAssignment {
            threshold,
            classes: vec![
                ClassAssignment { class, kernel: intra_kernel, blocks, rows, nnz, time_us },
                ClassAssignment {
                    class: SubgraphClass::Inter,
                    kernel: pair.inter,
                    blocks: 0,
                    rows: inter_rows,
                    nnz: inter_nnz,
                    time_us: inter_time_us,
                },
            ],
            provenance: None,
        }
    }

    pub fn intra_classes(&self) -> impl Iterator<Item = &ClassAssignment> {
        self.classes.iter().filter(|c| c.class.is_intra())
    }

    pub fn inter_class(&self) -> Result<&ClassAssignment> {
        self.classes
            .iter()
            .find(|c| c.class == SubgraphClass::Inter)
            .ok_or_else(|| anyhow!("assignment has no inter class"))
    }

    pub fn kernel_for(&self, class: SubgraphClass) -> Option<KernelKind> {
        self.classes.iter().find(|c| c.class == class).map(|c| c.kernel)
    }

    /// Two or more intra classes execute (per-block density routing).
    pub fn is_hybrid(&self) -> bool {
        self.intra_classes().count() >= 2
    }

    /// Distinct intra kernels, in class order.
    pub fn intra_kernels(&self) -> Vec<KernelKind> {
        let mut out = Vec::new();
        for c in self.intra_classes() {
            if !out.contains(&c.kernel) {
                out.push(c.kernel);
            }
        }
        out
    }

    /// Sum of the intra classes' planner cost basis (us).
    pub fn intra_cost_us(&self) -> f64 {
        self.intra_classes().map(|c| c.time_us).sum()
    }

    /// Total classes cost including inter (us).
    pub fn total_cost_us(&self) -> f64 {
        self.classes.iter().map(|c| c.time_us).sum()
    }

    /// Lower the class list onto the two-slot AOT artifact contract: the
    /// first intra class (the dense one when hybrid) executes in the
    /// intra slot; a hybrid plan's sparse class is merged into the inter
    /// operand at pack time (`kernels::pack::pack_assignment`), which the
    /// inter kernel's global sparse format absorbs exactly.
    pub fn executed_pair(&self) -> Result<KernelPair> {
        let intra = self
            .intra_classes()
            .next()
            .ok_or_else(|| anyhow!("assignment has no intra class"))?
            .kernel;
        if !candidates(KernelRole::IntraSlot).contains(&intra) {
            bail!("class kernel {intra} cannot execute in the intra artifact slot");
        }
        Ok(KernelPair::new(intra, self.inter_class()?.kernel))
    }

    /// Cheap consistency check against the decomposition a plan claims to
    /// cover (the fingerprint guarantees topology identity; this catches
    /// tampered or mismatched class lists).
    pub fn covers(&self, d: &Decomposition) -> Result<()> {
        let intra_nnz: usize = self.intra_classes().map(|c| c.nnz).sum();
        if intra_nnz != d.intra.nnz() {
            bail!(
                "assignment intra nnz {intra_nnz} != decomposition intra nnz {}",
                d.intra.nnz()
            );
        }
        let inter = self.inter_class()?;
        if inter.nnz != d.inter.nnz() {
            bail!("assignment inter nnz {} != decomposition inter nnz {}", inter.nnz, d.inter.nnz());
        }
        let blocks: usize = self.intra_classes().map(|c| c.blocks).sum();
        let expect = d.graph.n.div_ceil(d.community.max(1));
        if blocks != expect {
            bail!("assignment covers {blocks} blocks, decomposition has {expect}");
        }
        self.executed_pair().map(|_| ())
    }

    /// The [`DensityClass`] label a class assignment corresponds to.
    pub fn density_label(class: SubgraphClass) -> Option<DensityClass> {
        match class {
            SubgraphClass::DenseIntra => Some(DensityClass::Dense),
            SubgraphClass::SparseIntra => Some(DensityClass::Sparse),
            SubgraphClass::Inter => None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("threshold", Json::num(self.threshold)),
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("class", Json::str(c.class.as_str())),
                                ("kernel", Json::str(c.kernel.as_str())),
                                ("blocks", Json::num(c.blocks as f64)),
                                ("rows", Json::num(c.rows as f64)),
                                ("nnz", Json::num(c.nnz as f64)),
                                ("time_us", Json::num(c.time_us)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(p) = &self.provenance {
            fields.push(("provenance", p.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<GearAssignment> {
        let threshold = v
            .get("threshold")
            .as_f64()
            .ok_or_else(|| anyhow!("assignment missing threshold"))?;
        let raw = v
            .get("classes")
            .as_arr()
            .ok_or_else(|| anyhow!("assignment missing classes"))?;
        let mut classes = Vec::with_capacity(raw.len());
        for c in raw {
            let num = |k: &str| {
                c.get(k)
                    .as_usize()
                    .ok_or_else(|| anyhow!("class missing numeric field {k:?}"))
            };
            classes.push(ClassAssignment {
                class: c
                    .get("class")
                    .as_str()
                    .ok_or_else(|| anyhow!("class missing 'class'"))?
                    .parse()?,
                kernel: c
                    .get("kernel")
                    .as_str()
                    .ok_or_else(|| anyhow!("class missing 'kernel'"))?
                    .parse()?,
                blocks: num("blocks")?,
                rows: num("rows")?,
                nnz: num("nnz")?,
                time_us: c
                    .get("time_us")
                    .as_f64()
                    .ok_or_else(|| anyhow!("class missing time_us"))?,
            });
        }
        // Absent provenance is valid (adapted plans, pre-provenance
        // files); present-but-malformed provenance is not.
        let provenance = match v.get("provenance") {
            Json::Null => None,
            p => Some(SweepProvenance::from_json(p).context("assignment field 'provenance'")?),
        };
        let a = GearAssignment { threshold, classes, provenance };
        if a.intra_classes().next().is_none() {
            bail!("assignment has no intra class");
        }
        a.inter_class()?;
        Ok(a)
    }
}

/// Where a plan came from — recorded for `--explain` and cache forensics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    /// Planner that computed the decision ("simcost", "monitor", ...).
    pub planner: String,
    /// Timing source ("analytic", "sim", "wall").
    pub clock: String,
    /// GPU model driving simulated costs.
    pub gpu: String,
    /// True when this instance was served from a [`PlanStore`] hit.
    pub cached: bool,
}

/// A serializable subgraph-level kernel decision.
#[derive(Debug, Clone)]
pub struct GearPlan {
    /// Identity of the selection problem (topology + community + model).
    pub fingerprint: Fingerprint,
    pub dataset: String,
    pub model: ModelKind,
    pub scale: f64,
    pub community: usize,
    pub reorder: Reorder,
    pub seed: u64,
    /// AOT bucket the plan targets.
    pub bucket: String,
    /// Overall winner — the variant the AOT train/forward artifacts honor.
    /// Always the two-slot lowering of `assignment`
    /// ([`GearAssignment::executed_pair`]).
    pub chosen: KernelPair,
    /// The per-class decision: density threshold + one (class, kernel)
    /// entry per executed part. Hybrid plans carry two intra classes.
    pub assignment: GearAssignment,
    /// Per-aggregate-width winners, under the same per-candidate cost
    /// basis as `chosen` (informational; artifacts are lowered per
    /// overall pair, so `chosen` is what executes).
    pub per_width: BTreeMap<usize, KernelPair>,
    /// Mean measured/simulated time per intra candidate (us).
    pub intra_times: BTreeMap<String, f64>,
    /// Mean measured/simulated time per inter candidate (us).
    pub inter_times: BTreeMap<String, f64>,
    /// Projected cost of one forward pass under this plan.
    pub projected: IterationCost,
    /// Monitoring iterations spent producing THIS instance (0 when the
    /// plan was served from cache — the Sec. 6.3 overhead that caching
    /// eliminates).
    pub monitor_iters: usize,
    pub monitor_overhead_us: f64,
    pub provenance: Provenance,
    /// Streaming graph version this plan was derived at (0 for frozen
    /// graphs). Participates in the fingerprint, so `validate` can
    /// recompute the digest for versioned plans.
    pub graph_version: u64,
    /// Top-k feature density `rho = k/f` the plan's costs assumed (1.0 =
    /// dense features). Participates in the fingerprint; `validate` and
    /// the checker recompute costs at this density.
    pub feat_density: f64,
}

impl GearPlan {
    /// Check this plan solves the selection problem `d` + `model` poses.
    pub fn validate(&self, d: &Decomposition, model: ModelKind) -> Result<()> {
        if self.community != d.community {
            bail!(
                "plan community {} != decomposition community {}",
                self.community,
                d.community
            );
        }
        let fp = Fingerprint::of_full(d, model, self.graph_version, self.feat_density);
        if self.fingerprint != fp {
            bail!(
                "plan fingerprint {} does not match graph fingerprint {fp} — replan",
                self.fingerprint
            );
        }
        self.assignment
            .covers(d)
            .context("plan assignment does not cover this decomposition")?;
        let pair = self.assignment.executed_pair()?;
        if pair != self.chosen {
            bail!(
                "plan chosen {} disagrees with its assignment lowering {pair}",
                self.chosen
            );
        }
        Ok(())
    }

    /// Whether this plan's decision still applies to `bucket` — the
    /// bucket the padded graph currently fits. False after an artifacts
    /// rebuild changes bucket geometry (name, or the monitored widths):
    /// the graph fingerprint alone cannot see that, so the plan cache
    /// must re-check before serving a stored decision.
    pub fn matches_bucket(&self, bucket: &BucketInfo) -> bool {
        self.bucket == bucket.name
            && [bucket.features, bucket.hidden]
                .iter()
                .all(|w| self.per_width.contains_key(w))
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        let decision = if self.assignment.is_hybrid() {
            format!(
                "hybrid[{}]+{} @ thr {:.3}",
                self.assignment
                    .intra_kernels()
                    .iter()
                    .map(|k| k.as_str())
                    .collect::<Vec<_>>()
                    .join("|"),
                self.chosen.inter,
                self.assignment.threshold,
            )
        } else {
            self.chosen.to_string()
        };
        format!(
            "plan {}: {} on {} (scale {:.4}) -> {decision} in bucket {} | projected {:.1}us/fwd | {} monitor iters ({}{})",
            self.fingerprint,
            self.model.as_str(),
            if self.dataset.is_empty() { "<graph>" } else { self.dataset.as_str() },
            self.scale,
            self.bucket,
            self.projected.total_us(),
            self.monitor_iters,
            self.provenance.planner,
            if self.provenance.cached { ", cache hit" } else { "" },
        )
    }

    pub fn to_json(&self) -> Json {
        let times = |m: &BTreeMap<String, f64>| {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect())
        };
        let per_width = Json::Obj(
            self.per_width
                .iter()
                .map(|(w, p)| (w.to_string(), pair_to_json(*p)))
                .collect(),
        );
        Json::obj(vec![
            ("version", Json::num(4.0)),
            ("fingerprint", Json::str(self.fingerprint.to_string())),
            ("dataset", Json::str(self.dataset.clone())),
            ("model", Json::str(self.model.as_str())),
            ("scale", Json::num(self.scale)),
            ("feat_density", Json::num(self.feat_density)),
            ("community", Json::num(self.community as f64)),
            ("reorder", Json::str(self.reorder.as_str())),
            // string, not number: u64 seeds above 2^53 don't survive f64
            ("seed", Json::str(self.seed.to_string())),
            // same encoding rationale as seed
            ("graph_version", Json::str(self.graph_version.to_string())),
            ("bucket", Json::str(self.bucket.clone())),
            ("chosen", pair_to_json(self.chosen)),
            ("assignment", self.assignment.to_json()),
            ("per_width", per_width),
            ("intra_times", times(&self.intra_times)),
            ("inter_times", times(&self.inter_times)),
            ("projected", cost_to_json(&self.projected)),
            ("monitor_iters", Json::num(self.monitor_iters as f64)),
            ("monitor_overhead_us", Json::num(self.monitor_overhead_us)),
            (
                "provenance",
                Json::obj(vec![
                    ("planner", Json::str(self.provenance.planner.clone())),
                    ("clock", Json::str(self.provenance.clock.clone())),
                    ("gpu", Json::str(self.provenance.gpu.clone())),
                    ("cached", Json::Bool(self.provenance.cached)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<GearPlan> {
        let req_str = |k: &str| {
            v.get(k)
                .as_str()
                .ok_or_else(|| anyhow!("plan missing string field {k:?}"))
        };
        let req_num = |k: &str| {
            v.get(k)
                .as_f64()
                .ok_or_else(|| anyhow!("plan missing numeric field {k:?}"))
        };
        let times = |k: &str| -> Result<BTreeMap<String, f64>> {
            v.get(k)
                .as_obj()
                .map(|o| {
                    o.iter()
                        .map(|(name, t)| {
                            let t = t.as_f64().ok_or_else(|| anyhow!("bad time for {name}"))?;
                            Ok((name.clone(), t))
                        })
                        .collect()
                })
                .unwrap_or_else(|| Ok(BTreeMap::new()))
        };
        let mut per_width = BTreeMap::new();
        if let Some(obj) = v.get("per_width").as_obj() {
            for (w, p) in obj {
                let w: usize = w.parse().map_err(|_| anyhow!("bad width key {w:?}"))?;
                per_width.insert(w, pair_from_json(p)?);
            }
        }
        let prov = v.get("provenance");
        let chosen = pair_from_json(v.get("chosen")).context("plan field 'chosen'")?;
        // Pre-hybrid (version 1) plans have no assignment — they fail to
        // decode, which the PlanStore treats as a cache miss, so stale
        // uniform-only decisions are replanned rather than served.
        let assignment = GearAssignment::from_json(v.get("assignment"))
            .context("plan field 'assignment' (pre-hybrid plans must be recomputed)")?;
        if assignment.executed_pair()? != chosen {
            bail!("plan 'chosen' disagrees with its assignment lowering");
        }
        Ok(GearPlan {
            fingerprint: req_str("fingerprint")?.parse()?,
            dataset: req_str("dataset")?.to_string(),
            model: req_str("model")?.parse()?,
            scale: req_num("scale")?,
            community: req_num("community")? as usize,
            reorder: req_str("reorder")?.parse()?,
            seed: req_str("seed")?
                .parse::<u64>()
                .map_err(|e| anyhow!("bad seed in plan: {e}"))?,
            bucket: req_str("bucket")?.to_string(),
            chosen,
            assignment,
            per_width,
            intra_times: times("intra_times")?,
            inter_times: times("inter_times")?,
            projected: cost_from_json(v.get("projected")),
            monitor_iters: req_num("monitor_iters")? as usize,
            monitor_overhead_us: v.get("monitor_overhead_us").as_f64().unwrap_or(0.0),
            // absent in pre-stream (version <= 2) files: frozen graph
            graph_version: v
                .get("graph_version")
                .as_str()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0),
            // absent in density-blind (version <= 3) files: dense features
            feat_density: v.get("feat_density").as_f64().unwrap_or(1.0),
            provenance: Provenance {
                planner: prov.get("planner").as_str().unwrap_or("unknown").to_string(),
                clock: prov.get("clock").as_str().unwrap_or("unknown").to_string(),
                gpu: prov.get("gpu").as_str().unwrap_or("unknown").to_string(),
                cached: prov.get("cached").as_bool().unwrap_or(false),
            },
        })
    }
}

fn pair_to_json(p: KernelPair) -> Json {
    Json::obj(vec![
        (
            "intra",
            match p.intra {
                Some(k) => Json::str(k.as_str()),
                None => Json::Null,
            },
        ),
        ("inter", Json::str(p.inter.as_str())),
    ])
}

fn pair_from_json(v: &Json) -> Result<KernelPair> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("kernel pair must be an object"))?;
    let inter: KernelKind = obj
        .get("inter")
        .and_then(|j| j.as_str())
        .ok_or_else(|| anyhow!("kernel pair missing inter"))?
        .parse()?;
    // An ABSENT intra is malformed; only an explicit null means the
    // full-graph variant — a truncated plan must not silently decode.
    let intra = match obj.get("intra") {
        None => bail!("kernel pair missing intra (use null for the full-graph variant)"),
        Some(Json::Null) => None,
        Some(other) => Some(
            other
                .as_str()
                .ok_or_else(|| anyhow!("kernel pair intra must be a string or null"))?
                .parse::<KernelKind>()?,
        ),
    };
    Ok(KernelPair { intra, inter })
}

fn cost_to_json(c: &IterationCost) -> Json {
    Json::obj(vec![
        ("aggregate_us", Json::num(c.aggregate_us)),
        ("update_us", Json::num(c.update_us)),
        ("overhead_us", Json::num(c.overhead_us)),
        ("l2_hits", Json::num(c.l2_hits as f64)),
        ("l2_accesses", Json::num(c.l2_accesses as f64)),
        ("kernel_launches", Json::num(c.kernel_launches as f64)),
    ])
}

fn cost_from_json(v: &Json) -> IterationCost {
    IterationCost {
        aggregate_us: v.get("aggregate_us").as_f64().unwrap_or(0.0),
        update_us: v.get("update_us").as_f64().unwrap_or(0.0),
        overhead_us: v.get("overhead_us").as_f64().unwrap_or(0.0),
        l2_hits: v.get("l2_hits").as_f64().unwrap_or(0.0) as u64,
        l2_accesses: v.get("l2_accesses").as_f64().unwrap_or(0.0) as u64,
        kernel_launches: v.get("kernel_launches").as_f64().unwrap_or(0.0) as usize,
    }
}

/// A pluggable kernel-decision maker.
pub trait Planner {
    /// Short id used in provenance and CLI output.
    fn name(&self) -> &'static str;

    /// Decide kernels for the request (possibly via cache or monitoring).
    fn plan(&mut self, req: &PlanRequest) -> Result<GearPlan>;
}

impl<P: Planner + ?Sized> Planner for Box<P> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn plan(&mut self, req: &PlanRequest) -> Result<GearPlan> {
        (**self).plan(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::planted_partition;
    use crate::gpusim::A100;
    use crate::partition::Propagation;
    use crate::util::json;
    use crate::util::rng::Rng;

    pub(crate) fn small_decomposition(seed: u64) -> Decomposition {
        let mut rng = Rng::new(seed);
        let g = planted_partition(128, 16, 0.5, 0.02, &mut rng);
        let mut sh: Vec<u32> = (0..128).collect();
        rng.shuffle(&mut sh);
        Decomposition::build(&g.relabel(&sh), Reorder::Metis, Propagation::GcnNormalized, 16, 1)
    }

    pub(crate) fn small_bucket() -> BucketInfo {
        BucketInfo {
            name: "b256".to_string(),
            vertices: 256,
            edges: 1024,
            features: 32,
            hidden: 32,
            classes: 8,
            blocks: 16,
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let d = small_decomposition(3);
        let bucket = small_bucket();
        let mut req = PlanRequest::new(&d, ModelKind::Gcn, &bucket);
        req.dataset = "cora".to_string();
        req.scale = 0.25;
        req.seed = u64::MAX - 12345; // above 2^53: must survive JSON exactly
        let plan = SimCostPlanner::new(&A100).plan(&req).unwrap();

        let text = json::write(&plan.to_json());
        let back = GearPlan::from_json(&json::parse(&text).unwrap()).unwrap();
        // canonical JSON equality covers every field, including f64 values
        assert_eq!(json::write(&back.to_json()), text);
        assert_eq!(back.fingerprint, plan.fingerprint);
        assert_eq!(back.chosen, plan.chosen);
        assert_eq!(back.per_width, plan.per_width);
        assert_eq!(back.model, plan.model);
        assert_eq!(back.reorder, plan.reorder);
        assert_eq!(back.seed, plan.seed);
    }

    #[test]
    fn full_graph_pair_serializes_null_intra() {
        let p = KernelPair::full_graph(KernelKind::CsrInter);
        let j = pair_to_json(p);
        assert_eq!(j.get("intra"), &Json::Null);
        assert_eq!(pair_from_json(&j).unwrap(), p);
        // absent intra is malformed, not full-graph
        let truncated = json::parse(r#"{"inter":"coo"}"#).unwrap();
        assert!(pair_from_json(&truncated).is_err());
    }

    #[test]
    fn validate_rejects_other_graphs() {
        let d = small_decomposition(3);
        let other = small_decomposition(4);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        assert!(plan.validate(&d, ModelKind::Gcn).is_ok());
        assert!(plan.validate(&other, ModelKind::Gcn).is_err());
        assert!(plan.validate(&d, ModelKind::Gin).is_err());
    }

    #[test]
    fn uniform_assignment_is_consistent_with_chosen() {
        let d = small_decomposition(6);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        // small graphs stay uniform: one intra class + inter
        assert!(!plan.assignment.is_hybrid());
        assert_eq!(plan.assignment.classes.len(), 2);
        assert_eq!(plan.assignment.executed_pair().unwrap(), plan.chosen);
        assert!(plan.assignment.covers(&d).is_ok());
        let intra: usize = plan.assignment.intra_classes().map(|c| c.nnz).sum();
        assert_eq!(intra, d.intra.nnz());
    }

    #[test]
    fn pre_hybrid_plan_files_fail_to_decode() {
        // a v1 plan (no assignment) must not silently decode — the store
        // treats the parse failure as a miss and replans
        let d = small_decomposition(8);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        let Json::Obj(mut obj) = plan.to_json() else { unreachable!() };
        obj.remove("assignment");
        let err = GearPlan::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(err.to_string().contains("assignment"), "{err:#}");
    }

    #[test]
    fn provenance_roundtrips_and_plans_without_it_still_load() {
        let d = small_decomposition(9);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        let prov = plan
            .assignment
            .provenance
            .as_ref()
            .expect("planned assignments carry sweep provenance");
        assert_eq!(prov.threshold, plan.assignment.threshold);
        // every executed class has candidate costs including the kernel
        // that won it
        for c in &plan.assignment.classes {
            let cc = prov.class_costs.iter().find(|cc| cc.class == c.class).unwrap();
            assert!(cc.costs.contains_key(c.kernel.as_str()));
        }

        // provenance survives the JSON roundtrip exactly
        let text = json::write(&plan.to_json());
        let back = GearPlan::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.assignment.provenance.as_ref(), Some(prov));

        // a plan file written before provenance existed (assignment has
        // no "provenance" key) still decodes, covers, and validates —
        // old cache entries must keep loading
        let Json::Obj(mut obj) = plan.to_json() else { unreachable!() };
        let Some(Json::Obj(mut a)) = obj.remove("assignment") else { unreachable!() };
        a.remove("provenance");
        obj.insert("assignment".to_string(), Json::Obj(a));
        let old = GearPlan::from_json(&Json::Obj(obj)).unwrap();
        assert!(old.assignment.provenance.is_none());
        assert!(old.assignment.covers(&d).is_ok());
        assert!(old.validate(&d, ModelKind::Gcn).is_ok());
    }

    #[test]
    fn density_blind_plan_files_decode_as_dense_and_validate() {
        // a v3 file has no feat_density key: it must load as rho = 1.0
        // (its fingerprint was computed dense) and still validate
        let d = small_decomposition(11);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        assert_eq!(plan.feat_density, 1.0, "default requests assume dense features");
        let Json::Obj(mut obj) = plan.to_json() else { unreachable!() };
        obj.remove("feat_density");
        obj.insert("version".to_string(), Json::num(3.0));
        let old = GearPlan::from_json(&Json::Obj(obj)).unwrap();
        assert_eq!(old.feat_density, 1.0);
        assert!(old.validate(&d, ModelKind::Gcn).is_ok());

        // a sparse-feature request keys a different cache slot
        let mut sparse = PlanRequest::new(&d, ModelKind::Gcn, &bucket);
        sparse.feat_density = 0.125;
        assert_ne!(sparse.fingerprint(), PlanRequest::new(&d, ModelKind::Gcn, &bucket).fingerprint());
    }

    #[test]
    fn malformed_provenance_is_rejected_not_ignored() {
        // present-but-broken provenance must fail the decode (silent
        // acceptance would hide corrupt plan files)
        let bad = json::parse(r#"{"class_costs":[],"candidates":[]}"#).unwrap();
        assert!(SweepProvenance::from_json(&bad).is_err(), "missing threshold");
        let bad_candidate =
            json::parse(r#"{"threshold":0.5,"candidates":[{"threshold":0.1}]}"#).unwrap();
        assert!(SweepProvenance::from_json(&bad_candidate).is_err(), "missing outcome");
    }

    #[test]
    fn from_json_rejects_malformed_plans() {
        assert!(GearPlan::from_json(&json::parse("{}").unwrap()).is_err());
        let d = small_decomposition(5);
        let bucket = small_bucket();
        let plan = SimCostPlanner::new(&A100)
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        let text = json::write(&plan.to_json()).replace("csr", "zzz");
        assert!(GearPlan::from_json(&json::parse(&text).unwrap()).is_err());
    }
}
