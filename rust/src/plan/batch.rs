//! Amortized per-batch planning: a plan cache keyed by density *profile*.
//!
//! Exact topology fingerprints ([`Fingerprint`](super::Fingerprint)) are
//! the right cache key for full-graph plans — the same graph recurs run
//! after run. Sampled mini-batches are the opposite regime: every batch
//! is a fresh subgraph that will *never* recur exactly, but batches drawn
//! from the same graph with the same fanout have near-identical density
//! profiles, and the kernel decision depends only on that profile. So the
//! [`BatchPlanner`] keys its cache on a [`BatchProfile`] — coarsely
//! bucketed rows / nnz / intra fraction / block-density histogram — and,
//! on a hit, *re-derives* a valid [`GearPlan`] for the new batch from the
//! cached **decision** (threshold + per-class kernels): the class stats
//! are recomputed from the batch's real block profile, the bucket
//! admissibility is re-checked, and the plan carries the batch's own
//! fingerprint, so a served plan always validates against the batch it
//! executes. Inadmissible or degenerate adaptations fall back to the
//! inner planner (a full threshold sweep) and refresh the cache.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::ModelKind;
use crate::gpusim::kernel_cost::{est_occupied_tiles, CostCtx};
use crate::gpusim::{class_kernel_cost, kernel_cost_density, ClassDims, GpuModel, IterationCost};
use crate::kernels::tile::tile_capacity;
use crate::kernels::{candidates, KernelKind, KernelPair, Role};
use crate::partition::{BlockProfile, Decomposition, DensityClass};

use super::{
    ClassAssignment, GearAssignment, GearPlan, PlanRequest, Planner, Provenance, SubgraphClass,
};

/// Coarse density profile of one batch decomposition — the cache key for
/// amortized planning. Deliberately lossy: batches from the same
/// (graph, fanout, batch-size) workload should collide, and safety comes
/// from the per-batch re-derivation, not from key precision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchProfile {
    pub model: ModelKind,
    pub community: usize,
    /// `ceil(log2(rows))` — batch size class.
    pub rows_log2: u32,
    /// `ceil(log2(total nnz + 1))` — edge budget class.
    pub nnz_log2: u32,
    /// Intra share of the nnz, quantized to quarters (0..=4). Coarse on
    /// purpose: a spurious collision only re-derives a plan, a spurious
    /// miss re-runs the whole threshold sweep.
    pub intra_quarters: u8,
    /// Block-density histogram over 4 equal-width bins, each quantized to
    /// quarters of the block count.
    pub hist_quarters: [u8; 4],
}

/// `ceil(log2(v))` — the coarse size-class quantizer shared by the batch
/// profile key and the streaming drift tracker (`stream::drift` flags the
/// inter class only when this moves).
pub fn coarse_log2(v: usize) -> u32 {
    let v = v.max(1) as u64;
    64 - (v - 1).leading_zeros().min(64)
}

impl BatchProfile {
    pub fn of(d: &Decomposition, model: ModelKind) -> BatchProfile {
        BatchProfile::of_profile(&d.intra_block_profile(), d, model)
    }

    /// [`BatchProfile::of`] over an already-computed block profile, so
    /// the planner's hot path walks the intra part once per batch.
    pub fn of_profile(
        profile: &BlockProfile,
        d: &Decomposition,
        model: ModelKind,
    ) -> BatchProfile {
        let blocks = profile.len().max(1);
        let hist4 = profile.histogram(4);
        let mut hist_quarters = [0u8; 4];
        for (i, &count) in hist4.iter().enumerate() {
            hist_quarters[i] = ((count * 4 + blocks / 2) / blocks).min(4) as u8;
        }
        let intra = d.intra.nnz();
        let total = intra + d.inter.nnz();
        let intra_quarters = if total == 0 {
            0
        } else {
            ((intra * 4 + total / 2) / total).min(4) as u8
        };
        BatchProfile {
            model,
            community: d.community,
            rows_log2: coarse_log2(d.graph.n),
            nnz_log2: coarse_log2(total + 1),
            intra_quarters,
            hist_quarters,
        }
    }

    /// FNV-1a digest for map keying / diagnostics.
    pub fn key(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut put = |b: u64| {
            for byte in b.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        put(self.model as u64);
        put(self.community as u64);
        put(self.rows_log2 as u64);
        put(self.nnz_log2 as u64);
        put(self.intra_quarters as u64);
        for &e in &self.hist_quarters {
            put(e as u64);
        }
        h
    }
}

/// The part of a plan worth remembering across similar batches: the
/// density threshold and which kernel runs each class. Everything else
/// (stats, fingerprint, costs) is batch-specific and re-derived. Also
/// the unit of reuse for streaming re-planning (`stream::replan` adapts
/// the live plan's decision to the mutated decomposition instead of
/// re-running the sweep).
#[derive(Debug, Clone)]
pub struct PlanDecision {
    pub threshold: f64,
    pub dense: Option<KernelKind>,
    pub sparse: Option<KernelKind>,
    pub inter: KernelKind,
}

impl PlanDecision {
    pub fn of(a: &GearAssignment, inter: KernelKind) -> PlanDecision {
        PlanDecision {
            threshold: a.threshold,
            dense: a.kernel_for(SubgraphClass::DenseIntra),
            sparse: a.kernel_for(SubgraphClass::SparseIntra),
            inter,
        }
    }
}

/// Profile-keyed amortized planner for mini-batch workloads.
///
/// A hit costs one block-profile pass + closed-form class pricing; a
/// miss delegates to `inner` (typically
/// [`SimCostPlanner`](super::SimCostPlanner), whose threshold sweep is
/// the expensive step being amortized) and caches the resulting
/// decision. Hit/miss counters feed the `sample` bench suite's
/// `plan_cache/hit_rate` metric.
pub struct BatchPlanner<P> {
    gpu: &'static GpuModel,
    inner: P,
    cache: HashMap<u64, PlanDecision>,
    hits: usize,
    misses: usize,
}

impl<P: Planner> BatchPlanner<P> {
    pub fn new(inner: P, gpu: &'static GpuModel) -> BatchPlanner<P> {
        BatchPlanner { gpu, inner, cache: HashMap::new(), hits: 0, misses: 0 }
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Distinct cached profiles.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    /// Hits over total plans served so far (0.0 before the first plan).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

}

/// Adapt a cached decision to `req`'s actual batch: reclassify the
/// blocks at the cached threshold, rebuild the class stats, and
/// re-check bucket admissibility. `None` means the decision does not
/// transfer (degenerate split with no usable kernel, or the operands
/// would overflow the bucket) and a full sweep must run. Free function
/// because the streaming re-planner (`stream::replan`) reuses it
/// against the live plan's decision.
pub fn adapt_decision(
    decision: &PlanDecision,
    req: &PlanRequest,
    profile: &BlockProfile,
    gpu: &'static GpuModel,
) -> Option<GearAssignment> {
    let d = req.d;
    let bucket = req.bucket;
    if d.graph.n > bucket.vertices {
        return None;
    }
    let widths = req.widths();
    let labels = profile.classify(decision.threshold);
    let mut dense = (0usize, 0usize, 0usize); // (blocks, rows, nnz)
    let mut sparse = (0usize, 0usize, 0usize);
    for (b, label) in labels.iter().enumerate() {
        let (rows, nnz) = profile.blocks[b];
        let side = match label {
            DensityClass::Dense => &mut dense,
            DensityClass::Sparse => &mut sparse,
        };
        side.0 += 1;
        side.1 += rows;
        side.2 += nnz;
    }
    let mean_class = |kind: KernelKind, blocks: usize, rows: usize, nnz: usize| -> f64 {
        let dims = ClassDims { kind, blocks, rows, nnz };
        widths
            .iter()
            .map(|&w| {
                class_kernel_cost(
                    &CostCtx::new(dims, w, d.community, gpu).with_feat_density(req.feat_density),
                )
                .time_us
            })
            .sum::<f64>()
            / widths.len().max(1) as f64
    };
    // A tile-sparse class must still fit the bucket's reserved tile grid
    // on THIS batch (same estimate the sweep vetoes with).
    let tile_fits = |blocks: usize, nnz: usize| {
        est_occupied_tiles(blocks, nnz, d.community)
            <= tile_capacity(bucket.blocks, d.community) as f64
    };
    let inter_time = widths
        .iter()
        .map(|&w| {
            kernel_cost_density(decision.inter, &d.inter, w, d.community, gpu, req.feat_density)
                .time_us
        })
        .sum::<f64>()
        / widths.len().max(1) as f64;
    let inter_class = ClassAssignment {
        class: SubgraphClass::Inter,
        kernel: decision.inter,
        blocks: 0,
        rows: d.inter.n_rows,
        nnz: d.inter.nnz(),
        time_us: inter_time,
    };

    if dense.0 > 0 && sparse.0 > 0 {
        // Genuinely hybrid on this batch too: needs both kernels and
        // the merged sparse+inter operand must fit the bucket.
        let (dk, sk) = (decision.dense?, decision.sparse?);
        if dense.2 > bucket.edges || sparse.2 + d.inter.nnz() > bucket.edges {
            return None;
        }
        if dk == KernelKind::TileSparse && !tile_fits(dense.0, dense.2) {
            return None;
        }
        return Some(GearAssignment {
            threshold: decision.threshold,
            classes: vec![
                ClassAssignment {
                    class: SubgraphClass::DenseIntra,
                    kernel: dk,
                    blocks: dense.0,
                    rows: dense.1,
                    nnz: dense.2,
                    time_us: mean_class(dk, dense.0, dense.1, dense.2),
                },
                ClassAssignment {
                    class: SubgraphClass::SparseIntra,
                    kernel: sk,
                    blocks: sparse.0,
                    rows: sparse.1,
                    nnz: sparse.2,
                    time_us: mean_class(sk, sparse.0, sparse.1, sparse.2),
                },
                inter_class,
            ],
            // Adapted from a cached decision — the donor's sweep
            // record does not describe THIS batch's candidates.
            provenance: None,
        });
    }

    // One-sided split on this batch: collapse to the uniform plan for
    // whichever side is populated (the uniform extremes are always
    // executable when the subgraphs fit the bucket). The class kernel
    // must be able to run in the intra artifact slot — a sparse class
    // that ran as COO under the merged-operand lowering cannot.
    let (kernel, stats) = if dense.0 > 0 {
        (decision.dense?, dense)
    } else {
        (decision.sparse?, sparse)
    };
    if !candidates(Role::IntraSlot).contains(&kernel) {
        return None;
    }
    if kernel == KernelKind::TileSparse && !tile_fits(stats.0, stats.2) {
        return None;
    }
    if stats.2 > bucket.edges || d.inter.nnz() > bucket.edges {
        return None;
    }
    let pair = KernelPair::new(kernel, decision.inter);
    Some(GearAssignment::uniform(
        pair,
        (profile.len(), stats.1, stats.2, mean_class(kernel, stats.0, stats.1, stats.2)),
        (d.inter.n_rows, d.inter.nnz(), inter_time),
    ))
}

/// Assemble a served plan around an adapted assignment. `planner_label`
/// names the adapting consumer in the provenance ("batch" for the
/// amortized mini-batch cache, "replan" for the streaming re-planner).
pub fn plan_from_decision(
    req: &PlanRequest,
    assignment: GearAssignment,
    gpu: &'static GpuModel,
    planner_label: &str,
) -> Result<GearPlan> {
    let chosen = assignment.executed_pair()?;
    let widths = req.widths();
    let mut per_width = std::collections::BTreeMap::new();
    for &w in &widths {
        per_width.insert(w, chosen);
    }
    let mut intra_times = std::collections::BTreeMap::new();
    for c in assignment.intra_classes() {
        intra_times.insert(c.kernel.as_str().to_string(), c.time_us);
    }
    let mut inter_times = std::collections::BTreeMap::new();
    let inter = assignment.inter_class()?;
    inter_times.insert(inter.kernel.as_str().to_string(), inter.time_us);
    // Cheap projection from the class-cost basis (one launch set per
    // aggregate width) — amortized plans must not pay a cache sim.
    let projected = IterationCost {
        aggregate_us: assignment.total_cost_us() * widths.len() as f64,
        update_us: 0.0,
        overhead_us: 0.0,
        l2_hits: 0,
        l2_accesses: 0,
        kernel_launches: assignment.classes.len() * widths.len(),
    };
    Ok(GearPlan {
        fingerprint: req.fingerprint(),
        dataset: req.dataset.clone(),
        model: req.model,
        scale: req.scale,
        community: req.d.community,
        reorder: req.reorder,
        seed: req.seed,
        bucket: req.bucket.name.clone(),
        chosen,
        assignment,
        per_width,
        intra_times,
        inter_times,
        projected,
        monitor_iters: 0,
        monitor_overhead_us: 0.0,
        graph_version: req.graph_version,
        feat_density: req.feat_density,
        provenance: Provenance {
            planner: planner_label.to_string(),
            clock: "analytic".to_string(),
            gpu: gpu.name.to_string(),
            cached: true,
        },
    })
}

impl<P: Planner> Planner for BatchPlanner<P> {
    fn name(&self) -> &'static str {
        "batch"
    }

    fn plan(&mut self, req: &PlanRequest) -> Result<GearPlan> {
        // ONE block-profile pass per batch, shared by the key and the
        // hit-path re-derivation.
        let profile = req.d.intra_block_profile();
        let key = BatchProfile::of_profile(&profile, req.d, req.model).key();
        let cached = self.cache.get(&key).cloned();
        if let Some(decision) = cached {
            if let Some(assignment) = adapt_decision(&decision, req, &profile, self.gpu) {
                self.hits += 1;
                crate::obs::counter("plan.cache.hit").inc();
                return plan_from_decision(req, assignment, self.gpu, "batch");
            }
            // Inadmissible adaptation: fall through, replan, refresh.
        }
        let plan = self.inner.plan(req)?;
        self.misses += 1;
        crate::obs::counter("plan.cache.miss").inc();
        self.cache
            .insert(key, PlanDecision::of(&plan.assignment, plan.chosen.inter));
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{small_bucket, small_decomposition};
    use super::super::SimCostPlanner;
    use super::*;
    use crate::graph::generate::planted_partition_mixed;
    use crate::gpusim::A100;
    use crate::partition::{Propagation, Reorder};
    use crate::runtime::BucketInfo;
    use crate::util::rng::Rng;

    /// A topology-identical twin whose weights differ: the density
    /// PROFILE (pure counts) is unchanged, the exact FINGERPRINT (weights
    /// included) is not — exactly the "similar but not identical batch"
    /// the amortized planner exists for, with no quantization luck.
    fn weight_tweaked(d: &Decomposition) -> Decomposition {
        let mut out = d.clone();
        if let Some(v) = out.intra.vals.first_mut() {
            *v += 0.001;
        } else if let Some(v) = out.inter.vals.first_mut() {
            *v += 0.001;
        }
        out
    }

    #[test]
    fn coarse_log2_buckets() {
        assert_eq!(coarse_log2(1), 0);
        assert_eq!(coarse_log2(2), 1);
        assert_eq!(coarse_log2(3), 2);
        assert_eq!(coarse_log2(1024), 10);
        assert_eq!(coarse_log2(1025), 11);
    }

    #[test]
    fn profile_is_stable_and_weight_blind() {
        let d = small_decomposition(3);
        let p1 = BatchProfile::of(&d, ModelKind::Gcn);
        let p2 = BatchProfile::of(&d, ModelKind::Gcn);
        assert_eq!(p1, p2);
        assert_eq!(p1.key(), p2.key());
        // model participates in the key
        let gin = BatchProfile::of(&d, ModelKind::Gin);
        assert_ne!(p1.key(), gin.key());
        // weights do not: the profile sees counts only
        let twin = weight_tweaked(&d);
        assert_eq!(p1.key(), BatchProfile::of(&twin, ModelKind::Gcn).key());
        assert_ne!(
            crate::plan::Fingerprint::of(&d, ModelKind::Gcn),
            crate::plan::Fingerprint::of(&twin, ModelKind::Gcn),
            "the exact fingerprint must see the weight change"
        );
    }

    #[test]
    fn same_profile_different_fingerprint_hits_and_validates() {
        let bucket = small_bucket();
        let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
        let d1 = small_decomposition(5);
        let cold = planner
            .plan(&PlanRequest::new(&d1, ModelKind::Gcn, &bucket))
            .unwrap();
        assert_eq!(planner.misses(), 1);
        assert!(!cold.provenance.cached);
        // identical profile, different exact fingerprint: must be served
        // from the profile cache AND carry the new batch's fingerprint
        let d2 = weight_tweaked(&d1);
        let warm = planner
            .plan(&PlanRequest::new(&d2, ModelKind::Gcn, &bucket))
            .unwrap();
        assert_eq!(planner.hits(), 1, "same-profile batch must hit");
        assert!(warm.provenance.cached);
        assert_eq!(warm.provenance.planner, "batch");
        assert_eq!(warm.monitor_iters, 0);
        assert!(warm.validate(&d2, ModelKind::Gcn).is_ok());
        assert!(warm.validate(&d1, ModelKind::Gcn).is_err());
        assert_eq!(warm.chosen, cold.chosen);
        assert!(planner.hit_rate() > 0.49);
    }

    #[test]
    fn hybrid_decision_transfers_across_similar_batches() {
        // A mixed-density graph plans hybrid; a topology-identical twin
        // with different weights must adapt the cached threshold into a
        // plan that validates against the twin.
        // Same scale the planners' hybrid acceptance test asserts splits.
        let mut rng = Rng::new(5);
        let n = 131072;
        let g = planted_partition_mixed(n, 64, 0.95, 0.005, 3, 0.3 / n as f64, &mut rng);
        let d = Decomposition::build(
            &g,
            Reorder::Identity,
            Propagation::GcnNormalized,
            64,
            0,
        );
        let bucket = BucketInfo {
            name: "bb".to_string(),
            vertices: n,
            edges: 8 * 1024 * 1024,
            features: 32,
            hidden: 32,
            classes: 4,
            blocks: n / 64,
        };
        let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
        let cold = planner
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        assert!(cold.assignment.is_hybrid(), "mixed graph must plan hybrid");
        let twin = weight_tweaked(&d);
        let warm = planner
            .plan(&PlanRequest::new(&twin, ModelKind::Gcn, &bucket))
            .unwrap();
        assert_eq!(planner.misses(), 1);
        assert_eq!(planner.hits(), 1, "twin batch must reuse the swept decision");
        assert!(warm.provenance.cached);
        assert!(warm.assignment.is_hybrid());
        assert!(warm.validate(&twin, ModelKind::Gcn).is_ok());
        // the adapted assignment agrees with the donor's decision
        assert_eq!(warm.assignment.threshold, cold.assignment.threshold);
        assert_eq!(warm.assignment.intra_kernels(), cold.assignment.intra_kernels());
        assert_eq!(warm.chosen, cold.chosen);
    }

    #[test]
    fn inadmissible_adaptation_falls_back_to_inner() {
        let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
        let d = small_decomposition(7);
        let bucket = small_bucket();
        planner
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        // same profile, but a bucket too small for the batch: adapt()
        // must refuse and the inner planner must run again
        let d2 = weight_tweaked(&d);
        let mut tiny = small_bucket();
        tiny.edges = 1;
        let plan = planner
            .plan(&PlanRequest::new(&d2, ModelKind::Gcn, &tiny))
            .unwrap();
        assert_eq!(planner.misses(), 2, "tiny bucket must force a replan");
        assert!(!plan.provenance.cached);
    }

    #[test]
    fn degenerate_split_collapses_to_uniform() {
        // Cache a decision, then serve a batch whose blocks all land on
        // one side of the threshold: the adapted plan must be uniform and
        // still validate.
        let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
        let bucket = small_bucket();
        let d1 = small_decomposition(10);
        let p1 = planner
            .plan(&PlanRequest::new(&d1, ModelKind::Gcn, &bucket))
            .unwrap();
        let d2 = small_decomposition(11);
        let p2 = planner
            .plan(&PlanRequest::new(&d2, ModelKind::Gcn, &bucket))
            .unwrap();
        // small planted graphs stay uniform; the adaptation path is the
        // one-sided branch either way
        assert!(!p1.assignment.is_hybrid());
        assert!(!p2.assignment.is_hybrid());
        assert!(p2.validate(&d2, ModelKind::Gcn).is_ok());
        assert_eq!(planner.hits() + planner.misses(), 2);
    }

    #[test]
    fn planner_name_and_counters() {
        let mut planner = BatchPlanner::new(SimCostPlanner::new(&A100), &A100);
        assert_eq!(planner.name(), "batch");
        assert!(planner.is_empty());
        assert_eq!(planner.hit_rate(), 0.0);
        let d = small_decomposition(12);
        let bucket = small_bucket();
        planner
            .plan(&PlanRequest::new(&d, ModelKind::Gcn, &bucket))
            .unwrap();
        assert_eq!(planner.len(), 1);
    }
}
